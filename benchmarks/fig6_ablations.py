"""Paper Fig. 6 ablations: adaptive search on/off (a), loss function (b),
number of basis vectors (c), number of calibration trajectories (d)."""
from . import common


def run(nfe: int = 10) -> list[dict]:
    gmm = common.oracle()
    rows = []

    # (a) adaptive search: without it (tolerance=-inf => always correct,
    # no final gate) quality degrades vs with it (paper Fig. 6a / Table 7)
    _, (x_c, gt_c), (x_e, gt_e) = common.calib_eval_sets(gmm, nfe)
    for label, cfg in (
        ("PAS", common.default_pas_cfg()),
        ("PAS(-AS)", common.default_pas_cfg(tolerance=-1e9, final_gate=False,
                                            val_fraction=0.0)),
    ):
        pipe = common.pipeline_for(gmm.eps, "ddim", nfe, pas_cfg=cfg)
        pipe.calibrate(x_t=x_c, gt=gt_c)
        x0, _ = pipe.trajectory(x_e)
        rows.append({"panel": "a_adaptive_search", "method": label, "nfe": nfe,
                     "err_l2": common.final_err(x0, gt_e[-1]),
                     "n_corrected": int(pipe.params.active.sum())})

    # (b) loss functions
    for loss in ("l1", "l2", "pseudo_huber"):
        r = common.run_pas("ddim", nfe, gmm, common.default_pas_cfg(loss=loss))
        rows.append({"panel": "b_loss", "loss": loss, "nfe": nfe,
                     "err_l2": r["err_pas"]})

    # (c) number of basis vectors 1..4 (paper: >=2 works, 3-4 slightly better)
    for k in (1, 2, 3, 4):
        r = common.run_pas("ddim", nfe, gmm, common.default_pas_cfg(n_basis=k))
        rows.append({"panel": "c_n_basis", "n_basis": k, "nfe": nfe,
                     "err_l2": r["err_pas"]})

    # (d) number of calibration trajectories
    for n_traj in (64, 128, 256, 512):
        _, (x_c, gt_c), (x_e2, gt_e2) = common.calib_eval_sets(
            gmm, nfe, n_calib=n_traj)
        pipe = common.pipeline_for(gmm.eps, "ddim", nfe)
        pipe.calibrate(x_t=x_c, gt=gt_c)
        x0, _ = pipe.trajectory(x_e2)
        rows.append({"panel": "d_n_trajectories", "n_traj": n_traj, "nfe": nfe,
                     "err_l2": common.final_err(x0, gt_e2[-1])})

    common.save_table("fig6_ablations", rows)

    plain = common.run_pas("ddim", nfe, gmm)["err_plain"]
    k_errs = {r["n_basis"]: r["err_l2"] for r in rows if r["panel"] == "c_n_basis"}
    assert k_errs[2] < plain * 0.6            # 2 basis vectors already help
    assert min(k_errs[3], k_errs[4]) <= k_errs[2] * 1.1  # 3-4 at least as good
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
