"""Paper Fig. 2: PCA cumulative percent variance of sampling trajectories.

(a) single trajectory [x_T, d_N..d_1]: saturates by ~3 PCs (the PAS premise).
(b) K trajectories pooled: does NOT saturate (samples live in distinct
    subspaces) — why coordinates, not basis vectors, are what generalises.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pca, solvers

from . import common


def run() -> list[dict]:
    gmm = common.oracle()
    sol = common.spec_for("euler", 100).make_solver()
    x_t = gmm.sample_prior(jax.random.key(1), 64, common.T_MAX)
    xs, ds = solvers.sample_trajectory(sol, gmm.eps, x_t)

    rows = []
    # (a) per-trajectory [x_T, d_i...] cumvar, averaged over samples
    cum = []
    for b in range(16):
        traj = jnp.concatenate([x_t[b][None], ds[:, b]], axis=0)
        cum.append(np.asarray(pca.cumulative_variance(traj, center=False)))
    mean_cum = np.mean(cum, axis=0)
    for k in range(1, 7):
        rows.append({"panel": "a_single_trajectory", "n_components": k,
                     "cum_variance": float(mean_cum[k - 1])})

    # (b) pooled across K trajectories (states x_t)
    pooled = xs.transpose(1, 0, 2).reshape(-1, xs.shape[-1])[: 64 * 20]
    cv_pool = np.asarray(pca.cumulative_variance(jnp.asarray(pooled)))
    for k in (1, 2, 3, 5, 10, 20):
        rows.append({"panel": "b_pooled_K_trajectories", "n_components": k,
                     "cum_variance": float(cv_pool[k - 1])})

    common.save_table("fig2_pca_variance", rows)
    # headline claims (tested in tests/test_benchmarks.py)
    assert mean_cum[2] > 0.995, mean_cum[:4]
    assert cv_pool[2] < 0.9, cv_pool[:4]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
