"""Real backbones on the mesh: TP sampling throughput + zoo recalibration.

Two measurements, one root-level ``BENCH_backbone_mesh.json``:

* **TP sampling arms** — each (dp, state, tp) shape runs in its own
  subprocess (jax locks the host device table at first init) with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``: a zoo backbone is
  materialized onto the mesh via ``repro.models.build_eps`` and sampled
  through the mesh-native engine, TP collectives nested inside the DP scan.
  The replicated (1x1x1) arm is the oracle baseline the TP rows compare
  against (samples/sec ratio).
* **Zoo recalibration** — ``repro.engine.zoo`` calibrates an NFE ladder on
  ONE shared teacher trajectory vs the per-spec path; the row records both
  wall-clocks AND the teacher-eval ledger (evals counted once, not once per
  spec — the ISSUE acceptance metric).

On this CPU-only container the virtual devices share the same cores, so
absolute TP numbers measure partitioning overhead rather than real scaling;
``backend`` is recorded so accelerator runs are distinguishable.

  PYTHONPATH=src python -m benchmarks.backbone_mesh [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_backbone_mesh.json"

ARCH = "qwen1.5-0.5b"

_TP_WORKER = r"""
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from repro.api import MeshSpec, Pipeline, SamplerSpec
from repro.models import build_eps

dp, state, tp, seq, batch, nfe, n_rep = (int(a) for a in sys.argv[1:8])
ms = MeshSpec(dp=dp, state=state, tp=tp)
model = build_eps("%(arch)s", seq=seq,
                  mesh=None if ms.is_single else ms)
spec = SamplerSpec(solver="ddim", nfe=nfe,
                   mesh=None if ms.is_single else ms)
pipe = Pipeline.from_spec(spec, model.fn, dim=model.dim)
x = pipe.prior(jax.random.key(0), batch)

# timing discipline (matches sharded_throughput): compile + 2 warmups, then
# min over per-call-synced repeats
jax.block_until_ready(pipe.sample(x, use_pas=False))
for _ in range(2):
    jax.block_until_ready(pipe.sample(x, use_pas=False))
times = []
for _ in range(n_rep):
    t0 = time.perf_counter()
    jax.block_until_ready(pipe.sample(x, use_pas=False))
    times.append(time.perf_counter() - t0)
row = {"mesh": f"{dp}x{state}x{tp}", "arch": "%(arch)s", "seq": seq,
       "dim": model.dim, "batch": batch, "nfe": nfe,
       "samples_per_s": round(batch / min(times), 2),
       "n_params": model.n_params, "reps": n_rep,
       "timing": "min-over-reps, per-call sync"}
print("ROW_JSON:" + json.dumps(row))
""" % {"arch": ARCH}

_ZOO_WORKER = r"""
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from repro.api import PASConfig, SamplerSpec, TeacherSpec
from repro.core import two_mode_gmm
from repro.engine import get_calibration_engine_for_spec
from repro.engine.zoo import ZooCalibrationEngine

dim, batch, teacher_nfe, sgd = (int(a) for a in sys.argv[1:5])
nfes = tuple(int(n) for n in sys.argv[5].split(","))
gmm = two_mode_gmm(dim, sep=6.0, var=0.25)
specs = {f"nfe{n}": SamplerSpec(
             solver="ddim", nfe=n, teacher=TeacherSpec(nfe=teacher_nfe),
             pas=PASConfig(n_sgd_iters=sgd))
         for n in nfes}
x = gmm.sample_prior(jax.random.key(0), batch, 80.0)

def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0

def per_spec_pass():
    for s in specs.values():
        eng = get_calibration_engine_for_spec(s)
        gt = eng.teacher_trajectory(gmm.eps, x)   # per-spec teacher: old cost
        eng.calibrate(gmm.eps, x, gt)

# cold = first recalibration (includes XLA compile of the one batched zoo
# program vs the several small per-spec programs); warm = every subsequent
# model drop (programs cached, only teacher + Algorithm-1 runtime remains
# — the steady-state fleet cost)
zoo = ZooCalibrationEngine(specs)
results, t_zoo_cold = timed(lambda: zoo.calibrate(gmm.eps, x))
_, t_zoo_warm = timed(lambda: zoo.calibrate(gmm.eps, x))
_, t_per_spec_cold = timed(per_spec_pass)
_, t_per_spec_warm = timed(per_spec_pass)

ledger = results[f"nfe{nfes[0]}"][1]["zoo"]
row = {"nfes": list(nfes), "teacher_nfe": teacher_nfe, "dim": dim,
       "batch": batch,
       "zoo_wall_s_cold": round(t_zoo_cold, 2),
       "zoo_wall_s_warm": round(t_zoo_warm, 2),
       "per_spec_wall_s_cold": round(t_per_spec_cold, 2),
       "per_spec_wall_s_warm": round(t_per_spec_warm, 2),
       "teacher_evals_shared": ledger["teacher_evals"],
       "teacher_evals_per_spec_sum": ledger["teacher_evals_per_spec_sum"],
       "teacher_evals_counted_once": True,
       "shared_grid_nfe": ledger["shared_grid_nfe"],
       "note": "oracle eps is nearly free on CPU, so the eval ledger (not "
               "wall-clock) is the accelerator-relevant signal; cold "
               "includes one-time XLA compile of the batched program"}
print("ROW_JSON:" + json.dumps(row))
"""


def _run_worker(script: str, argv: list[str], n_dev: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env.setdefault("PYTHONPATH", str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", script, *argv],
                         capture_output=True, text=True, env=env,
                         timeout=1500)
    if out.returncode != 0:
        raise RuntimeError(f"worker {argv} failed:\n{out.stderr[-2000:]}")
    payload = next(line for line in out.stdout.splitlines()
                   if line.startswith("ROW_JSON:"))
    return json.loads(payload[len("ROW_JSON:"):])


def run(dry_run: bool = False) -> dict:
    seq, batch, nfe, n_rep = (8, 32, 6, 5) if not dry_run else (4, 8, 3, 2)
    meshes = [(1, 1, 1), (2, 1, 1), (1, 1, 2), (2, 1, 2)]
    if not dry_run:
        meshes += [(1, 1, 4), (2, 1, 4)]

    tp_rows = []
    for dp, state, tp in meshes:
        row = _run_worker(_TP_WORKER, [str(v) for v in
                                       (dp, state, tp, seq, batch, nfe, n_rep)])
        tp_rows.append(row)
        print(row)
    base = next(r for r in tp_rows if r["mesh"] == "1x1x1")
    for r in tp_rows:
        r["vs_replicated"] = round(r["samples_per_s"]
                                   / base["samples_per_s"], 3)

    dim, cal_batch, teacher_nfe, sgd = ((64, 256, 60, 100) if not dry_run
                                        else (16, 32, 12, 20))
    nfes = "5,6,10" if not dry_run else "2,3"
    zoo_row = _run_worker(_ZOO_WORKER,
                          [str(dim), str(cal_batch), str(teacher_nfe),
                           str(sgd), nfes], n_dev=1)
    print(zoo_row)

    report = {
        "tp_sampling": tp_rows,
        "zoo_recalibration": zoo_row,
        "arch": ARCH,
        "generated": time.strftime("%F %T"),
    }
    if not dry_run:               # smoke runs don't pollute the perf record
        import jax
        report["backend"] = jax.default_backend()
        OUT.write_text(json.dumps(report, indent=1))
        from . import common
        common.save_table("backbone_mesh", tp_rows + [zoo_row],
                          extra={"backend": report["backend"]})
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="small arms, no JSON write (CI smoke)")
    args = ap.parse_args()
    run(dry_run=args.dry_run)
