"""PAS on a *learned* denoiser (the paper's actual setting, miniaturised):
train a tiny EDM MLP denoiser on GMM data, then PAS-correct its DDIM sampler.

Validates that PAS gains transfer from the analytic oracle to a trained
eps_theta with approximation error (the paper's real-world claim)."""
import jax
import jax.numpy as jnp

from repro.core import pas, schedules, solvers
from repro.diffusion import (EDMConfig, edm_loss, eps_from_denoiser, init_denoiser,
                             precondition, raw_apply)
from repro.optim import AdamW

from . import common


def train_denoiser(gmm, steps: int = 400, batch: int = 256, width: int = 128):
    edm_cfg = EDMConfig(sigma_data=jnp.std(
        gmm.sample_data(jax.random.key(11), 2048)).item())
    params = init_denoiser(jax.random.key(0), common.DIM, width=width, depth=3)
    opt = AdamW(lr=2e-3, weight_decay=0.0, clip_norm=1.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, key):
        k1, k2 = jax.random.split(key)
        x0 = gmm.sample_data(k1, batch)

        def loss_fn(p):
            den = precondition(lambda x, c: raw_apply(p, x, c), edm_cfg)
            return edm_loss(den, k2, x0, edm_cfg)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    key = jax.random.key(1)
    for i in range(steps):
        key, sub = jax.random.split(key)
        params, opt_state, loss = step(params, opt_state, sub)
    den = precondition(lambda x, c: raw_apply(params, x, c), edm_cfg)
    return eps_from_denoiser(den), float(loss)


def run(nfe: int = 10) -> list[dict]:
    gmm = common.oracle()
    eps_fn, train_loss = train_denoiser(gmm)

    s_ts, t_ts, m = schedules.nested_teacher_schedule(
        nfe, common.TEACHER_NFE, common.T_MIN, common.T_MAX)
    x_c = gmm.sample_prior(jax.random.key(0), common.N_CALIB, common.T_MAX)
    gt_c = solvers.ground_truth_trajectory(eps_fn, s_ts, t_ts, m, x_c)
    x_e = gmm.sample_prior(jax.random.key(99), common.N_EVAL, common.T_MAX)
    gt_e = solvers.ground_truth_trajectory(eps_fn, s_ts, t_ts, m, x_e)

    cfg = common.default_pas_cfg()
    sol = solvers.make_solver("ddim", s_ts)
    params, diag = pas.calibrate(sol, eps_fn, x_c, gt_c, cfg)
    x_plain = solvers.sample(sol, eps_fn, x_e)
    x_pas, _ = pas.pas_sample_trajectory(sol, eps_fn, x_e, params, cfg)

    rows = [{
        "model": "learned-mlp-edm", "nfe": nfe, "edm_train_loss": train_loss,
        "err_plain": common.final_err(x_plain, gt_e[-1]),
        "err_pas": common.final_err(x_pas, gt_e[-1]),
        "corrected_steps": params.corrected_paper_steps(),
        "n_stored_params": params.n_stored_params,
    }]
    common.save_table("learned_denoiser", rows)
    assert rows[0]["err_pas"] < rows[0]["err_plain"] * 0.7, rows
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
