"""PAS on a *learned* denoiser (the paper's actual setting, miniaturised):
train a tiny EDM MLP denoiser on GMM data, then PAS-correct its DDIM sampler.

Validates that PAS gains transfer from the analytic oracle to a trained
eps_theta with approximation error (the paper's real-world claim)."""
import jax
import jax.numpy as jnp

from repro.diffusion import (EDMConfig, edm_loss, eps_from_denoiser, init_denoiser,
                             precondition, raw_apply)
from repro.optim import AdamW

from . import common


def train_denoiser(gmm, steps: int = 400, batch: int = 256, width: int = 128):
    edm_cfg = EDMConfig(sigma_data=jnp.std(
        gmm.sample_data(jax.random.key(11), 2048)).item())
    params = init_denoiser(jax.random.key(0), common.DIM, width=width, depth=3)
    opt = AdamW(lr=2e-3, weight_decay=0.0, clip_norm=1.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, key):
        k1, k2 = jax.random.split(key)
        x0 = gmm.sample_data(k1, batch)

        def loss_fn(p):
            den = precondition(lambda x, c: raw_apply(p, x, c), edm_cfg)
            return edm_loss(den, k2, x0, edm_cfg)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    key = jax.random.key(1)
    for i in range(steps):
        key, sub = jax.random.split(key)
        params, opt_state, loss = step(params, opt_state, sub)
    den = precondition(lambda x, c: raw_apply(params, x, c), edm_cfg)
    return eps_from_denoiser(den), float(loss)


def run(nfe: int = 10) -> list[dict]:
    gmm = common.oracle()
    eps_fn, train_loss = train_denoiser(gmm)

    _, (x_c, gt_c), (x_e, gt_e) = common.calib_eval_sets(gmm, nfe,
                                                         eps_fn=eps_fn)
    pipe = common.pipeline_for(eps_fn, "ddim", nfe)
    pipe.calibrate(x_t=x_c, gt=gt_c)
    x_plain = pipe.sample(x_e, use_pas=False)
    x_pas, _ = pipe.trajectory(x_e)

    rows = [{
        "model": "learned-mlp-edm", "nfe": nfe, "edm_train_loss": train_loss,
        "err_plain": common.final_err(x_plain, gt_e[-1]),
        "err_pas": common.final_err(x_pas, gt_e[-1]),
        "corrected_steps": pipe.params.corrected_paper_steps(),
        "n_stored_params": pipe.params.n_stored_params,
    }]
    common.save_table("learned_denoiser", rows)
    assert rows[0]["err_pas"] < rows[0]["err_plain"] * 0.7, rows
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
