"""Engine throughput: fused SamplingEngine vs the seed sampling path.

Measures end-to-end samples/sec for a full PAS-corrected trajectory at batch
{1, 16, 128}, comparing:

* ``seed``   — the pre-engine path exactly as the serve loop dispatched it:
  ``solvers.sample`` (plain) / ``pas.pas_sample_trajectory`` (corrected),
  re-traced on every call (kept as the measured baseline — the one sampling
  construction that intentionally does NOT go through repro.api);
* ``engine`` — the ``repro.api`` Pipeline: one cached jitted scan with the
  fused step kernel and the PAS projection folded in.

  PYTHONPATH=src python -m benchmarks.engine_throughput [--dry-run]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pas, solvers

from . import common

NFE = 10
SOLVER = "ipndm3"


def _throughput(fn, x, n_rep: int) -> float:
    """Samples/sec over n_rep calls (first call compiles and is excluded)."""
    jax.block_until_ready(fn(x))
    t0 = time.time()
    for _ in range(n_rep):
        out = fn(x)
    jax.block_until_ready(out)
    return x.shape[0] * n_rep / (time.time() - t0)


def _synthetic_params(n: int) -> pas.PASParams:
    """A realistic correction pattern (2 active steps) without calibration."""
    active = np.zeros(n, dtype=bool)
    active[[1, 3]] = True
    coords = np.zeros((n, 4), np.float32)
    coords[1] = [1.0, 0.05, 0.0, 0.0]
    coords[3] = [0.98, -0.04, 0.0, 0.0]
    return pas.PASParams(active=active, coords=jnp.asarray(coords))


def run(dry_run: bool = False) -> list[dict]:
    gmm = common.oracle()
    pipe = common.pipeline_for(gmm.eps, SOLVER, NFE)
    sol = pipe.solver                       # the seed path's bound solver
    params = _synthetic_params(NFE)
    cfg = pipe.spec.pas

    batches = (1, 16) if dry_run else (1, 16, 128)
    n_rep = 3 if dry_run else 10
    rows = []
    for b in batches:
        x = gmm.sample_prior(jax.random.key(0), b, common.T_MAX)
        pairs = {
            "plain": (
                lambda x: solvers.sample(sol, gmm.eps, x),
                lambda x: pipe.sample(x, use_pas=False),
            ),
            "pas": (
                lambda x: pas.pas_sample_trajectory(
                    sol, gmm.eps, x, params, cfg)[0],
                lambda x: pipe.set_params(params).sample(x),
            ),
        }
        for mode, (seed_fn, engine_fn) in pairs.items():
            sps_seed = _throughput(seed_fn, x, n_rep)
            sps_engine = _throughput(engine_fn, x, n_rep)
            rows.append({
                "mode": mode, "batch": b, "solver": SOLVER, "nfe": NFE,
                "seed_samples_per_s": round(sps_seed, 1),
                "engine_samples_per_s": round(sps_engine, 1),
                "speedup": round(sps_engine / max(sps_seed, 1e-9), 2),
            })
    if not dry_run:
        common.save_table("engine_throughput", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="small batch set + few repeats (CI smoke)")
    args = ap.parse_args()
    for r in run(dry_run=args.dry_run):
        print(r)
