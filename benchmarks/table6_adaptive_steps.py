"""Paper Tables 1+6: the time points adaptive search selects for correction.

Expected paper-faithful structure: DDIM (large truncation error) corrects
more/mid-trajectory steps; iPNDM corrects fewer; counts stay ~1-5 so stored
params stay ~4-20 ("approximately 10 parameters").
"""
from . import common


def run(nfes=(5, 6, 8, 10)) -> list[dict]:
    gmm = common.oracle()
    rows = []
    for solver_name, tol in (("ddim", 1e-2), ("ipndm3", 1e-4)):
        cfg = common.default_pas_cfg(tolerance=tol)
        for nfe in nfes:
            r = common.run_pas(solver_name, nfe, gmm, cfg)
            rows.append({
                "method": f"{solver_name}+PAS", "nfe": nfe,
                "corrected_paper_steps": r["corrected_steps"],
                "n_corrected": len(r["corrected_steps"]),
                "n_stored_params": r["n_stored_params"],
            })
    common.save_table("table6_adaptive_steps", rows)
    ddim_counts = [r["n_corrected"] for r in rows if r["method"] == "ddim+PAS"]
    ip_counts = [r["n_corrected"] for r in rows if r["method"] == "ipndm3+PAS"]
    assert all(1 <= c <= 6 for c in ddim_counts), ddim_counts
    assert sum(ip_counts) <= sum(ddim_counts), (ip_counts, ddim_counts)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
