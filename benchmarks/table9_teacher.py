"""Paper Table 9: the solver used for ground-truth trajectories barely
matters (any ~100-NFE solve approximates the true trajectory well)."""
import jax

from . import common


def run(nfe: int = 10) -> list[dict]:
    gmm = common.oracle()
    cfg = common.default_pas_cfg()
    rows = []
    # eval gt always from heun; hoisted — only the calibration teacher is swept
    _, _, (x_e, gt_e) = common.calib_eval_sets(gmm, nfe, teacher="heun")
    x_c = gmm.sample_prior(jax.random.key(0), common.N_CALIB, common.T_MAX)
    for teacher in ("heun", "euler", "dpm2"):
        pipe = common.pipeline_for(gmm.eps, "ddim", nfe, teacher=teacher,
                                   pas_cfg=cfg)
        gt_c = pipe.teacher_trajectory(x_c)     # swept-teacher calibration gt
        err_plain = common.final_err(pipe.sample(x_e, use_pas=False),
                                     gt_e[-1])
        pipe.calibrate(x_t=x_c, gt=gt_c)
        x0, _ = pipe.trajectory(x_e)
        rows.append({"teacher": teacher, "nfe": nfe,
                     "err_plain": err_plain,
                     "err_pas": common.final_err(x0, gt_e[-1]),
                     "corrected_steps": pipe.params.corrected_paper_steps()})
    common.save_table("table9_teacher", rows)
    # paper Table 9: every ~100-NFE teacher yields a large PAS gain; the
    # second-order teachers (heun/dpm2) agree closely, euler slightly behind
    for r in rows:
        assert r["err_pas"] < 0.3 * r["err_plain"], r
    errs = {r["teacher"]: r["err_pas"] for r in rows}
    assert abs(errs["heun"] - errs["dpm2"]) < 0.3 * errs["heun"], errs
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
