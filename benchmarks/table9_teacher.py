"""Paper Table 9: the solver used for ground-truth trajectories barely
matters (any ~100-NFE solve approximates the true trajectory well)."""
import jax

from repro.core import pas, schedules, solvers

from . import common


def run(nfe: int = 10) -> list[dict]:
    gmm = common.oracle()
    cfg = common.default_pas_cfg()
    rows = []
    for teacher in ("heun", "euler", "dpm2"):
        s_ts, t_ts, m = schedules.nested_teacher_schedule(
            nfe, common.TEACHER_NFE, common.T_MIN, common.T_MAX)
        x_c = gmm.sample_prior(jax.random.key(0), common.N_CALIB, common.T_MAX)
        gt_c = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_c,
                                               teacher=teacher)
        x_e = gmm.sample_prior(jax.random.key(99), common.N_EVAL, common.T_MAX)
        gt_e = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_e,
                                               teacher="heun")
        sol = solvers.make_solver("ddim", s_ts)
        err_plain = common.final_err(solvers.sample(sol, gmm.eps, x_e),
                                     gt_e[-1])
        params, _ = pas.calibrate(sol, gmm.eps, x_c, gt_c, cfg)
        x0, _ = pas.pas_sample_trajectory(sol, gmm.eps, x_e, params, cfg)
        rows.append({"teacher": teacher, "nfe": nfe,
                     "err_plain": err_plain,
                     "err_pas": common.final_err(x0, gt_e[-1]),
                     "corrected_steps": params.corrected_paper_steps()})
    common.save_table("table9_teacher", rows)
    # paper Table 9: every ~100-NFE teacher yields a large PAS gain; the
    # second-order teachers (heun/dpm2) agree closely, euler slightly behind
    for r in rows:
        assert r["err_pas"] < 0.3 * r["err_plain"], r
    errs = {r["teacher"]: r["err_pas"] for r in rows}
    assert abs(errs["heun"] - errs["dpm2"]) < 0.3 * errs["heun"], errs
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
