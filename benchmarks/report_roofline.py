"""Render §Dry-run / §Roofline markdown tables from dryrun JSON artifacts.

  PYTHONPATH=src python -m benchmarks.report_roofline [--mesh single] [--tag X]
"""
import argparse
import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load(mesh: str, tag: str = ""):
    rows = []
    for f in sorted(glob.glob(str(ART / "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        rows.append(r)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return rows


def roofline_table(mesh: str, tag: str = "") -> str:
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "bound step | model/impl FLOPs | mem/dev (CPU-meas) | fits 16G TPU |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in load(mesh, tag):
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — "
                       f"| {r['reason'][:48]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR {r['error'][:40]} |")
            continue
        rf = r["roofline"]
        mem = r["memory_per_device_bytes"]["total_live"] / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} "
            f"| {fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} "
            f"| **{rf['dominant']}** | {fmt_s(rf['bound_step_s'])} "
            f"| {rf['model_flops_ratio']:.2f} | {mem:.1f} GiB "
            f"| {'yes' if r.get('fits_16g_tpu') else 'NO'} |")
    return "\n".join(out)


def dryrun_table(mesh: str, tag: str = "") -> str:
    out = ["| arch | shape | status | compile s | args/dev | temps/dev | "
           "HLO colls (loop-aware) | HLO flops/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in load(mesh, tag):
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | | | | | |")
            continue
        m = r["memory_per_device_bytes"]
        colls = r.get("collectives", {})
        cstr = " ".join(f"{k.split('-')[0]}:{v['count']}x{v['bytes']/2**20:.0f}M"
                        for k, v in colls.items() if v["count"])
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['seconds']['compile']} "
            f"| {m['arguments']/2**30:.2f}G | {m['temps']/2**30:.2f}G "
            f"| {cstr or '—'} | {r['cost'].get('flops', 0):.2e} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun", "both"])
    args = ap.parse_args()
    if args.table in ("roofline", "both"):
        print(roofline_table(args.mesh, args.tag))
    if args.table in ("dryrun", "both"):
        print()
        print(dryrun_table(args.mesh, args.tag))


if __name__ == "__main__":
    main()
