"""Paper Table 8: tolerance tau ablation — PAS is insensitive for tau in
[1e-4, 1e-2]; a huge tau disables correction entirely (DDIM row equality)."""
from . import common


def run(nfe: int = 10) -> list[dict]:
    gmm = common.oracle()
    rows = []
    for tau in (1e9, 1e-1, 1e-2, 1e-3, 1e-4):
        cfg = common.default_pas_cfg(tolerance=tau)
        r = common.run_pas("ddim", nfe, gmm, cfg)
        rows.append({"method": "ddim+PAS", "tau": tau, "nfe": nfe,
                     "err_plain": r["err_plain"], "err_pas": r["err_pas"],
                     "corrected_steps": r["corrected_steps"]})
    common.save_table("table8_tolerance", rows)
    huge = rows[0]
    assert huge["corrected_steps"] == []           # tau huge -> no-op
    assert abs(huge["err_pas"] - huge["err_plain"]) < 1e-4
    small = [r for r in rows if r["tau"] <= 1e-2]
    errs = [r["err_pas"] for r in small]
    assert max(errs) < 0.6 * huge["err_plain"]     # insensitive and effective
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
