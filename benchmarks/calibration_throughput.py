"""Calibration throughput: fused CalibrationEngine vs the legacy loop.

The ISSUE-4 acceptance metric: at NFE=10, batch 256, fused calibration must
beat the legacy path by >= 5x steady-state wall-clock.  Calibration here is
what ``Pipeline.calibrate`` actually executes — paper Algorithm 1 *including*
the nested teacher trajectory it trains against (§3.3):

* ``legacy`` — the per-step reference loop (``pas.calibrate_reference``:
  eager eps/basis dispatch, per-step jitted SGD, host-synced adoption) fed
  by the eager teacher builder (``solvers.ground_truth_trajectory``);
* ``fused``  — ``repro.engine.CalibrationEngine``: the teacher as one jitted
  refinement scan and the whole of Algorithm 1 (eps evals, PCA bases, SGD
  scans, on-device lax.cond adoption, compiled final-state gate) as one
  cached program.

Timings separate cold (first call: trace + compile) from warm (steady state,
averaged over repeats): the fused program front-loads one large compile,
which repeated calibrations — artifact refresh, solver/NFE sweeps like
benchmarks/table5, serve fleets recalibrating per model drop — amortise
away.  Phase breakdown (teacher / algorithm1 / end_to_end) and both columns
land in root-level ``BENCH_calibration_fusion.json`` so the perf trajectory
is recorded PR over PR.

``--cache-dir`` activates the persistent compile cache
(``repro.engine.compile_cache``): run the bench twice against one
directory and the second run's "cold" column is a *warm-cache* cold start
— the XLA disk cache restores the fused program's compilation, which is
exactly the serve-fleet relaunch case.  The JSON records the cache state
(``compile_cache.state``: none/cold/warm, from the hit counters) so the
``speedup_cold`` row is never read out of context.

  PYTHONPATH=src python -m benchmarks.calibration_throughput \
      [--batch 256] [--n-rep 5] [--cache-dir DIR] [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

from repro.core import pas, solvers
from repro.engine import compile_cache, get_calibration_engine_for_spec

from . import common

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_calibration_fusion.json"

NFE = 10
SOLVER = "ddim"


def _timed(fn, n_rep: int) -> tuple[float, float]:
    """(cold, warm) seconds: first call separately, then the mean of n_rep."""
    t0 = time.time()
    jax.block_until_ready(fn())
    cold = time.time() - t0
    t0 = time.time()
    for _ in range(n_rep):
        out = fn()
    jax.block_until_ready(out)
    return cold, (time.time() - t0) / n_rep


def run(batch: int = 256, n_rep: int = 5, dry_run: bool = False,
        cache_dir: str | None = None) -> dict:
    nfe, sgd_iters = (6, 40) if dry_run else (NFE, 300)
    if dry_run:
        batch, n_rep = 32, 2
    if cache_dir:
        compile_cache.configure(cache_dir)
        compile_cache.reset_cache_stats()

    gmm = common.oracle()
    cfg = common.default_pas_cfg(n_sgd_iters=sgd_iters)
    spec = common.spec_for(SOLVER, nfe, pas_cfg=cfg)
    sol = spec.make_solver()
    s_ts, t_ts, m = spec.teacher_grid()
    tsol = spec.make_teacher(t_ts)
    x_t = gmm.sample_prior(jax.random.key(0), batch, common.T_MAX)
    jax.block_until_ready(x_t)
    eng = get_calibration_engine_for_spec(spec)

    phases = {}

    # phase 1: the nested teacher trajectory (gt both arms train against)
    def legacy_teacher():
        return solvers.ground_truth_trajectory(
            gmm.eps, s_ts, t_ts, m, x_t, teacher=tsol)

    phases["teacher"] = {
        "legacy": _timed(legacy_teacher, n_rep),
        "fused": _timed(lambda: eng.teacher_trajectory(gmm.eps, x_t), n_rep),
    }
    gt = eng.teacher_trajectory(gmm.eps, x_t)
    jax.block_until_ready(gt)

    # phase 2: Algorithm 1 proper, on a fixed precomputed gt
    phases["algorithm1"] = {
        "legacy": _timed(
            lambda: pas.calibrate_reference(sol, gmm.eps, x_t, gt, cfg)[0].coords,
            n_rep),
        "fused": _timed(
            lambda: eng.calibrate(gmm.eps, x_t, gt)[0].coords, n_rep),
    }

    def row(arm):
        teach, alg = phases["teacher"][arm], phases["algorithm1"][arm]
        cold, warm = teach[0] + alg[0], teach[1] + alg[1]
        return {
            "teacher_warm_s": round(teach[1], 3),
            "algorithm1_warm_s": round(alg[1], 3),
            "cold_s": round(cold, 3), "warm_s": round(warm, 3),
            "steps_per_s": round(nfe / warm, 2),
        }

    legacy, fused = row("legacy"), row("fused")
    report = {
        "solver": SOLVER, "nfe": nfe, "batch": batch, "dim": common.DIM,
        "n_sgd_iters": sgd_iters, "n_rep": n_rep,
        "backend": jax.default_backend(),
        "legacy": legacy,
        "fused": fused,
        "speedup_warm": round(legacy["warm_s"] / fused["warm_s"], 2),
        "speedup_warm_algorithm1_only": round(
            phases["algorithm1"]["legacy"][1]
            / phases["algorithm1"]["fused"][1], 2),
        "speedup_cold": round(legacy["cold_s"] / fused["cold_s"], 2),
        "compile_cache": _cache_state(cache_dir),
        "generated": time.strftime("%F %T"),
    }
    if not dry_run:               # smoke runs don't pollute the perf record
        OUT.write_text(json.dumps(report, indent=1))
        common.save_table("calibration_throughput", [report])
    return report


def _cache_state(cache_dir: str | None) -> dict:
    """Honest cache provenance for the JSON: none / cold / warm, with the
    hit counters backing the claim (hits > 0 means the 'cold' column paid
    cache restores, not full compiles)."""
    if not cache_dir:
        return {"state": "none", "dir": None}
    stats = compile_cache.cache_stats()
    state = "warm" if stats["persistent_hits"] > 0 else "cold"
    return {"state": state, "dir": str(cache_dir),
            "persistent_hits": stats["persistent_hits"],
            "persistent_misses": stats["persistent_misses"]}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--n-rep", type=int, default=5)
    ap.add_argument("--cache-dir", default=None,
                    help="persistent compile cache dir; run twice against "
                         "one dir for a warm-cache cold column")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny config, no JSON written (CI smoke)")
    args = ap.parse_args()
    rep = run(batch=args.batch, n_rep=args.n_rep, dry_run=args.dry_run,
              cache_dir=args.cache_dir)
    print(json.dumps(rep, indent=1))
    print(f"CALIBRATION_SPEEDUP_WARM={rep['speedup_warm']}x")
