"""Insert the generated roofline table into EXPERIMENTS.md (idempotent)."""
import re
from pathlib import Path

from .report_roofline import roofline_table

ROOT = Path(__file__).resolve().parent.parent


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    table = roofline_table("single")
    marker = "<!-- ROOFLINE_TABLE -->"
    block = f"{marker}\n{table}\n<!-- /ROOFLINE_TABLE -->"
    if "<!-- /ROOFLINE_TABLE -->" in md:
        md = re.sub(r"<!-- ROOFLINE_TABLE -->.*?<!-- /ROOFLINE_TABLE -->",
                    block, md, flags=re.S)
    else:
        md = md.replace(marker, block)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md roofline table updated "
          f"({table.count(chr(10)) + 1} lines)")


if __name__ == "__main__":
    main()
