"""Paper Table 2 (proxy): solver quality across the zoo of fast solvers, with
and without PAS and TP, NFE in {5, 6, 8, 10}.

Offline proxy metric: mean L2 distance of the final state to the 100-NFE
teacher endpoint on held-out trajectories (paper Table 11's auxiliary metric;
FID needs the Inception network + 50k real images — unavailable offline).
The paper-faithful ordering to reproduce: DDIM (worst) >> DDIM+PAS;
iPNDM > iPNDM+PAS (small); +TP improves both; TP+PAS best.
"""
import jax

from repro.api import Pipeline
from repro.core import teleport

from . import common


def _tp_eval(gmm, solver_name, nfe, with_pas, cfg):
    """DDIM+TP(+PAS): teleport to sigma_skip=10 then solve with full budget."""
    data = gmm.sample_data(jax.random.key(5), 4096)
    stats = teleport.gaussian_stats_from_data(data)

    _, (x_c, _), (x_e, gt_e) = common.calib_eval_sets(gmm, nfe)
    x_c_skip = teleport.teleport(stats, x_c, common.T_MAX, 10.0)
    x_e_skip = teleport.teleport(stats, x_e, common.T_MAX, 10.0)

    # post-teleport spec: the full NFE budget on [t_min, sigma_skip]
    spec = common.spec_for(solver_name, nfe, t_max=10.0, pas_cfg=cfg)
    pipe = Pipeline.from_spec(spec, gmm.eps, dim=common.DIM)
    if with_pas:
        pipe.calibrate(x_t=x_c_skip)   # teacher runs on the post-TP schedule
    x0 = pipe.sample(x_e_skip, use_pas=with_pas)
    return common.final_err(x0, gt_e[-1])


def run(nfes=(5, 6, 8, 10)) -> list[dict]:
    gmm = common.oracle()
    cfg = common.default_pas_cfg()
    rows = []
    for nfe in nfes:
        _, _, (x_e, gt_e) = common.calib_eval_sets(gmm, nfe)
        # training-free baselines (each spec binding is engine-cached)
        for name in ("ddim", "dpmpp2m", "deis3", "ipndm3", "ipndm2"):
            pipe = common.pipeline_for(gmm.eps, name, nfe)
            rows.append({"method": name, "nfe": nfe,
                         "err_l2": common.final_err(
                             pipe.sample(x_e), gt_e[-1])})
        # 2-eval solvers at matched NFE budget
        if nfe % 2 == 0:
            for name in ("heun", "dpm2"):
                pipe = common.pipeline_for(gmm.eps, name, nfe // 2)
                rows.append({"method": name, "nfe": nfe,
                             "err_l2": common.final_err(
                                 pipe.sample(x_e), gt_e[-1])})
        # PAS-corrected
        for name in ("ddim", "ipndm3"):
            r = common.run_pas(name, nfe, gmm, cfg)
            rows.append({"method": f"{name}+PAS", "nfe": nfe,
                         "err_l2": r["err_pas"],
                         "corrected_steps": r["corrected_steps"],
                         "n_stored_params": r["n_stored_params"],
                         "calib_seconds": r["calib_seconds"]})
        # TP and TP+PAS (paper's strongest rows)
        rows.append({"method": "ddim+TP", "nfe": nfe,
                     "err_l2": _tp_eval(gmm, "ddim", nfe, False, cfg)})
        rows.append({"method": "ddim+TP+PAS", "nfe": nfe,
                     "err_l2": _tp_eval(gmm, "ddim", nfe, True, cfg)})
    common.save_table("table2_solvers", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
