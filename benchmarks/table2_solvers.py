"""Paper Table 2 (proxy): solver quality across the zoo of fast solvers, with
and without PAS and TP, NFE in {5, 6, 8, 10}.

Offline proxy metric: mean L2 distance of the final state to the 100-NFE
teacher endpoint on held-out trajectories (paper Table 11's auxiliary metric;
FID needs the Inception network + 50k real images — unavailable offline).
The paper-faithful ordering to reproduce: DDIM (worst) >> DDIM+PAS;
iPNDM > iPNDM+PAS (small); +TP improves both; TP+PAS best.
"""
import jax

from repro.core import pas, solvers, teleport
from repro.engine import engine_for_solver, get_engine

from . import common


def _tp_eval(gmm, solver_name, nfe, with_pas, cfg):
    """DDIM+TP(+PAS): teleport to sigma_skip=10 then solve with full budget."""
    data = gmm.sample_data(jax.random.key(5), 4096)
    stats = teleport.gaussian_stats_from_data(data)
    tp_ts = teleport.tp_schedule(nfe, sigma_skip=10.0, t_min=common.T_MIN)
    sol = solvers.make_solver(solver_name, tp_ts)

    s_ts, (x_c, gt_c), (x_e, gt_e) = common.calib_eval_sets(gmm, nfe)
    x_c_skip = teleport.teleport(stats, x_c, common.T_MAX, 10.0)
    x_e_skip = teleport.teleport(stats, x_e, common.T_MAX, 10.0)

    engine = engine_for_solver(sol)
    if with_pas:
        # teacher trajectory along the post-TP schedule
        from repro.core import schedules
        s2, t_ts2, m2 = schedules.nested_teacher_schedule(
            nfe, common.TEACHER_NFE, common.T_MIN, 10.0)
        gt_c2 = solvers.ground_truth_trajectory(gmm.eps, s2, t_ts2, m2, x_c_skip)
        params, _ = pas.calibrate(sol, gmm.eps, x_c_skip, gt_c2, cfg)
        x0 = engine.sample(gmm.eps, x_e_skip, params=params, cfg=cfg)
    else:
        x0 = engine.sample(gmm.eps, x_e_skip)
    return common.final_err(x0, gt_e[-1])


def run(nfes=(5, 6, 8, 10)) -> list[dict]:
    gmm = common.oracle()
    cfg = common.default_pas_cfg()
    rows = []
    for nfe in nfes:
        s_ts, _, (x_e, gt_e) = common.calib_eval_sets(gmm, nfe)
        # training-free baselines (each engine binding is cached by schedule)
        for name in ("ddim", "dpmpp2m", "deis3", "ipndm3", "ipndm2"):
            engine = get_engine(name, s_ts)
            rows.append({"method": name, "nfe": nfe,
                         "err_l2": common.final_err(
                             engine.sample(gmm.eps, x_e), gt_e[-1])})
        # 2-eval solvers at matched NFE budget
        if nfe % 2 == 0:
            from repro.core import schedules
            half = schedules.polynomial_schedule(nfe // 2, common.T_MIN,
                                                 common.T_MAX)
            for name in ("heun", "dpm2"):
                engine = get_engine(name, half)
                rows.append({"method": name, "nfe": nfe,
                             "err_l2": common.final_err(
                                 engine.sample(gmm.eps, x_e), gt_e[-1])})
        # PAS-corrected
        for name in ("ddim", "ipndm3"):
            r = common.run_pas(name, nfe, gmm, cfg)
            rows.append({"method": f"{name}+PAS", "nfe": nfe,
                         "err_l2": r["err_pas"],
                         "corrected_steps": r["corrected_steps"],
                         "n_stored_params": r["n_stored_params"],
                         "calib_seconds": r["calib_seconds"]})
        # TP and TP+PAS (paper's strongest rows)
        rows.append({"method": "ddim+TP", "nfe": nfe,
                     "err_l2": _tp_eval(gmm, "ddim", nfe, False, cfg)})
        rows.append({"method": "ddim+TP+PAS", "nfe": nfe,
                     "err_l2": _tp_eval(gmm, "ddim", nfe, True, cfg)})
    common.save_table("table2_solvers", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
