"""Sharded sampling throughput: samples/sec vs device count.

Each device count runs in its own subprocess (jax locks the host device
table at first init) with ``XLA_FLAGS=--xla_force_host_platform_device_count
=N``: a ``MeshSpec(dp=N)`` pipeline serves one large flush, plain and
PAS-corrected, through the mesh-native engine.  The aggregate lands in a
root-level ``BENCH_sharded_throughput.json`` so the perf trajectory of the
sharded path is recorded PR over PR.

On this CPU-only container the virtual devices all share the same cores, so
absolute numbers measure partitioning overhead rather than real scaling —
the JSON records ``backend`` so TPU runs are distinguishable.

  PYTHONPATH=src python -m benchmarks.sharded_throughput \
      [--devices 1,2,8] [--batch 256] [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_sharded_throughput.json"

_WORKER = r"""
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from repro.api import MeshSpec, Pipeline, SamplerSpec
from repro.core import two_mode_gmm
from repro.core.pas import PASParams

n_dev, batch, n_rep, dim, nfe, solver = (
    int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]), sys.argv[6])
assert len(jax.devices()) >= n_dev
gmm = two_mode_gmm(dim, sep=6.0, var=0.25)
spec = SamplerSpec(solver=solver, nfe=nfe, mesh=MeshSpec(dp=n_dev))
pipe = Pipeline.from_spec(spec, gmm.eps, dim=dim)

active = np.zeros(nfe, bool); active[[1, 3]] = True
coords = np.zeros((nfe, 4), np.float32)
coords[1] = [1.0, 0.05, 0.0, 0.0]; coords[3] = [0.98, -0.04, 0.0, 0.0]
pipe.set_params(PASParams(active=active, coords=jnp.asarray(coords)))

x = pipe.prior(jax.random.key(0), batch)
rows = []
sps_by_mode = {}
for mode, use_pas in (("plain", False), ("pas", True)):
    # timing discipline (regression: a dp=2 plain row once recorded ~300k
    # samples/s, ~10x the dp=1/dp=8 rows — async dispatch measured without a
    # per-call device sync): one compile call, two warmup calls to reach
    # steady state, then every repeat individually bracketed by
    # block_until_ready and the *minimum* repeat taken, so a row can never
    # report faster than the device actually ran a full sampling pass
    jax.block_until_ready(pipe.sample(x, use_pas=use_pas))   # compile
    for _ in range(2):
        jax.block_until_ready(pipe.sample(x, use_pas=use_pas))
    times = []
    for _ in range(n_rep):
        t0 = time.perf_counter()
        jax.block_until_ready(pipe.sample(x, use_pas=use_pas))
        times.append(time.perf_counter() - t0)
    sps = batch / min(times)
    sps_by_mode[mode] = sps
    rows.append({"devices": n_dev, "mode": mode, "batch": batch,
                 "solver": solver, "nfe": nfe,
                 "samples_per_s": round(sps, 1),
                 "reps": n_rep, "timing": "min-over-reps, per-call sync"})
# cost of turning correction on at this device count; the fused-basis
# acceptance metric is this ratio staying flat (or shrinking) in n_dev
ratio = sps_by_mode["plain"] / sps_by_mode["pas"]
for row in rows:
    row["pas_overhead_ratio"] = round(ratio, 3)
print("ROWS_JSON:" + json.dumps(rows))
"""


def run(device_counts=(1, 2, 8), batch: int = 256, n_rep: int = 10,
        dim: int = 64, nfe: int = 10, solver: str = "ipndm3",
        dry_run: bool = False) -> list[dict]:
    if dry_run:
        # smoke: shrink the workload but honour the caller's device list
        # (CI runs --dry-run --devices 1,8 to exercise the 8-way mesh)
        batch, n_rep = min(batch, 64), 3
    rows: list[dict] = []
    for n_dev in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env.setdefault("PYTHONPATH", str(ROOT / "src"))
        out = subprocess.run(
            [sys.executable, "-c", _WORKER, str(n_dev), str(batch),
             str(n_rep), str(dim), str(nfe), solver],
            capture_output=True, text=True, env=env, timeout=900)
        if out.returncode != 0:
            raise RuntimeError(
                f"worker for {n_dev} device(s) failed:\n{out.stderr[-2000:]}")
        payload = next(line for line in out.stdout.splitlines()
                       if line.startswith("ROWS_JSON:"))
        rows.extend(json.loads(payload[len("ROWS_JSON:"):]))

    if not dry_run:                # smoke runs don't pollute the perf record
        import jax
        report = {
            "rows": rows,
            "backend": jax.default_backend(),
            "device_counts": list(device_counts),
            "generated": time.strftime("%F %T"),
        }
        OUT.write_text(json.dumps(report, indent=1))
        from . import common
        common.save_table("sharded_throughput", rows,
                          extra={"backend": report["backend"]})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", default=None,
                    help="comma list of virtual device counts "
                         "(default 1,2,8; dry-run default 1,2)")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--dry-run", action="store_true",
                    help="small batch + 3 reps, no JSON write (CI smoke)")
    args = ap.parse_args()
    default_counts = "1,2" if args.dry_run else "1,2,8"
    counts = tuple(int(c) for c in (args.devices or default_counts).split(","))
    for r in run(device_counts=counts, batch=args.batch,
                 dry_run=args.dry_run):
        print(r)
