"""Fleet cold start: calibration + first-request wall time, cold vs warm cache.

The ISSUE-9 acceptance metric: with a populated persistent compile cache
(``repro.engine.compile_cache``), a fresh process must reach "calibrated and
serving" at >= 2x lower calibration wall time than the same process with no
cache.  The probe is the full fleet launch path — an ``NFELadder`` of three
PAS rungs over the GMM oracle, routed through ``PipelineRouter``:

* ``calibrate``   — ``NFELadder.calibrate`` end-to-end (teacher scans,
  Algorithm-1 programs, final gates; every compile lands inside the timer);
* ``precompile``  — ``NFELadder.precompile``: AOT-warm each lane's exact
  flush variant before the queue admits traffic;
* ``first requests`` — one budget-filling request per lane, timed
  submit -> result (the latency the first real user sees).

Each arm runs in a *fresh subprocess* (a warm in-process jit cache would
fake the numbers): ``nocache`` (no cache dir), ``cold_cache`` (empty cache
dir — pays the compiles AND populates the cache), ``warm_cache`` (same dir
again — the restart we are optimising).  Results land in root-level
``BENCH_cold_start.json`` with the per-arm persistent-cache counters so the
speedup is auditable (cache hits, compile seconds).

  PYTHONPATH=src python -m benchmarks.cold_start [--cache-dir DIR]

``--dry-run`` is the CI smoke: one tiny in-process probe against
``--cache-dir``, appending to ``<dir>/probe_history.jsonl``; a second
invocation with ``--expect-cache-hits`` asserts the cache actually hit and
the wall time dropped (two processes sharing one cache dir = a real
restart, no BENCH json written).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import jax

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_cold_start.json"
HISTORY = "probe_history.jsonl"

NFES = (4, 6, 8)


def probe(cache_dir: str | None, *, nfes=NFES, teacher_nfe: int = 40,
          calib_batch: int = 128, sgd_iters: int = 100,
          max_batch: int = 16) -> dict:
    """One cold-start measurement inside THIS process (must be fresh)."""
    from repro.engine import compile_cache, engine_cache_stats
    from repro.runtime.ladder import NFELadder
    from repro.runtime.serve_loop import Request, ServeConfig

    from . import common

    if cache_dir:
        compile_cache.configure(cache_dir)
    gmm = common.oracle()
    cfg = common.default_pas_cfg(n_sgd_iters=sgd_iters)
    spec = common.spec_for("ipndm4", nfes[-1], teacher_nfe=teacher_nfe,
                           pas_cfg=cfg)
    ladder = NFELadder(spec, nfes=nfes, teacher_rung=False)
    model_key = f"oracle:gmm:{common.DIM}"

    router = ladder.build_router(
        gmm.eps, common.DIM,
        cfg=ServeConfig(max_batch=max_batch, deadline_ms=50.0))
    try:
        t0 = time.time()
        ladder.calibrate(router, key=jax.random.key(0), batch=calib_batch)
        calibrate_s = time.time() - t0

        t0 = time.time()
        ladder.precompile(router, model_key=model_key)
        precompile_s = time.time() - t0

        # first request per lane, sized to fill the flush budget so the
        # latency is program dispatch, not the partial-flush deadline wait
        first_ms = {}
        for i, lane in enumerate(router.lane_keys):
            t0 = time.time()
            h = router.submit(Request(seed=i, n_samples=max_batch,
                                      pipeline=lane))
            jax.block_until_ready(h.result())
            first_ms[lane] = round((time.time() - t0) * 1e3, 1)
    finally:
        router.close()

    lats = sorted(first_ms.values())
    p95 = lats[min(len(lats) - 1, int(round(0.95 * (len(lats) - 1))))]
    stats = engine_cache_stats()["persistent"]
    return {
        "cache_dir": cache_dir,
        "nfes": list(nfes), "teacher_nfe": teacher_nfe,
        "calib_batch": calib_batch, "sgd_iters": sgd_iters,
        "calibrate_s": round(calibrate_s, 3),
        "precompile_s": round(precompile_s, 3),
        "ready_s": round(calibrate_s + precompile_s, 3),
        "first_request_ms": first_ms,
        "first_request_p95_ms": p95,
        "persistent": stats,
    }


def _spawn_probe(arm: str, cache_dir: str | None, extra: list[str]) -> dict:
    """Run one probe in a fresh interpreter; parse its marker line."""
    cmd = [sys.executable, "-m", "benchmarks.cold_start", "--probe"]
    if cache_dir:
        cmd += ["--cache-dir", cache_dir]
    cmd += extra
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    t0 = time.time()
    res = subprocess.run(cmd, cwd=ROOT, env=env, text=True,
                         capture_output=True)
    wall = time.time() - t0
    if res.returncode != 0:
        sys.stderr.write(res.stdout + res.stderr)
        raise RuntimeError(f"{arm} probe failed (exit {res.returncode})")
    line = next(ln for ln in res.stdout.splitlines()
                if ln.startswith("COLD_START_PROBE_JSON:"))
    rep = json.loads(line.split(":", 1)[1])
    rep["process_wall_s"] = round(wall, 3)
    print(f"  [{arm}] calibrate={rep['calibrate_s']}s "
          f"precompile={rep['precompile_s']}s "
          f"first_req_p95={rep['first_request_p95_ms']}ms "
          f"(process {rep['process_wall_s']}s)")
    return rep


def run(cache_dir: str | None = None) -> dict:
    """Three fresh-process arms; write BENCH_cold_start.json."""
    tmp = None
    if cache_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="pas-cold-start-")
        cache_dir = tmp.name
    try:
        print("cold-start bench: 3-rung ladder "
              f"(nfes={list(NFES)}), cache dir {cache_dir}")
        arms = {
            "nocache": _spawn_probe("nocache", None, []),
            "cold_cache": _spawn_probe("cold_cache", cache_dir, []),
            "warm_cache": _spawn_probe("warm_cache", cache_dir, []),
        }
    finally:
        if tmp is not None:
            tmp.cleanup()

    no, warm = arms["nocache"], arms["warm_cache"]
    report = {
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "arms": arms,
        "speedup_calibrate_warm_vs_nocache": round(
            no["calibrate_s"] / warm["calibrate_s"], 2),
        "speedup_ready_warm_vs_nocache": round(
            no["ready_s"] / warm["ready_s"], 2),
        "first_request_p95_ms": {
            "nocache": no["first_request_p95_ms"],
            "warm_cache": warm["first_request_p95_ms"],
        },
        "warm_persistent_hits": warm["persistent"]["persistent_hits"],
        "generated": time.strftime("%F %T"),
    }
    OUT.write_text(json.dumps(report, indent=1))
    from . import common
    common.save_table("cold_start", [report])
    return report


def dry_run(cache_dir: str, expect_cache_hits: bool) -> dict:
    """CI smoke: tiny in-process probe + history assertion (no BENCH json)."""
    rep = probe(cache_dir, nfes=(3, 4), teacher_nfe=8, calib_batch=16,
                sgd_iters=8, max_batch=8)
    hist_path = Path(cache_dir) / HISTORY
    history = ([json.loads(ln) for ln in
                hist_path.read_text().splitlines() if ln.strip()]
               if hist_path.exists() else [])
    if expect_cache_hits:
        if not history:
            raise SystemExit("--expect-cache-hits: no prior probe in "
                             f"{hist_path}; run once without it first")
        hits = rep["persistent"]["persistent_hits"]
        if hits <= 0:
            raise SystemExit(
                f"--expect-cache-hits: persistent_hits={hits} "
                f"(stats {rep['persistent']})")
        prev = history[0]["calibrate_s"]
        if not rep["calibrate_s"] < prev:
            raise SystemExit(
                f"--expect-cache-hits: warm calibrate {rep['calibrate_s']}s "
                f"not below cold {prev}s")
        print(f"cache hits confirmed: persistent_hits={hits}, "
              f"calibrate {prev}s -> {rep['calibrate_s']}s")
    with hist_path.open("a") as f:
        f.write(json.dumps(rep) + "\n")
    return rep


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-dir", default=None,
                    help="persistent compile cache dir (default: fresh tmp)")
    ap.add_argument("--probe", action="store_true",
                    help="internal: run one measurement in this process")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny in-process probe, no BENCH json (CI smoke)")
    ap.add_argument("--expect-cache-hits", action="store_true",
                    help="with --dry-run: assert the cache hit and the wall "
                         "time dropped vs the first recorded probe")
    args = ap.parse_args()
    if args.probe:
        rep = probe(args.cache_dir)
        print("COLD_START_PROBE_JSON:" + json.dumps(rep))
    elif args.dry_run:
        if not args.cache_dir:
            ap.error("--dry-run requires --cache-dir")
        rep = dry_run(args.cache_dir, args.expect_cache_hits)
        print(json.dumps(rep, indent=1))
    else:
        rep = run(cache_dir=args.cache_dir)
        print(json.dumps(rep, indent=1))
        print("COLD_START_SPEEDUP="
              f"{rep['speedup_calibrate_warm_vs_nocache']}x")
