"""Paper §3.5 cost claim: one PCA correction is negligible vs one NFE.

The paper reports 0.06 s PCA vs 30.2 s NFE on Stable Diffusion.  We measure
the same ratio on this container: the PAS basis computation (gram-trick PCA +
Schmidt) vs one denoiser evaluation at LM scale (reduced backbone, but the
*ratio* scales in PAS's favour with D: PCA is O(n^2 D), the denoiser O(P D)).
Also measures the Pallas gram kernel vs the jnp oracle (interpret mode).
"""
import jax
import jax.numpy as jnp

from repro.core import pca
from repro.kernels import ops, ref

from . import common


def run() -> list[dict]:
    rows = []
    for d in (4096, 65536, 1 << 20):
        n = 12
        q = jax.random.normal(jax.random.key(0), (n, d))
        mask = jnp.ones((n,))
        dvec = jax.random.normal(jax.random.key(1), (d,))

        basis = jax.jit(lambda q, m, dd: pca.pas_basis(q, m, dd, 4))
        us_basis = common.timed_us(basis, q, mask, dvec)
        rows.append({"op": "pas_basis(gram+eigh+schmidt)", "D": d,
                     "us_per_call": round(us_basis, 1)})

    # one denoiser NFE at (reduced) LM scale for the ratio
    from repro import models
    from repro.configs import get_config
    cfg = get_config("qwen1.5-0.5b").reduced(d_model=256, n_layers=4)
    params = models.init_params(jax.random.key(0), cfg, with_diffusion_head=True)
    x = jax.random.normal(jax.random.key(2), (8, 64, cfg.d_model))
    sigma = jnp.full((8,), 10.0)
    den = jax.jit(lambda p, x, s: models.denoise(p, x, s, cfg))
    us_nfe = common.timed_us(den, params, x, sigma)
    d_state = 8 * 64 * cfg.d_model
    rows.append({"op": "denoiser_nfe(reduced-lm)", "D": d_state,
                 "us_per_call": round(us_nfe, 1)})

    basis_at_same_d = common.timed_us(
        jax.jit(lambda q, m, dd: pca.pas_basis(q, m, dd, 4)),
        jax.random.normal(jax.random.key(3), (12, d_state)),
        jnp.ones((12,)), jax.random.normal(jax.random.key(4), (d_state,)))
    rows.append({"op": "pas_basis_at_same_D", "D": d_state,
                 "us_per_call": round(basis_at_same_d, 1),
                 "ratio_vs_nfe": round(basis_at_same_d / us_nfe, 4)})
    common.save_table("pas_overhead", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
