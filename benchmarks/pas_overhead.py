"""Paper §3.5 cost claim: one PCA correction is negligible vs one NFE.

The paper reports 0.06 s PCA vs 30.2 s NFE on Stable Diffusion.  We measure
the same ratio on this container: the PAS basis computation (gram-trick PCA +
Schmidt) vs one denoiser evaluation at LM scale (reduced backbone, but the
*ratio* scales in PAS's favour with D: PCA is O(n^2 D), the denoiser O(P D)).
Also measures the fused engine step (kernels/fused_step.py) against the
seed's unfused phi composition — the projection + multistep update that the
engine folds into one kernel pass.

  PYTHONPATH=src python -m benchmarks.pas_overhead [--dry-run]

``--dry-run`` (the CI smoke mode) runs the smallest config of every
measurement so the harness can't silently rot.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import pca, solvers
from repro.kernels import ops

from . import common


def _fused_step_rows(d: int, batch: int = 16) -> list[dict]:
    """Fused engine step vs the seed's unfused phi for one ipndm3 update."""
    sol = common.spec_for("ipndm3", 10).make_solver()
    x = jax.random.normal(jax.random.key(0), (batch, d))
    dvec = jax.random.normal(jax.random.key(1), (batch, d))
    hist = jax.random.normal(jax.random.key(2), (2, batch, d))
    coef = jnp.concatenate([sol.alpha[3][None], sol.beta[3],
                            sol.ts_jax[3][None]])

    def seed_phi(x, dvec, hist):
        return sol.phi(x, dvec, 3, solvers.SolverHist(hist, jnp.int32(2)))

    us_seed = common.timed_us(jax.jit(seed_phi), x, dvec, hist)
    us_fused = common.timed_us(
        jax.jit(lambda x, n, h: ops.fused_step(x, n, h, coef)), x, dvec, hist)
    return [
        {"op": "seed_phi(unfused)", "D": d, "B": batch,
         "us_per_call": round(us_seed, 1)},
        {"op": "engine_fused_step", "D": d, "B": batch,
         "us_per_call": round(us_fused, 1),
         "speedup_vs_seed": round(us_seed / max(us_fused, 1e-9), 3)},
    ]


def run(dry_run: bool = False) -> list[dict]:
    rows = []
    dims = (4096,) if dry_run else (4096, 65536, 1 << 20)
    for d in dims:
        n = 12
        q = jax.random.normal(jax.random.key(0), (n, d))
        mask = jnp.ones((n,))
        dvec = jax.random.normal(jax.random.key(1), (d,))

        basis = jax.jit(lambda q, m, dd: pca.pas_basis(q, m, dd, 4))
        us_basis = common.timed_us(basis, q, mask, dvec)
        rows.append({"op": "pas_basis(gram+eigh+schmidt)", "D": d,
                     "us_per_call": round(us_basis, 1)})

    rows.extend(_fused_step_rows(dims[-1]))

    # one denoiser NFE at (reduced) LM scale for the ratio
    from repro import models
    from repro.configs import get_config
    reduced = dict(d_model=128, n_layers=2) if dry_run \
        else dict(d_model=256, n_layers=4)
    cfg = get_config("qwen1.5-0.5b").reduced(**reduced)
    params = models.init_params(jax.random.key(0), cfg, with_diffusion_head=True)
    x = jax.random.normal(jax.random.key(2), (8, 64, cfg.d_model))
    sigma = jnp.full((8,), 10.0)
    den = jax.jit(lambda p, x, s: models.denoise(p, x, s, cfg))
    us_nfe = common.timed_us(den, params, x, sigma)
    d_state = 8 * 64 * cfg.d_model
    rows.append({"op": "denoiser_nfe(reduced-lm)", "D": d_state,
                 "us_per_call": round(us_nfe, 1)})

    basis_at_same_d = common.timed_us(
        jax.jit(lambda q, m, dd: pca.pas_basis(q, m, dd, 4)),
        jax.random.normal(jax.random.key(3), (12, d_state)),
        jnp.ones((12,)), jax.random.normal(jax.random.key(4), (d_state,)))
    rows.append({"op": "pas_basis_at_same_D", "D": d_state,
                 "us_per_call": round(basis_at_same_d, 1),
                 "ratio_vs_nfe": round(basis_at_same_d / us_nfe, 4)})
    if not dry_run:
        common.save_table("pas_overhead", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="smallest config of every measurement (CI smoke)")
    args = ap.parse_args()
    for r in run(dry_run=args.dry_run):
        print(r)
