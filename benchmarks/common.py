"""Shared benchmark substrate: oracle models, specs/pipelines, metrics,
artifacts.

All sampler construction goes through ``repro.api`` (SamplerSpec →
Pipeline); benchmarks never hand-wire make_solver/calibrate/engine lookups.

Offline constraint (DESIGN.md §7): no pretrained EDM checkpoints or image
datasets exist in this container, so sample quality is measured as L2/L1
distance to the exact solution / high-NFE teacher — the paper's own auxiliary
metric (Table 11) — on the analytic Gaussian-mixture oracle, plus a learned
tiny denoiser for the "trained model" path.  FID rows are therefore proxies;
every table states this.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.api import (Pipeline, SamplerSpec, ScheduleSpec, TeacherSpec,
                       teacher_trajectory)
from repro.core import analytic, pas

ART = Path(__file__).resolve().parent / "artifacts" / "repro"

DIM = 64
T_MIN, T_MAX = 0.002, 80.0
N_CALIB = 512
N_EVAL = 256
TEACHER_NFE = 100


def oracle(kind: str = "two_mode"):
    if kind == "two_mode":
        return analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)
    if kind == "multi":
        return analytic.make_gmm(jax.random.key(7), DIM, n_modes=8)
    raise ValueError(kind)


def spec_for(solver: str, nfe: int, *, t_min: float = T_MIN,
             t_max: float = T_MAX, teacher: str = "heun",
             teacher_nfe: int = TEACHER_NFE,
             pas_cfg: pas.PASConfig | None = None,
             dtype: str = "float32") -> SamplerSpec:
    """The benchmark-default SamplerSpec for one (solver, NFE)."""
    return SamplerSpec(
        solver=solver, nfe=nfe,
        schedule=ScheduleSpec(t_min=t_min, t_max=t_max),
        dtype=dtype,
        teacher=TeacherSpec(solver=teacher, nfe=teacher_nfe),
        pas=pas_cfg if pas_cfg is not None else default_pas_cfg())


def pipeline_for(eps_fn, solver: str, nfe: int, **kw) -> Pipeline:
    return Pipeline.from_spec(spec_for(solver, nfe, **kw), eps_fn, dim=DIM)


def calib_eval_sets(gmm, nfe: int, n_calib: int = N_CALIB,
                    n_eval: int = N_EVAL, teacher: str = "heun",
                    eps_fn=None):
    """(student_ts, (x_c, gt_c), (x_e, gt_e)) on the benchmark spec's grids."""
    eps_fn = eps_fn if eps_fn is not None else gmm.eps
    spec = spec_for("ddim", nfe, teacher=teacher)
    x_c = gmm.sample_prior(jax.random.key(0), n_calib, T_MAX)
    gt_c = teacher_trajectory(spec, eps_fn, x_c)
    x_e = gmm.sample_prior(jax.random.key(99), n_eval, T_MAX)
    gt_e = teacher_trajectory(spec, eps_fn, x_e)
    return spec.ts(), (x_c, gt_c), (x_e, gt_e)


def final_err(x0, gt_end, metric: str = "l2") -> float:
    d = x0 - gt_end
    if metric == "l2":
        return float(jnp.mean(jnp.linalg.norm(d, axis=-1)))
    return float(jnp.mean(jnp.abs(d)))


def default_pas_cfg(**kw) -> pas.PASConfig:
    base = dict(lr=1e-2, n_sgd_iters=300, tolerance=1e-4, loss="l1",
                val_fraction=0.25, final_gate=True)
    base.update(kw)
    return pas.PASConfig(**base)


def run_pas(solver_name: str, nfe: int, gmm=None, cfg=None,
            eval_metric: str = "l2"):
    """Calibrate + evaluate PAS for one (solver, NFE). Returns a result dict."""
    gmm = gmm or oracle()
    cfg = cfg or default_pas_cfg()
    pipe = pipeline_for(gmm.eps, solver_name, nfe, pas_cfg=cfg)
    x_c = gmm.sample_prior(jax.random.key(0), N_CALIB, T_MAX)
    gt_c = pipe.teacher_trajectory(x_c)     # teacher solve outside the timer
    t0 = time.time()
    pipe.calibrate(x_t=x_c, gt=gt_c)
    train_s = time.time() - t0
    x_e = gmm.sample_prior(jax.random.key(99), N_EVAL, T_MAX)
    gt_e = pipe.teacher_trajectory(x_e)
    x_plain = pipe.sample(x_e, use_pas=False)
    x_pas = pipe.sample(x_e)
    return {
        "solver": solver_name, "nfe": nfe,
        "err_plain": final_err(x_plain, gt_e[-1], eval_metric),
        "err_pas": final_err(x_pas, gt_e[-1], eval_metric),
        "corrected_steps": pipe.params.corrected_paper_steps(),
        "n_stored_params": pipe.params.n_stored_params,
        "calib_seconds": round(train_s, 2),
    }


def save_table(name: str, rows, extra: dict | None = None) -> Path:
    ART.mkdir(parents=True, exist_ok=True)
    path = ART / f"{name}.json"
    path.write_text(json.dumps({"rows": rows, "extra": extra or {},
                                "generated": time.strftime("%F %T")}, indent=1))
    return path


def timed_us(fn, *args, n: int = 5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6
