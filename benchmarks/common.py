"""Shared benchmark substrate: oracle models, schedules, metrics, artifacts.

Offline constraint (DESIGN.md §7): no pretrained EDM checkpoints or image
datasets exist in this container, so sample quality is measured as L2/L1
distance to the exact solution / high-NFE teacher — the paper's own auxiliary
metric (Table 11) — on the analytic Gaussian-mixture oracle, plus a learned
tiny denoiser for the "trained model" path.  FID rows are therefore proxies;
every table states this.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import analytic, pas, schedules, solvers
from repro.engine import engine_for_solver

ART = Path(__file__).resolve().parent / "artifacts" / "repro"

DIM = 64
T_MIN, T_MAX = 0.002, 80.0
N_CALIB = 512
N_EVAL = 256
TEACHER_NFE = 100


def oracle(kind: str = "two_mode"):
    if kind == "two_mode":
        return analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)
    if kind == "multi":
        return analytic.make_gmm(jax.random.key(7), DIM, n_modes=8)
    raise ValueError(kind)


def calib_eval_sets(gmm, nfe: int, n_calib: int = N_CALIB, n_eval: int = N_EVAL):
    s_ts, t_ts, m = schedules.nested_teacher_schedule(nfe, TEACHER_NFE,
                                                      T_MIN, T_MAX)
    x_c = gmm.sample_prior(jax.random.key(0), n_calib, T_MAX)
    gt_c = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_c)
    x_e = gmm.sample_prior(jax.random.key(99), n_eval, T_MAX)
    gt_e = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_e)
    return s_ts, (x_c, gt_c), (x_e, gt_e)


def final_err(x0, gt_end, metric: str = "l2") -> float:
    d = x0 - gt_end
    if metric == "l2":
        return float(jnp.mean(jnp.linalg.norm(d, axis=-1)))
    return float(jnp.mean(jnp.abs(d)))


def default_pas_cfg(**kw) -> pas.PASConfig:
    base = dict(lr=1e-2, n_sgd_iters=300, tolerance=1e-4, loss="l1",
                val_fraction=0.25, final_gate=True)
    base.update(kw)
    return pas.PASConfig(**base)


def run_pas(solver_name: str, nfe: int, gmm=None, cfg=None,
            eval_metric: str = "l2"):
    """Calibrate + evaluate PAS for one (solver, NFE). Returns a result dict."""
    gmm = gmm or oracle()
    cfg = cfg or default_pas_cfg()
    s_ts, (x_c, gt_c), (x_e, gt_e) = calib_eval_sets(gmm, nfe)
    sol = solvers.make_solver(solver_name, s_ts)
    t0 = time.time()
    params, diag = pas.calibrate(sol, gmm.eps, x_c, gt_c, cfg)
    train_s = time.time() - t0
    engine = engine_for_solver(sol)
    x_plain = engine.sample(gmm.eps, x_e)
    x_pas = engine.sample(gmm.eps, x_e, params=params, cfg=cfg)
    return {
        "solver": solver_name, "nfe": nfe,
        "err_plain": final_err(x_plain, gt_e[-1], eval_metric),
        "err_pas": final_err(x_pas, gt_e[-1], eval_metric),
        "corrected_steps": params.corrected_paper_steps(),
        "n_stored_params": params.n_stored_params,
        "calib_seconds": round(train_s, 2),
    }


def save_table(name: str, rows, extra: dict | None = None) -> Path:
    ART.mkdir(parents=True, exist_ok=True)
    path = ART / f"{name}.json"
    path.write_text(json.dumps({"rows": rows, "extra": extra or {},
                                "generated": time.strftime("%F %T")}, indent=1))
    return path


def timed_us(fn, *args, n: int = 5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6
