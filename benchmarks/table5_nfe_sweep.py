"""Paper Table 5: NFE sweep 4..10 for DDIM / iPNDM3 with and without PAS."""
from . import common


def run(nfes=(4, 5, 6, 7, 8, 9, 10)) -> list[dict]:
    gmm = common.oracle()
    cfg = common.default_pas_cfg()
    rows = []
    for nfe in nfes:
        for name in ("ddim", "ipndm3"):
            r = common.run_pas(name, nfe, gmm, cfg)
            rows.append({"method": name, "nfe": nfe, "err_l2": r["err_plain"]})
            rows.append({"method": f"{name}+PAS", "nfe": nfe,
                         "err_l2": r["err_pas"],
                         "corrected_steps": r["corrected_steps"]})
    common.save_table("table5_nfe_sweep", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
