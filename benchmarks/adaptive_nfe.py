"""Adaptive-NFE curves: error-controlled sampling + the NFE-ladder router.

Two experiments, one JSON record (root-level ``BENCH_adaptive_nfe.json``):

* **NFE vs error** — fixed Karras grids (ddim = Euler, heun) against the
  error-controlled embedded-pair sampler (``repro.engine.adaptive``) over an
  rtol sweep, error = mean L2 to a heun@200 reference on the shared GMM
  oracle.  The acceptance claim (``claim_a``): some adaptive point reaches
  its error with a *lower mean NFE* than the cheapest fixed grid reaching
  the same error — per-sample step-size control beats one-size-fits-all
  grids once the error target is tight (at loose targets the Karras grid's
  few-step tuning wins; the curves record both regimes honestly).

* **Ladder deadline hit-rate** — an ``NFELadder`` router (PAS rungs +
  teacher-grade lane from one base spec/artifact family) against a
  single-lane teacher-grade baseline under the *same* seeded Poisson load
  with mixed 25 ms / 250 ms deadlines.  Hit = submit-to-last-chunk latency
  within the request's deadline, warm (pre-replayed) schedules on both
  sides.  The acceptance claim (``claim_b``): the ladder's overall hit rate
  is at least the baseline's — tight deadlines route to few-step PAS rungs
  instead of queueing behind teacher-grade flushes.

Mean NFE for adaptive rows counts evals actually executed (accepted +
rejected embedded steps, 2 evals each) — the same honest counter the serve
stack accounts at retire time; the compiled scan's fixed-iteration capacity
cost is recorded separately as ``scan_evals_per_sample``.

  PYTHONPATH=src python -m benchmarks.adaptive_nfe [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_adaptive_nfe.json"

N_EVAL = 256
REF_NFE = 200                       # heun reference grid (400 evals)
FIXED_NFES = (5, 8, 10, 15, 20, 25, 30, 40)
RTOLS = (0.05, 0.02, 0.01, 0.005, 0.002)
MAX_ITERS = 64

# ladder experiment: rungs + a moderate teacher lane (heun@40 = 80 evals —
# teacher-grade for the load test without making every flush glacial on CPU)
LADDER_NFES = (4, 8)
LADDER_TEACHER_NFE = 40
LADDER_BUDGETS = {"nfe4": 32, "nfe8": 32, "teacher": 256}
# prices nfe8 at 8 ms of slack and the teacher lane at 80 ms, so with the
# half-SLA batching deadline below an interactive request (12.5 ms batching
# slack) routes to a cheap rung and a batch request (125 ms) to the teacher
SLACK_MS_PER_EVAL = 1.0
# requests flush when their *batching* deadline expires; batching at the
# full SLA would land every deadline-triggered flush just after it, so the
# scheduler gets half the SLA and the other half covers flush compute
BATCHING_FRAC = 0.5
INTERACTIVE_DEADLINE_MS = 25.0
BATCH_DEADLINE_MS = 250.0
RATE_RPS = 80.0
DURATION_S = 1.5


# -- part (a): NFE vs error --------------------------------------------------

def _nfe_vs_error(dry_run: bool) -> tuple[list[dict], bool]:
    import jax
    import jax.numpy as jnp

    from repro.api import ErrorControlConfig, Pipeline, SamplerSpec
    from repro.engine import get_adaptive_engine_for_spec

    from . import common

    fixed_nfes = (5, 10, 20) if dry_run else FIXED_NFES
    rtols = (0.05, 0.01) if dry_run else RTOLS
    n_eval = 64 if dry_run else N_EVAL

    gmm = common.oracle()
    x_t = gmm.sample_prior(jax.random.key(99), n_eval, common.T_MAX)
    ref = Pipeline.from_spec(
        SamplerSpec(solver="heun", nfe=REF_NFE), gmm.eps,
        dim=common.DIM).sample(x_t, use_pas=False)

    def err(x) -> float:
        return float(jnp.mean(jnp.linalg.norm(x - ref, axis=-1)))

    rows: list[dict] = []
    for solver in ("ddim", "heun"):
        for n in fixed_nfes:
            pipe = Pipeline.from_spec(SamplerSpec(solver=solver, nfe=n),
                                      gmm.eps, dim=common.DIM)
            e = err(pipe.sample(x_t, use_pas=False))
            rows.append({"method": f"{solver}@{n}", "kind": "fixed",
                         "evals_per_sample": pipe.engine.nfe,
                         "mean_nfe": float(pipe.engine.nfe),
                         "err_l2": round(e, 4)})
            print(f"fixed {solver}@{n}: evals={pipe.engine.nfe} "
                  f"err={e:.4f}", flush=True)

    for rtol in rtols:
        ec = ErrorControlConfig(rtol=rtol, max_iters=MAX_ITERS)
        spec = SamplerSpec(solver="ddim", nfe=10, error_control=ec)
        eng = get_adaptive_engine_for_spec(spec)
        x, info = eng.sample_with_info(gmm.eps, x_t)
        nfe = np.asarray(info["nfe"])
        e = err(x)
        rows.append({
            "method": f"adaptive@rtol{rtol}", "kind": "adaptive",
            "rtol": rtol, "atol": ec.atol,
            "mean_nfe": round(float(nfe.mean()), 2),
            "max_nfe": int(nfe.max()), "min_nfe": int(nfe.min()),
            "finished_frac": round(float(np.asarray(
                info["finished"]).mean()), 4),
            "scan_evals_per_sample": 2 * ec.max_iters,
            "err_l2": round(e, 4)})
        print(f"adaptive rtol={rtol}: mean_nfe={nfe.mean():.1f} "
              f"err={e:.4f}", flush=True)

    # claim (a): some adaptive point beats the cheapest fixed grid that
    # reaches the same error
    fixed = [r for r in rows if r["kind"] == "fixed"]
    claim_a = False
    for r in rows:
        if r["kind"] != "adaptive":
            continue
        qualifying = [f["evals_per_sample"] for f in fixed
                      if f["err_l2"] <= r["err_l2"]]
        r["best_fixed_evals_at_err"] = min(qualifying, default=None)
        r["beats_best_fixed"] = (bool(qualifying)
                                 and r["mean_nfe"] < min(qualifying))
        claim_a = claim_a or r["beats_best_fixed"]
    return rows, claim_a


# -- part (b): ladder router deadline hit-rate -------------------------------

def _hit_rate(pairs) -> dict:
    """Deadline hit stats over (arrival, handle) pairs from one replay."""
    hits = total = 0
    by_class: dict[str, list[int]] = {}
    for arrival, handle in pairs:
        ddl_ms = arrival.deadline_ms
        if ddl_ms is None or handle.latency_s is None:
            continue
        hit = int(handle.latency_s * 1e3 <= ddl_ms)
        hits += hit
        total += 1
        by_class.setdefault(handle.priority, []).append(hit)
    return {
        "hit_rate": round(hits / total, 4) if total else None,
        "requests": total,
        "by_priority": {p: round(float(np.mean(v)), 4)
                        for p, v in by_class.items()},
    }


def _bucketed_runner(pipes, budgets: dict, use_pas: dict, dim: int):
    """Lane executors that pad every flush to the lane budget, so each lane
    compiles exactly one batch shape (the serve_router idiom) — the hit-rate
    curves then measure scheduling, never per-shape recompilation."""
    import jax.numpy as jnp

    def run(key, x_t):
        budget = budgets[key]
        x = np.asarray(x_t)
        if x.shape[0] < budget:
            x = np.concatenate(
                [x, np.zeros((budget - x.shape[0], dim), x.dtype)])
        return pipes[key].sample(jnp.asarray(x),
                                 use_pas=use_pas.get(key, False))
    return run


def _replay_on(router, arrivals) -> dict:
    """Warm replay (compile everything), then one timed replay; stats."""
    from repro.api import replay

    def submit(req):
        # batching slack = half the SLA (see BATCHING_FRAC); the request's
        # own deadline_ms stays the SLA the hit-rate is scored against
        ddl = req.deadline_ms
        return router.submit(
            req, deadline_ms=(None if ddl is None else ddl * BATCHING_FRAC))

    replay(arrivals, submit)               # warmup: compile flush shapes
    router.drain(timeout=600)
    pairs = replay(arrivals, submit)
    router.drain(timeout=600)
    out = _hit_rate(pairs)
    out["lane_rows"] = dict(router.stats["lane_rows"])
    return out


def _ladder_vs_baseline(dry_run: bool) -> dict:
    import jax

    from repro.api import (NFELadder, Pipeline, PipelineRouter, SamplerSpec,
                           ServeConfig, TeacherSpec, poisson_arrivals)

    from . import common

    duration = 0.5 if dry_run else DURATION_S
    base = SamplerSpec(
        solver="ddim", nfe=10,
        teacher=TeacherSpec(solver="heun", nfe=LADDER_TEACHER_NFE),
        pas=common.default_pas_cfg(n_sgd_iters=100))
    gmm = common.oracle()
    cfg = ServeConfig(max_batch=max(LADDER_BUDGETS.values()),
                      slack_ms_per_eval=SLACK_MS_PER_EVAL)

    ladder = NFELadder(base, nfes=LADDER_NFES)
    use_pas = ({k: False for k in ladder.keys} if dry_run
               else dict(ladder.use_pas))
    with tempfile.TemporaryDirectory() as family_dir:
        pipes = {k: Pipeline.from_spec(spec, gmm.eps, dim=common.DIM)
                 for k, spec in ladder.specs.items()}
        router = PipelineRouter(
            pipes, budgets=dict(LADDER_BUDGETS), cfg=cfg,
            run_batch=_bucketed_runner(pipes, LADDER_BUDGETS, use_pas,
                                       common.DIM))
        if not dry_run:
            # the "one artifact family" workflow end to end: calibrate every
            # PAS rung against the shared teacher, persist rung artifacts +
            # the ladder manifest in one directory
            ladder.calibrate(router, jax.random.key(0), batch=128,
                             artifact_dir=family_dir)
        arrivals = poisson_arrivals(
            RATE_RPS, duration, seed=0,
            interactive_deadline_ms=INTERACTIVE_DEADLINE_MS,
            batch_deadline_ms=BATCH_DEADLINE_MS)
        try:
            ladder_stats = _replay_on(router, arrivals)
        finally:
            router.close()

        # equal-load baseline: the teacher-grade lane alone
        base_pipes = {"teacher": Pipeline.from_spec(
            ladder.specs["teacher"], gmm.eps, dim=common.DIM)}
        base_budgets = {"teacher": LADDER_BUDGETS["teacher"]}
        baseline = PipelineRouter(
            base_pipes, budgets=base_budgets, cfg=cfg,
            run_batch=_bucketed_runner(base_pipes, base_budgets,
                                       {"teacher": False}, common.DIM))
        try:
            base_stats = _replay_on(baseline, arrivals)
        finally:
            baseline.close()

    report = {
        "ladder": ladder_stats, "baseline": base_stats,
        "rungs": ladder.keys, "rate_rps": RATE_RPS, "duration_s": duration,
        "deadlines_ms": {"interactive": INTERACTIVE_DEADLINE_MS,
                         "batch": BATCH_DEADLINE_MS},
        "slack_ms_per_eval": SLACK_MS_PER_EVAL,
        "claim_b": (ladder_stats["hit_rate"] is not None
                    and base_stats["hit_rate"] is not None
                    and ladder_stats["hit_rate"] >= base_stats["hit_rate"]),
    }
    print(f"ladder hit_rate={ladder_stats['hit_rate']} "
          f"baseline hit_rate={base_stats['hit_rate']}", flush=True)
    return report


def run(dry_run: bool = False) -> dict:
    import jax

    rows, claim_a = _nfe_vs_error(dry_run)
    ladder = _ladder_vs_baseline(dry_run)
    report = {
        "rows": rows,
        "claim_a_adaptive_beats_best_fixed": claim_a,
        "ladder": ladder,
        "claim_b_ladder_hit_rate_ge_baseline": ladder["claim_b"],
        "backend": jax.default_backend(),
        "generated": time.strftime("%F %T"),
    }
    if not dry_run:               # smoke runs don't pollute the perf record
        OUT.write_text(json.dumps(report, indent=1))
        from . import common
        common.save_table(
            "adaptive_nfe", rows,
            extra={"claim_a": claim_a, "claim_b": ladder["claim_b"],
                   "backend": report["backend"]})
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="small sweep, no root JSON write (CI smoke)")
    args = ap.parse_args()
    rep = run(dry_run=args.dry_run)
    for r in rep["rows"]:
        print(r)
    print(f"claim_a={rep['claim_a_adaptive_beats_best_fixed']} "
          f"claim_b={rep['claim_b_ladder_hit_rate_ge_baseline']}")
    if not args.dry_run:
        assert rep["claim_a_adaptive_beats_best_fixed"], \
            "no adaptive point beat the best fixed grid at its error"
        assert rep["claim_b_ladder_hit_rate_ge_baseline"], \
            "ladder router missed more deadlines than the single-lane baseline"
