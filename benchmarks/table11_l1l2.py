"""Paper Table 11: L1/L2 metrics vs the teacher across iPNDM orders 1..4,
with and without PAS (PAS never hurts; gains shrink as the solver improves)."""
from . import common


def run(nfe: int = 10) -> list[dict]:
    gmm = common.oracle()
    cfg = common.default_pas_cfg()
    rows = []
    for order in (1, 2, 3, 4):
        name = f"ipndm{order}"
        for metric in ("l2", "l1"):
            r = common.run_pas(name, nfe, gmm, cfg, eval_metric=metric)
            rows.append({"method": name, "order": order, "metric": metric,
                         "nfe": nfe, "plain": r["err_plain"],
                         "pas": r["err_pas"],
                         "corrected_steps": r["corrected_steps"]})
    common.save_table("table11_l1l2", rows)
    for r in rows:
        assert r["pas"] <= r["plain"] * 1.05, r   # final gate: never worse
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
