"""Router SLA curves: latency vs offered load, per priority class.

A two-lane ``PipelineRouter`` — a PAS-corrected low-NFE lane ("fast",
ddim@4 + synthetic correction) and a teacher-grade lane ("hq", ddim@20,
uncorrected) — serves a seeded Poisson request stream at several offered
loads.  Each arrival carries a priority class and a deadline
(``runtime.traffic.poisson_arrivals``): interactive requests are small with
a tight deadline, so the slack router lands them on the fast lane and the
scheduler packs them ahead of batch backfill; batch requests are large with
a loose deadline and ride the hq lane.  Both lanes share one submit queue,
one scheduler thread and one in-flight window — the SLA separation is pure
scheduling, not extra hardware.

Per (offered load, priority class): p50/p95/p99 submit-to-last-chunk
latency, request/sample counts and the per-lane routing split, into a
root-level ``BENCH_serve_router.json`` so the SLA trajectory is tracked PR
over PR.  The run asserts the acceptance contract: pooled over the mixed
Poisson load, **interactive p95 < batch p95**, and both lanes actually
served flushes.

Lane executors bucket-pad every flush to the lane budget before sampling
(the retire path only reads the real rows back), so each lane compiles one
batch shape once and the latency curves measure scheduling, not
recompilation.

  PYTHONPATH=src python -m benchmarks.serve_router [--rates 60,120,240] \
      [--duration 1.5] [--trace FILE] [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_serve_router.json"

DIM = 64
FAST_NFE, HQ_NFE = 4, 20
# the hq budget is deliberately large (a bulk lane amortises into big
# flushes): with a small budget the batch class fills it instantly at high
# offered load and flushes *faster* than the interactive deadline, which
# inverts the SLA ordering the curves are meant to show
BUDGETS = {"fast": 32, "hq": 512}
# ms of slack one model eval is worth: 2.0 prices the fast lane at 8 ms and
# the hq lane at 40 ms, so a 25 ms interactive deadline routes fast and a
# 250 ms batch deadline routes hq
SLACK_MS_PER_EVAL = 2.0
INTERACTIVE_DEADLINE_MS = 25.0
BATCH_DEADLINE_MS = 250.0
RATES_RPS = (60.0, 120.0, 240.0)
DURATION_S = 1.5


def _percentiles(lat_s) -> dict:
    lat = np.asarray(sorted(lat_s))
    return {f"p{p}_ms": round(float(np.percentile(lat, p)) * 1e3, 2)
            for p in (50, 95, 99)}


def _build_zoo():
    """The shared pipeline zoo: built once so every load point's router
    reuses the same compiled programs (prior/sample caches live on the
    pipeline objects — fresh routers over shared lanes measure scheduling,
    never recompilation)."""
    import jax.numpy as jnp

    from repro.api import Pipeline, SamplerSpec
    from repro.core import two_mode_gmm
    from repro.core.pas import PASParams

    gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)
    fast = Pipeline.from_spec(SamplerSpec(solver="ddim", nfe=FAST_NFE),
                              gmm.eps, dim=DIM)
    active = np.zeros(FAST_NFE, bool)
    active[[1, 3]] = True
    coords = np.zeros((FAST_NFE, 4), np.float32)
    coords[1] = [1.0, 0.05, 0.0, 0.0]
    coords[3] = [0.98, -0.04, 0.0, 0.0]
    fast.set_params(PASParams(active=active, coords=jnp.asarray(coords)))
    hq = Pipeline.from_spec(SamplerSpec(solver="ddim", nfe=HQ_NFE),
                            gmm.eps, dim=DIM)
    pipes = {"fast": fast, "hq": hq}

    def bucketed(key, x_t):
        # pad to the lane budget (in numpy — host concat never compiles) so
        # each lane's sampler compiles exactly one batch shape; the retire
        # path only reads the real rows back off the front
        import jax
        budget = BUDGETS[key]
        x = np.asarray(x_t)
        if x.shape[0] < budget:
            x = np.concatenate(
                [x, np.zeros((budget - x.shape[0], DIM), x.dtype)])
        return pipes[key].sample(jax.numpy.asarray(x),
                                 use_pas=(key == "fast"))

    return pipes, bucketed


def _router_for(pipes, bucketed, stats: dict):
    from repro.api import PipelineRouter, ServeConfig

    return PipelineRouter(
        pipes, budgets=BUDGETS, run_batch=bucketed, stats=stats,
        cfg=ServeConfig(max_batch=max(BUDGETS.values()),
                        slack_ms_per_eval=SLACK_MS_PER_EVAL))


def _warm(pipes, bucketed, arrivals) -> None:
    """Compile everything the timed pass will touch: both lanes' bucket
    shapes, every palette request size's prior draw, and (via one untimed
    replay of the same schedule) the flush compositions the scheduler's
    host staging concatenates."""
    from repro.api import Request, replay

    router = _router_for(pipes, bucketed, {})
    try:
        sizes = {a.n_samples for a in arrivals}
        sizes.update(BUDGETS.values())
        for key in pipes:
            for n in sorted(sizes):
                router.submit(Request(seed=0, n_samples=n), pipeline=key)
        router.drain(timeout=600)
        replay(arrivals, router.submit)
        router.drain(timeout=600)
    finally:
        router.close()


def _one_load_point(pipes, bucketed, arrivals, rate_rps: float,
                    duration_s: float) -> list[dict]:
    from repro.api import replay

    stats: dict = {}
    router = _router_for(pipes, bucketed, stats)
    try:
        pairs = replay(arrivals, router.submit)
        router.drain(timeout=600)
    finally:
        router.close()
    assert all(ln > 0 for ln in stats["lane_batches"].values()), \
        f"a lane sat idle under mixed load: {stats['lane_batches']}"

    rows = []
    for prio in ("interactive", "batch"):
        handles = [h for _, h in pairs if h.priority == prio]
        if not handles:
            continue
        lanes: dict[str, int] = {}
        for h in handles:
            lanes[h.lane] = lanes.get(h.lane, 0) + 1
        samples = sum(a.n_samples for a, h in pairs if h.priority == prio)
        rows.append({
            "rate_rps": rate_rps, "priority": prio,
            "requests": len(handles), "samples": samples,
            "offered_samples_per_s": round(samples / duration_s, 1),
            **_percentiles([h.latency_s for h in handles]),
            "lanes": lanes,
            "deadline_ms": (INTERACTIVE_DEADLINE_MS if prio == "interactive"
                            else BATCH_DEADLINE_MS),
        })
    return rows


def run(rates=RATES_RPS, duration_s: float = DURATION_S, trace=None,
        dry_run: bool = False) -> dict:
    from repro.api import load_trace, poisson_arrivals

    if dry_run:
        rates, duration_s = (80.0,), 0.5

    pipes, bucketed = _build_zoo()
    rows: list[dict] = []
    pooled: dict[str, list[float]] = {"interactive": [], "batch": []}
    for rate in rates:
        if trace is not None:
            arrivals = load_trace(trace)
        else:
            arrivals = poisson_arrivals(
                rate, duration_s, seed=0,
                interactive_deadline_ms=INTERACTIVE_DEADLINE_MS,
                batch_deadline_ms=BATCH_DEADLINE_MS)
        _warm(pipes, bucketed, arrivals)
        point = _one_load_point(pipes, bucketed, arrivals, rate, duration_s)
        rows.extend(point)
        for r in point:
            pooled[r["priority"]].append(r["p95_ms"])
        print(f"rate={rate}rps " + " ".join(
            f"{r['priority']}:p95={r['p95_ms']}ms" for r in point),
            flush=True)

    # acceptance: under the mixed Poisson load the interactive class beats
    # the batch class at p95 (worst load point governs)
    sla_ok = (bool(pooled["interactive"]) and bool(pooled["batch"])
              and max(pooled["interactive"]) < min(pooled["batch"]))
    report = {
        "rows": rows,
        "lanes": {"fast": {"solver": "ddim", "nfe": FAST_NFE, "pas": True,
                           "budget": BUDGETS["fast"]},
                  "hq": {"solver": "ddim", "nfe": HQ_NFE, "pas": False,
                         "budget": BUDGETS["hq"]}},
        "slack_ms_per_eval": SLACK_MS_PER_EVAL,
        "duration_s": duration_s,
        "interactive_p95_lt_batch_p95": sla_ok,
        "backend": __import__("jax").default_backend(),
        "generated": time.strftime("%F %T"),
    }
    if not dry_run:               # smoke runs don't pollute the perf record
        OUT.write_text(json.dumps(report, indent=1))
        from . import common
        common.save_table("serve_router", rows,
                          extra={"backend": report["backend"],
                                 "interactive_p95_lt_batch_p95": sla_ok})
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default=None,
                    help="comma list of offered loads, requests/s")
    ap.add_argument("--duration", type=float, default=DURATION_S)
    ap.add_argument("--trace", default=None,
                    help="CSV trace file to replay instead of Poisson")
    ap.add_argument("--dry-run", action="store_true",
                    help="one small load point (CI smoke)")
    args = ap.parse_args()
    rates = (tuple(float(r) for r in args.rates.split(","))
             if args.rates else RATES_RPS)
    rep = run(rates=rates, duration_s=args.duration, trace=args.trace,
              dry_run=args.dry_run)
    for r in rep["rows"]:
        print(r)
    print(f"interactive_p95_lt_batch_p95={rep['interactive_p95_lt_batch_p95']}")
    assert rep["interactive_p95_lt_batch_p95"], \
        "interactive p95 did not beat batch p95 under mixed load"
