"""Paper Fig. 3: the cumulative truncation error is S-shaped (a), and PAS
corrects exactly the high-curvature region (b)."""
import numpy as np

from repro.core.pas import truncation_error_curve

from . import common


def run(nfe: int = 10) -> list[dict]:
    gmm = common.oracle()
    s_ts, (x_c, gt_c), (x_e, gt_e) = common.calib_eval_sets(gmm, nfe)
    pipe = common.pipeline_for(gmm.eps, "ddim", nfe)

    _, xs_plain = pipe.trajectory(x_e, use_pas=False)
    err_plain = np.asarray(truncation_error_curve(xs_plain, gt_e))

    pipe.calibrate(x_t=x_c, gt=gt_c)
    _, xs_pas = pipe.trajectory(x_e)
    err_pas = np.asarray(truncation_error_curve(xs_pas, gt_e))

    rows = [{"step": j, "t": float(s_ts[j]),
             "err_euler": float(err_plain[j]), "err_pas": float(err_pas[j])}
            for j in range(nfe + 1)]
    common.save_table("fig3_truncation", rows, extra={
        "corrected_steps_paper_index": pipe.params.corrected_paper_steps()})

    # S-shape: the middle third of steps contributes the bulk of the growth
    third = nfe // 3
    total = err_plain[-1] - err_plain[0]
    assert err_plain[2 * third] - err_plain[third] > 0.45 * total
    assert err_pas[-1] < 0.5 * err_plain[-1]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
