"""Paper Fig. 3: the cumulative truncation error is S-shaped (a), and PAS
corrects exactly the high-curvature region (b)."""
import numpy as np

from repro.core import pas, solvers

from . import common


def run(nfe: int = 10) -> list[dict]:
    gmm = common.oracle()
    s_ts, (x_c, gt_c), (x_e, gt_e) = common.calib_eval_sets(gmm, nfe)
    sol = solvers.make_solver("ddim", s_ts)

    xs_plain, _ = solvers.sample_trajectory(sol, gmm.eps, x_e)
    err_plain = np.asarray(pas.truncation_error_curve(xs_plain, gt_e))

    cfg = common.default_pas_cfg()
    params, _ = pas.calibrate(sol, gmm.eps, x_c, gt_c, cfg)
    _, xs_pas = pas.pas_sample_trajectory(sol, gmm.eps, x_e, params, cfg)
    err_pas = np.asarray(pas.truncation_error_curve(xs_pas, gt_e))

    rows = [{"step": j, "t": float(s_ts[j]),
             "err_euler": float(err_plain[j]), "err_pas": float(err_pas[j])}
            for j in range(nfe + 1)]
    common.save_table("fig3_truncation", rows, extra={
        "corrected_steps_paper_index": params.corrected_paper_steps()})

    # S-shape: the middle third of steps contributes the bulk of the growth
    third = nfe // 3
    total = err_plain[-1] - err_plain[0]
    assert err_plain[2 * third] - err_plain[third] > 0.45 * total
    assert err_pas[-1] < 0.5 * err_plain[-1]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
