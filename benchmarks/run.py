"""Benchmark aggregator: one module per paper table/figure + the PAS overhead
microbenchmark.  Prints ``name,us_per_call,derived`` CSV per the deliverable
and writes per-table JSON artifacts under benchmarks/artifacts/repro/.

  PYTHONPATH=src python -m benchmarks.run [--only fig2,table2,...] [--fast]
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,fig3,table2,table5,table8,"
                         "table9,table11,fig6,learned,overhead,sharded,"
                         "serve,router,adaptive")
    ap.add_argument("--fast", action="store_true",
                    help="smaller NFE grids (CI mode)")
    args = ap.parse_args()

    from . import (adaptive_nfe, fig2_pca_variance, fig3_truncation,
                   fig6_ablations, learned_denoiser, pas_overhead,
                   serve_latency, serve_router, sharded_throughput,
                   table2_solvers, table5_nfe_sweep, table6_adaptive_steps,
                   table8_tolerance, table9_teacher, table11_l1l2)

    suite = {
        "fig2": lambda: fig2_pca_variance.run(),
        "fig3": lambda: fig3_truncation.run(),
        "table2": lambda: table2_solvers.run((5, 10) if args.fast
                                             else (5, 6, 8, 10)),
        "table5": lambda: table5_nfe_sweep.run((5, 8, 10) if args.fast
                                               else (4, 5, 6, 7, 8, 9, 10)),
        # the adaptive story as one target: the paper tables' corrected-step
        # selection (table6) + the adaptive-NFE engine/ladder curves
        "adaptive": lambda: (
            table6_adaptive_steps.run((5, 10) if args.fast
                                      else (5, 6, 8, 10))
            + adaptive_nfe.run(dry_run=args.fast)["rows"]),
        "table8": lambda: table8_tolerance.run(),
        "table9": lambda: table9_teacher.run(),
        "table11": lambda: table11_l1l2.run(),
        "fig6": lambda: fig6_ablations.run(),
        "learned": lambda: learned_denoiser.run(),
        "overhead": lambda: pas_overhead.run(),
        # --fast routes through dry_run so the CI smoke never overwrites the
        # root-level BENCH_sharded_throughput.json perf record
        "sharded": lambda: sharded_throughput.run(dry_run=args.fast),
        "serve": lambda: serve_latency.run(dry_run=args.fast)["rows"],
        "router": lambda: serve_router.run(dry_run=args.fast)["rows"],
    }
    only = args.only.split(",") if args.only else list(suite)

    print("name,us_per_call,derived")
    failures = 0
    for name in only:
        t0 = time.time()
        try:
            rows = suite[name]()
            us = (time.time() - t0) * 1e6
            derived = f"rows={len(rows)}"
            if name == "overhead":
                ratio = next((r.get("ratio_vs_nfe") for r in rows
                              if "ratio_vs_nfe" in r), "")
                derived += f";pas_basis_vs_nfe_ratio={ratio}"
            if name in ("table2", "table5"):
                best = min((r for r in rows if "err_l2" in r),
                           key=lambda r: r["err_l2"])
                derived += f";best={best['method']}@{best['nfe']}:{best['err_l2']:.4f}"
            print(f"{name},{us:.0f},{derived}", flush=True)
        except Exception as e:
            failures += 1
            print(f"{name},0,FAILED:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
