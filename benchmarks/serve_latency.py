"""Serve-path request latency: sync flush loop vs async scheduler.

A mixed-size request stream (small interactive requests packed between
medium and oversized batch jobs) is served twice through the same warmed
pipeline:

* **sync** — the legacy ``DiffusionServer.serve`` flush loop: every
  response lands when the whole list finishes, so per-request latency is
  the full wall time for everyone;
* **async** — the ``runtime.scheduler.ServeScheduler``: requests are
  submitted individually, flushes dispatch without blocking
  (double-buffered device futures), and each request completes when its
  last chunk retires — early requests stop paying for late ones.

Recorded per mode: p50/p95/p99 request latency (submit -> last chunk) and
samples/sec over the stream, into a root-level ``BENCH_serve_latency.json``
so the serving stack's latency trajectory is tracked PR over PR.  The run
also asserts the acceptance contract: the async facade's responses are
**bit-identical** to the sync loop's on the same seeds (recorded as
``bitwise_identical``).

On this CPU-only container both modes share the same cores, so the async
win is scheduling (earlier completion), not extra device throughput; the
JSON records ``backend`` so TPU runs are distinguishable.

  PYTHONPATH=src python -m benchmarks.serve_latency [--repeat 3] [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_serve_latency.json"

DIM = 64
NFE = 10
MAX_BATCH = 64
# mixed request sizes: interactive singles, mid packs, one oversized job
SIZES = [4, 16, 96, 8, 4, 32, 4, 160, 8, 16, 4, 48]


def _percentiles(lat_s: list[float]) -> dict:
    lat = np.asarray(sorted(lat_s))
    return {f"p{p}_ms": round(float(np.percentile(lat, p)) * 1e3, 2)
            for p in (50, 95, 99)}


def _requests(sizes):
    from repro.api import Request
    return [Request(seed=i, n_samples=n) for i, n in enumerate(sizes)]


def _serve_sync(server, sizes):
    """One pass through the legacy loop; every request waits for the list."""
    t0 = time.perf_counter()
    outs = server.serve(_requests(sizes))
    wall = time.perf_counter() - t0
    return outs, [wall] * len(sizes), wall


def _serve_async(server, sizes):
    """One pass through the scheduler; per-request completion times."""
    t0 = time.perf_counter()
    handles = [server.submit(r) for r in _requests(sizes)]
    server.drain(timeout=600)
    outs = [h.result() for h in handles]
    wall = time.perf_counter() - t0
    return outs, [h.latency_s for h in handles], wall


def run(sizes=None, repeat: int = 3, nfe: int = NFE,
        max_batch: int = MAX_BATCH, dry_run: bool = False) -> dict:
    from repro.core import two_mode_gmm
    from repro.api import DiffusionServer, ServeConfig

    if sizes is None:
        sizes = SIZES
    if dry_run:
        sizes, repeat, nfe = [4, 20, 8], 1, 5

    gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)

    def server_for(mode: str) -> DiffusionServer:
        return DiffusionServer(gmm.eps, DIM, ServeConfig(
            nfe=nfe, solver="ddim", max_batch=max_batch, use_pas=False,
            scheduler=mode))

    sync_srv, async_srv = server_for("sync"), server_for("async")
    # warm both paths (one shared compiled program: same spec, same model)
    sync_srv.serve(_requests([max_batch]))
    async_srv.serve(_requests([max_batch]))

    # bitwise parity of the async facade with the legacy loop, same seeds
    outs_sync, _, _ = _serve_sync(sync_srv, sizes)
    outs_async, _, _ = _serve_async(async_srv, sizes)
    bitwise = all(np.array_equal(a, b)
                  for a, b in zip(outs_sync, outs_async))

    rows = []
    for mode, srv, one_pass in (("sync", sync_srv, _serve_sync),
                                ("async", async_srv, _serve_async)):
        lat_all: list[float] = []
        walls: list[float] = []
        for _ in range(repeat):
            _, lat, wall = one_pass(srv, sizes)
            lat_all.extend(lat)
            walls.append(wall)
        rows.append({
            "mode": mode, "nfe": nfe, "max_batch": max_batch,
            "requests": len(sizes), "samples": int(sum(sizes)),
            **_percentiles(lat_all),
            "samples_per_s": round(sum(sizes) * repeat / sum(walls), 1),
        })

    async_srv.close()
    by_mode = {r["mode"]: r for r in rows}
    report = {
        "rows": rows,
        "sizes": list(sizes),
        "bitwise_identical": bool(bitwise),
        "async_p95_speedup": round(
            by_mode["sync"]["p95_ms"] / by_mode["async"]["p95_ms"], 2),
        "backend": __import__("jax").default_backend(),
        "generated": time.strftime("%F %T"),
    }
    if not dry_run:               # smoke runs don't pollute the perf record
        OUT.write_text(json.dumps(report, indent=1))
        from . import common
        common.save_table("serve_latency", rows,
                          extra={"backend": report["backend"],
                                 "bitwise_identical": report[
                                     "bitwise_identical"]})
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny request stream, 1 repeat (CI smoke)")
    args = ap.parse_args()
    rep = run(repeat=args.repeat, dry_run=args.dry_run)
    for r in rep["rows"]:
        print(r)
    print(f"bitwise_identical={rep['bitwise_identical']} "
          f"async_p95_speedup={rep['async_p95_speedup']}x")
    assert rep["bitwise_identical"], "async facade diverged from sync loop"
