"""Adaptive-NFE subsystem: error-controlled engine, spec plumbing, serving.

The acceptance contract of the adaptive engine (``repro.engine.adaptive``)
and its spec/serve integration:

* **spec plumbing** — ``ErrorControlConfig`` JSON round-trips inside
  ``SamplerSpec``; ``engine_key`` stays the legacy 5-tuple when
  ``error_control`` is None (existing artifacts/caches unaffected) and
  extends to a 6-tuple when set;
* **rtol=0 bit-identity** — a disabled config delegates to the *same
  compiled object* as the fixed-grid engine, so outputs are bit-identical,
  plain and PAS-corrected alike;
* **controller parity** — the compiled fixed-iteration masked scan
  reproduces the eager single-sample reference loop exactly: same
  accept/reject counters, matching states;
* **honest accounting** — per-sample ``nfe == 2 * (n_accept + n_reject)``,
  bounded by the scan capacity; the active-mask trace is monotone (no lane
  resumes after finishing); the serve stack's ``nfe_total`` equals the sum
  of per-sample counters, not a nominal constant.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (DiffusionServer, ErrorControlConfig, Pipeline,
                       Request, SamplerSpec, ServeConfig)
from repro.core import analytic, pas
from repro.core.error_control import adaptive_sample_reference
from repro.engine import get_adaptive_engine_for_spec, get_engine_for_spec

DIM = 16
NFE = 8


@pytest.fixture(scope="module")
def gmm():
    return analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)


def _spec(rtol=0.05, **kw) -> SamplerSpec:
    return SamplerSpec(solver="ddim", nfe=NFE,
                       error_control=ErrorControlConfig(rtol=rtol, **kw))


def _x(gmm, n=6, seed=0):
    return gmm.sample_prior(jax.random.key(seed), n, 80.0)


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------


def test_error_control_config_roundtrip():
    ec = ErrorControlConfig(rtol=0.03, atol=0.01, pcoeff=0.2, max_iters=32)
    assert ErrorControlConfig.from_dict(
        json.loads(json.dumps(ec.to_dict()))) == ec
    assert ec.enabled
    assert not ErrorControlConfig(rtol=0.0).enabled


@pytest.mark.parametrize("bad", [
    dict(h_init=0.0), dict(accept_safety=0.0), dict(accept_safety=3.0),
    dict(order=0), dict(max_iters=0), dict(rtol=0.1, atol=-1.0),
])
def test_error_control_config_validation(bad):
    with pytest.raises(ValueError):
        ErrorControlConfig(**bad)


def test_spec_roundtrip_with_error_control():
    spec = _spec(rtol=0.02, max_iters=24)
    back = SamplerSpec.from_json(spec.to_json())
    assert back == spec
    assert back.error_control == spec.error_control


def test_engine_key_stable_without_error_control():
    """Fixed-NFE specs keep the legacy 5-tuple key: existing artifacts and
    engine-cache entries are untouched by the adaptive field."""
    spec = SamplerSpec(solver="ddim", nfe=NFE)
    key = spec.engine_key
    assert len(key) == 5
    assert key == (spec.solver, spec.nfe, spec.schedule, spec.dtype,
                   spec.mesh)
    adaptive_key = _spec().engine_key
    assert len(adaptive_key) == 6
    assert adaptive_key[:5] == key


def test_spec_from_dict_legacy_payload():
    """A pre-adaptive serialized spec (no error_control key) still loads."""
    d = SamplerSpec(solver="ddim", nfe=NFE).to_dict()
    d.pop("error_control", None)
    spec = SamplerSpec.from_dict(d)
    assert spec.error_control is None
    assert len(spec.engine_key) == 5


# ---------------------------------------------------------------------------
# rtol=0 bit-identity with the fixed-grid engine
# ---------------------------------------------------------------------------


def test_rtol_zero_delegates_to_fixed_engine_bit_identical(gmm):
    spec = _spec(rtol=0.0)
    eng = get_adaptive_engine_for_spec(spec)
    fixed = get_engine_for_spec(spec.replace(error_control=None))
    assert eng.fixed is fixed          # same compiled object, by construction
    x_t = _x(gmm)
    y_a = eng.sample(gmm.eps, x_t)
    y_f = fixed.sample(gmm.eps, x_t)
    assert bool(jnp.all(y_a == y_f))


def test_rtol_zero_bit_identical_with_pas(gmm):
    active = np.zeros(NFE, bool)
    active[[1, 3]] = True
    coords = np.zeros((NFE, 4), np.float32)
    coords[1] = [1.0, 0.05, 0.0, 0.0]
    coords[3] = [0.98, -0.04, 0.0, 0.0]
    params = pas.PASParams(active=active, coords=jnp.asarray(coords))
    spec = _spec(rtol=0.0)
    x_t = _x(gmm)
    y_a = get_adaptive_engine_for_spec(spec).sample(
        gmm.eps, x_t, params=params, cfg=spec.pas)
    y_f = get_engine_for_spec(spec.replace(error_control=None)).sample(
        gmm.eps, x_t, params=params, cfg=spec.pas)
    assert bool(jnp.all(y_a == y_f))


# ---------------------------------------------------------------------------
# the compiled scan: mask monotonicity, honest counters, reference parity
# ---------------------------------------------------------------------------


def test_active_mask_monotone_and_counters_consistent(gmm):
    eng = get_adaptive_engine_for_spec(_spec())
    x, info = eng.sample_with_info(gmm.eps, _x(gmm, n=8))
    nfe = np.asarray(info["nfe"])
    acc = np.asarray(info["n_accept"])
    rej = np.asarray(info["n_reject"])
    trace = np.asarray(info["alive_trace"])       # (max_iters, B)
    ec = _spec().error_control
    # nfe counts exactly the evals executed: 2 per embedded step, accepted
    # or rejected, never the scan's fixed-iteration capacity
    assert np.array_equal(nfe, 2 * (acc + rej))
    assert np.all(nfe <= 2 * ec.max_iters)
    assert np.all(nfe >= 2)
    assert info["scan_evals"] == 2 * ec.max_iters * 8
    # once a lane goes inactive it never resumes
    alive_int = trace.astype(np.int8)
    assert np.all(np.diff(alive_int, axis=0) <= 0)
    # iterations executed per lane == accepted + rejected proposals
    assert np.array_equal(alive_int.sum(axis=0), acc + rej)
    assert np.all(np.asarray(info["finished"]))
    assert np.allclose(np.asarray(info["t"]), eng.t_min)


def test_compiled_matches_eager_reference(gmm):
    """The compiled masked scan reproduces the eager per-sample loop: the
    exact accept/reject sequence and matching final states."""
    spec = _spec()
    eng = get_adaptive_engine_for_spec(spec)
    x_t = _x(gmm, n=4, seed=3)
    x, info = eng.sample_with_info(gmm.eps, x_t)
    acc = np.asarray(info["n_accept"])
    rej = np.asarray(info["n_reject"])
    for b in range(x_t.shape[0]):
        x_ref, ref = adaptive_sample_reference(
            gmm.eps, x_t[b], float(eng.t_min), float(eng.t_max),
            spec.error_control)
        assert ref["finished"]
        assert (acc[b], rej[b]) == (ref["n_accept"], ref["n_reject"]), b
        np.testing.assert_allclose(np.asarray(x[b]), np.asarray(x_ref),
                                   rtol=1e-4, atol=1e-4)


def test_adaptive_converges_to_teacher(gmm):
    """Tightening rtol drives the adaptive solution toward the high-NFE
    teacher while spending more evals."""
    x_t = _x(gmm, n=8, seed=7)
    ref = Pipeline.from_spec(SamplerSpec(solver="heun", nfe=80), gmm.eps,
                             dim=DIM).sample(x_t, use_pas=False)

    def point(rtol):
        eng = get_adaptive_engine_for_spec(_spec(rtol=rtol, max_iters=96))
        x, info = eng.sample_with_info(gmm.eps, x_t)
        err = float(jnp.mean(jnp.linalg.norm(x - ref, axis=-1)))
        return err, float(np.asarray(info["nfe"]).mean())

    err_loose, nfe_loose = point(0.05)
    err_tight, nfe_tight = point(0.005)
    assert err_tight < err_loose
    assert nfe_tight > nfe_loose
    assert err_tight < 0.2


def test_pas_correction_on_adaptive_grid(gmm):
    """Gated coords change the adaptive output; all-inactive params don't."""
    spec = _spec()
    eng = get_adaptive_engine_for_spec(spec)
    x_t = _x(gmm)
    plain, _ = eng.sample_with_info(gmm.eps, x_t)
    active = np.zeros(NFE, bool)
    active[[2, 4]] = True
    coords = np.zeros((NFE, 4), np.float32)
    coords[2] = [1.0, 0.05, 0.0, 0.0]
    coords[4] = [0.97, -0.03, 0.02, 0.0]
    params = pas.PASParams(active=active, coords=jnp.asarray(coords))
    corrected, info = eng.sample_with_info(gmm.eps, x_t, params=params,
                                           cfg=spec.pas)
    assert not np.allclose(np.asarray(plain), np.asarray(corrected))
    assert np.all(np.asarray(info["finished"]))
    inert = pas.PASParams(active=np.zeros(NFE, bool),
                          coords=jnp.zeros((NFE, 4), jnp.float32))
    uncorrected, _ = eng.sample_with_info(gmm.eps, x_t, params=inert,
                                          cfg=spec.pas)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(uncorrected),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# pipeline + serve integration
# ---------------------------------------------------------------------------


def test_pipeline_adaptive_dispatch_and_evals(gmm):
    fixed = Pipeline.from_spec(SamplerSpec(solver="heun", nfe=NFE), gmm.eps,
                               dim=DIM)
    assert not fixed.is_adaptive
    assert fixed.evals_per_sample == 2 * NFE       # evals, not steps
    pipe = Pipeline.from_spec(_spec(), gmm.eps, dim=DIM)
    assert pipe.is_adaptive
    assert pipe.evals_per_sample == 2 * pipe.spec.error_control.max_iters
    x_t = _x(gmm)
    y = pipe.sample(x_t, use_pas=False)
    assert y.shape == x_t.shape
    info = pipe.last_adaptive_info
    assert info is not None and np.all(np.asarray(info["finished"]))
    y2, valid, evals = pipe.sample_async(_x(gmm), use_pas=False,
                                         want_evals=True)
    assert valid.all() and evals.shape[0] == y2.shape[0]
    assert np.array_equal(np.asarray(evals), np.asarray(info["nfe"]))


def test_serve_nfe_total_sums_actual_evals(gmm):
    """The serve stack's nfe_total is the per-sample honest counter summed at
    retire time, identical through the async scheduler and the sync loop."""
    pipe = Pipeline.from_spec(_spec(), gmm.eps, dim=DIM)
    reqs = [Request(seed=0, n_samples=4), Request(seed=1, n_samples=3)]
    srv = DiffusionServer.from_pipeline(pipe)
    try:
        outs = srv.serve(reqs)
    finally:
        srv.close()
    sync = DiffusionServer.from_pipeline(
        pipe, ServeConfig.for_spec(pipe.spec, scheduler="sync"))
    outs_sync = sync.serve(reqs)
    assert srv.stats["nfe_total"] == sync.stats["nfe_total"] > 0
    # every flushed row ran a data-dependent number of evals; the total can
    # never be the fixed-grid nominal (7 rows x nfe) by construction here
    assert srv.stats["nfe_total"] >= 2 * srv.stats["samples"]
    for a, b in zip(outs, outs_sync):
        assert np.array_equal(a, b)


def test_disabled_error_control_pipeline_matches_plain(gmm):
    """A spec carrying a disabled (rtol=0) config samples bit-identically to
    one carrying none, through the Pipeline surface."""
    x_t = _x(gmm)
    y_none = Pipeline.from_spec(SamplerSpec(solver="ddim", nfe=NFE), gmm.eps,
                                dim=DIM).sample(x_t, use_pas=False)
    pipe = Pipeline.from_spec(_spec(rtol=0.0), gmm.eps, dim=DIM)
    assert not pipe.is_adaptive        # disabled config = fixed-grid path
    y_zero = pipe.sample(x_t, use_pas=False)
    assert np.array_equal(np.asarray(y_none), np.asarray(y_zero))
