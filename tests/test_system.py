"""End-to-end behaviour tests for the paper's system.

The full pipeline: calibrate PAS offline -> serialise the ~10 parameters ->
hot-swap them into the serving loop -> serve batched requests -> verify the
quality gain and that the correction round-trips through checkpointing.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core import (PASConfig, PASParams, calibrate,
                        ground_truth_trajectory, nested_teacher_schedule,
                        two_mode_gmm)
from repro.core import solvers
from repro.runtime import DiffusionServer, Request, ServeConfig

DIM, NFE = 64, 10


def _setup():
    gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)
    s_ts, t_ts, m = nested_teacher_schedule(NFE, 100, 0.002, 80.0)
    x_c = gmm.sample_prior(jax.random.key(0), 256, 80.0)
    gt = ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_c)
    return gmm, s_ts, t_ts, m, x_c, gt


def test_end_to_end_calibrate_serialize_serve(tmp_path):
    gmm, s_ts, t_ts, m, x_c, gt = _setup()
    cfg = ServeConfig(nfe=NFE, use_pas=True,
                      pas=PASConfig(n_sgd_iters=200, val_fraction=0.25))
    server = DiffusionServer(gmm.eps, DIM, cfg)

    params, diag = calibrate(server.solver, gmm.eps, x_c, gt, cfg.pas)
    assert 1 <= params.n_stored_params <= 24      # "approximately 10"

    # round-trip the learned parameters through the checkpoint system
    ckpt.save(tmp_path, 1, {"active": jnp.asarray(params.active),
                            "coords": params.coords})
    restored, _ = ckpt.restore(tmp_path, {"active": jnp.asarray(params.active),
                                          "coords": params.coords})
    params2 = PASParams(active=np.asarray(restored["active"]),
                        coords=restored["coords"])
    assert params2.corrected_paper_steps() == params.corrected_paper_steps()

    server.set_pas(params2)
    reqs = [Request(seed=7, n_samples=32), Request(seed=8, n_samples=16)]
    outs_pas = server.serve(reqs)

    server_plain = DiffusionServer(gmm.eps, DIM,
                                   ServeConfig(nfe=NFE, use_pas=False))
    outs_plain = server_plain.serve(reqs)

    # quality: both batches closer to the teacher with PAS
    for req, o_pas, o_plain in zip(reqs, outs_pas, outs_plain):
        x_t = 80.0 * jax.random.normal(jax.random.key(req.seed),
                                       (req.n_samples, DIM))
        gt_req = ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_t)
        e_pas = float(np.mean(np.linalg.norm(o_pas - np.asarray(gt_req[-1]),
                                             axis=-1)))
        e_plain = float(np.mean(np.linalg.norm(o_plain - np.asarray(gt_req[-1]),
                                               axis=-1)))
        assert e_pas < 0.5 * e_plain, (e_pas, e_plain)


def test_pas_is_plug_and_play_across_solvers():
    """The same server serves ddim and ipndm3 with per-solver coordinates."""
    gmm, s_ts, t_ts, m, x_c, gt = _setup()
    for name in ("ddim", "ipndm3"):
        cfg = ServeConfig(nfe=NFE, solver=name, use_pas=True,
                          pas=PASConfig(n_sgd_iters=150, val_fraction=0.25))
        server = DiffusionServer(gmm.eps, DIM, cfg)
        params, _ = calibrate(server.solver, gmm.eps, x_c, gt, cfg.pas)
        server.set_pas(params)
        outs = server.serve([Request(seed=1, n_samples=8)])
        assert outs[0].shape == (8, DIM)
        assert np.isfinite(outs[0]).all()


def test_trajectory_interpolation_preserved():
    """Paper §3.5: PAS preserves the ODE trajectory family — the corrected
    endpoint stays close to the *true* endpoint of its own trajectory, so
    noise-space interpolation still lands in the teacher's mode basins."""
    gmm, s_ts, t_ts, m, x_c, gt = _setup()
    cfg = PASConfig(n_sgd_iters=200, val_fraction=0.25)
    sol = solvers.make_solver("ddim", s_ts)
    params, _ = calibrate(sol, gmm.eps, x_c, gt, cfg)

    from repro.core import pas as pas_mod
    a = 80.0 * jax.random.normal(jax.random.key(3), (1, DIM))
    b = 80.0 * jax.random.normal(jax.random.key(4), (1, DIM))
    lam = jnp.linspace(0.0, 1.0, 9)[:, None]
    x_interp = (1 - lam) * a + lam * b
    gt_i = ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_interp)
    x0, _ = pas_mod.pas_sample_trajectory(sol, gmm.eps, x_interp, params, cfg)
    # same mode (sign of coordinate 0) as the exact solution, for every lambda
    assert np.array_equal(np.sign(np.asarray(x0[:, 0])),
                          np.sign(np.asarray(gt_i[-1][:, 0])))
