"""Zoo-wide batched calibration: one teacher, one compiled Algorithm-1 run.

The fast tests pin the host-side contracts: spec validation, the lcm grid
and its per-spec strides (the polynomial family is closed under
sub-indexing), the shared-teacher refinement bump, the teacher-eval ledger,
and the vmap grouping rule.  The slow tests compile the real programs and
assert the numerics contract from ``repro.engine.zoo``: given the same
ground-truth trajectory, the zoo program reproduces each spec's own
``_calibrate_body`` — sequential bodies bit-exactly, vmapped groups within
float tolerance — and ``NFELadder.calibrate`` rides the shared-teacher path
end to end (artifact family included).
"""
import numpy as np
import pytest

import jax

from repro.api import PASConfig, SamplerSpec, ScheduleSpec, TeacherSpec
from repro.core import analytic
from repro.engine.zoo import ZooCalibrationEngine, _lcm, calibrate_zoo
from repro.runtime import NFELadder

DIM = 16


@pytest.fixture(scope="module")
def gmm():
    return analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)


def _spec(solver="ddim", nfe=2, teacher_nfe=12, sgd=30, **kw):
    return SamplerSpec(solver=solver, nfe=nfe,
                       teacher=TeacherSpec(nfe=teacher_nfe),
                       pas=PASConfig(n_sgd_iters=sgd), **kw)


# ---------------------------------------------------------------------------
# host-side contracts (no compilation)
# ---------------------------------------------------------------------------


def test_zoo_validation():
    with pytest.raises(ValueError, match="at least one"):
        ZooCalibrationEngine({})
    with pytest.raises(ValueError, match="share teacher"):
        ZooCalibrationEngine({"a": _spec(nfe=2, teacher_nfe=12),
                              "b": _spec(nfe=3, teacher_nfe=24)})
    with pytest.raises(ValueError, match="polynomial"):
        ZooCalibrationEngine({"a": _spec(
            nfe=2, schedule=ScheduleSpec(kind="linear"))})


def test_lcm_and_strided_grid_nesting():
    assert _lcm((5, 8, 10)) == 40
    zoo = ZooCalibrationEngine({"n2": _spec(nfe=2), "n3": _spec(nfe=3)})
    assert zoo.L == 6 and zoo.strides == {"n2": 3, "n3": 2}
    # the polynomial grid with L intervals contains every rung grid as a
    # strided subset — this nesting is what makes ONE teacher sufficient
    ts_shared = np.asarray(zoo._teacher_engine.solver.ts)
    for k, eng in zoo.engines.items():
        np.testing.assert_allclose(ts_shared[::zoo.strides[k]],
                                   np.asarray(eng.solver.ts),
                                   rtol=1e-12, atol=1e-12)


def test_teacher_eval_ledger():
    """nfes (5, 8, 10) under a heun@100 teacher: the shared trajectory costs
    240 evals where the per-spec path paid 608 — counted once, not per spec."""
    zoo = ZooCalibrationEngine({f"nfe{n}": _spec(nfe=n, teacher_nfe=100)
                                for n in (5, 8, 10)})
    assert zoo.L == 40
    assert zoo.teacher_evals == 240
    per = zoo.teacher_evals_per_spec
    assert sum(per.values()) == 608
    assert zoo.teacher_evals < sum(per.values())


def test_shared_teacher_refined_past_coarse_teacher():
    """When the shared L-grid is already at least teacher-fine, the zoo bumps
    the shared teacher to 2L rather than degrade below any rung's teacher."""
    zoo = ZooCalibrationEngine({"n4": _spec(nfe=4, teacher_nfe=8),
                                "n6": _spec(nfe=6, teacher_nfe=8)})
    assert zoo.L == 12
    assert zoo._shared_spec.teacher.nfe == 24
    # every rung's own refined-teacher step count is dominated
    grid_steps = zoo.teacher_evals
    for k in zoo.specs:
        assert grid_steps >= zoo.teacher_evals_per_spec[k]


def test_vmap_grouping_rule(monkeypatch):
    zoo = ZooCalibrationEngine({"d4": _spec("ddim", 4),
                                "i4": _spec("ipndm2", 4),
                                "d8": _spec("ddim", 8)})
    groups = sorted(sorted(g) for g in zoo._vmap_groups())
    assert groups == [["d4", "i4"], ["d8"]]
    # sharded zoos never vmap (the vmapped body skips per-step sharding
    # constraints); a bound mesh forces every body sequential
    for eng in zoo.engines.values():
        monkeypatch.setattr(eng.sampling, "mesh", object(), raising=True)
    assert all(len(g) == 1 for g in zoo._vmap_groups())


# ---------------------------------------------------------------------------
# compiled parity (slow)
# ---------------------------------------------------------------------------


def _reference(zoo, key, eps_fn, x_t, gt_shared):
    """The per-spec path fed the SAME ground truth: each engine's own
    ``_calibrate_body`` + ``_postprocess``, exactly what ``calibrate()``
    would run spec by spec."""
    eng = zoo.engines[key]
    gt_k = zoo.gt_for(key, gt_shared)
    outs = jax.jit(eng._calibrate_body(eps_fn))(x_t, gt_k)
    b = int(x_t.shape[0])
    n_val = int(round(b * eng.cfg.val_fraction))
    va = slice(0, n_val) if n_val > 0 else slice(None)
    return eng._postprocess(eps_fn, outs,
                            x_t[va] if eng.cfg.final_gate else None,
                            gt_k[-1][va])


@pytest.mark.slow
def test_zoo_matches_per_spec_given_same_gt(gmm):
    zoo = ZooCalibrationEngine({"n2": _spec(nfe=2), "n3": _spec(nfe=3)})
    x = gmm.sample_prior(jax.random.key(0), 64, 80.0)
    results = zoo.calibrate(gmm.eps, x)
    gt = zoo.shared_teacher(gmm.eps, x)
    for key in ("n2", "n3"):
        params, diag = results[key]
        p_ref, d_ref = _reference(zoo, key, gmm.eps, x, gt)
        np.testing.assert_array_equal(np.asarray(params.active),
                                      np.asarray(p_ref.active))
        np.testing.assert_array_equal(np.asarray(params.coords),
                                      np.asarray(p_ref.coords))
        assert diag["zoo"]["teacher_shared"] is True
        assert (diag["zoo"]["teacher_evals"]
                < diag["zoo"]["teacher_evals_per_spec_sum"])
        assert (diag["corrected_steps_paper_index"]
                == d_ref["corrected_steps_paper_index"])
        assert diag["final_l2_to_gt"] == d_ref["final_l2_to_gt"]


@pytest.mark.slow
def test_vmapped_group_parity(gmm):
    """Same-NFE specs share one vmapped trace; the traced-coefficient-table
    body must match each spec's own closure-constant body."""
    specs = {"d3": _spec("ddim", 3), "i3": _spec("ipndm2", 3)}
    zoo = ZooCalibrationEngine(specs)
    assert [sorted(g) for g in zoo._vmap_groups()] == [["d3", "i3"]]
    x = gmm.sample_prior(jax.random.key(1), 64, 80.0)
    results = zoo.calibrate(gmm.eps, x)
    gt = zoo.shared_teacher(gmm.eps, x)
    for key in specs:
        params, _ = results[key]
        p_ref, _ = _reference(zoo, key, gmm.eps, x, gt)
        np.testing.assert_array_equal(np.asarray(params.active),
                                      np.asarray(p_ref.active))
        np.testing.assert_allclose(np.asarray(params.coords),
                                   np.asarray(p_ref.coords),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_calibrate_zoo_helper(gmm):
    x = gmm.sample_prior(jax.random.key(2), 32, 80.0)
    out = calibrate_zoo({"n2": _spec(nfe=2)}, gmm.eps, x)
    params, diag = out["n2"]
    assert params.active.shape == (2,)
    assert diag["zoo"]["shared_grid_nfe"] == 2


@pytest.mark.slow
def test_ladder_rides_shared_teacher(gmm, tmp_path):
    ladder = NFELadder(_spec(nfe=6), nfes=(2, 3))
    router = ladder.build_router(gmm.eps, dim=DIM)
    ladder.calibrate(router, key=jax.random.key(0), batch=64,
                     artifact_dir=tmp_path)
    for name in ("nfe2", "nfe3"):
        pipe = router.pipelines[name]
        assert pipe.calibrated
        assert pipe.diag["zoo"]["teacher_shared"] is True
        assert (tmp_path / name).exists()
    # the artifact family round-trips into an identically calibrated router
    reloaded = NFELadder.from_manifest(tmp_path)
    router2 = reloaded.build_router(gmm.eps, dim=DIM, artifact_dir=tmp_path)
    for name in ("nfe2", "nfe3"):
        np.testing.assert_array_equal(
            np.asarray(router.pipelines[name].params.coords),
            np.asarray(router2.pipelines[name].params.coords))
    # opting out (or a single uncalibrated rung) falls back to per-rung
    ladder_f = NFELadder(_spec(nfe=6), nfes=(2, 3))
    router_f = ladder_f.build_router(gmm.eps, dim=DIM)
    ladder_f.calibrate(router_f, key=jax.random.key(0), batch=64,
                       shared_teacher=False)
    pipe_f = router_f.pipelines["nfe2"]
    assert pipe_f.calibrated and "zoo" not in pipe_f.diag
