"""CalibrationEngine: fused Algorithm 1 vs the per-step reference loop.

Parity contract (mirrors tests/test_engine.py for sampling): the fused
program must reproduce ``pas.calibrate_reference`` *behaviourally* — same
adopted step set, same stored-parameter count, coordinates allclose — not
bit-for-bit.  Bitwise equality is impossible by construction: the reference
dispatches eagerly between steps while the engine fuses the whole algorithm
into one XLA program, and near-degenerate PCA components (early steps, when
the Q buffer holds one or two rows) amplify last-ulp differences through the
SGD scan (the same effect the engine-parity suite documents for sampling).
Adoption decisions carry the tolerance margin, so they are stable.

Sharded calibration has the same caveat one level up: sampling is
bit-identical under DP because nothing crosses batch rows, but calibration's
SGD loss and adoption metrics *reduce over the batch*, and a partitioned
reduction reassociates (local partials + all-reduce).  The dp=8 contract is
therefore: identical adopted steps and gate decisions, coordinates tightly
allclose, teacher trajectories bit-identical (those stay row-parallel).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Pipeline, SamplerSpec, ScheduleSpec
from repro.core import analytic, pas, schedules, solvers
from repro.core.pas import PASConfig, PASParams
from repro.engine import (CalibrationEngine, calibration_engine_cache_stats,
                          calibration_engine_for_solver,
                          get_calibration_engine_for_spec)

DIM, NFE, BATCH = 32, 8, 96
T_MIN, T_MAX = 0.002, 80.0
TEACHER_NFE = 40

CFG = PASConfig(lr=1e-2, n_sgd_iters=80, tolerance=1e-4, loss="l1",
                val_fraction=0.25, final_gate=True)

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="module")
def setup():
    gmm = analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)
    s_ts, t_ts, m = schedules.nested_teacher_schedule(
        NFE, TEACHER_NFE, T_MIN, T_MAX)
    x_t = gmm.sample_prior(jax.random.key(0), BATCH, T_MAX)
    gt = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_t)
    return gmm, s_ts, x_t, gt


# ---------------------------------------------------------------------------
# fused vs reference parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("solver_name", ["ddim", "ipndm4"])
def test_fused_matches_reference(setup, solver_name):
    """Same adopted step set, coords allclose, identical stored params."""
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver(solver_name, s_ts)

    p_ref, d_ref = pas.calibrate_reference(sol, gmm.eps, x_t, gt, CFG)
    eng = calibration_engine_for_solver(sol, CFG)
    p_fused, d_fused = eng.calibrate(gmm.eps, x_t, gt)

    np.testing.assert_array_equal(p_fused.active, p_ref.active)
    assert p_fused.n_stored_params == p_ref.n_stored_params
    # coords tolerance: degenerate early-step PCA components inject eager-vs-
    # fused noise that the SGD scan integrates (module docstring); adopted
    # coordinates are O(1) and agree to ~1e-2
    np.testing.assert_allclose(np.asarray(p_fused.coords),
                               np.asarray(p_ref.coords), rtol=0, atol=2e-2)
    assert d_fused.get("final_gate_dropped") == d_ref.get("final_gate_dropped")
    assert set(d_fused) == set(d_ref)
    assert len(d_fused["loss_before"]) == len(d_ref["loss_before"]) == NFE
    assert (d_fused["corrected_steps_paper_index"]
            == d_ref["corrected_steps_paper_index"])


def test_fused_diag_values_track_reference(setup):
    """The on-device adoption metrics agree with the reference up to the
    first adopted step (beyond it the carried state embeds the SGD-trained
    coordinates, whose eager-vs-fused noise compounds — decisions still
    match, asserted above)."""
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver("ddim", s_ts)
    p_ref, d_ref = pas.calibrate_reference(sol, gmm.eps, x_t, gt, CFG)
    eng = calibration_engine_for_solver(sol, CFG)
    _, d_fused = eng.calibrate(gmm.eps, x_t, gt)
    first = int(np.nonzero(p_ref.active)[0][0]) if p_ref.active.any() else NFE
    np.testing.assert_allclose(d_fused["loss_before"][:first + 1],
                               d_ref["loss_before"][:first + 1], rtol=5e-2)
    assert all(np.isfinite(v) for v in d_fused["loss_after"])
    assert d_fused["n_stored_params"] == d_ref["n_stored_params"]


def test_legacy_shim_is_the_engine_bit_identical(setup, tmp_path):
    """ISSUE acceptance: ``Pipeline.calibrate`` and the ``pas.calibrate``
    legacy shim share one compiled program — artifacts sample bit-identically."""
    gmm, s_ts, x_t, gt = setup
    spec = SamplerSpec(solver="ddim", nfe=NFE,
                       schedule=ScheduleSpec(t_min=T_MIN, t_max=T_MAX),
                       pas=CFG)
    pipe = Pipeline.from_spec(spec, gmm.eps, dim=DIM)
    pipe.calibrate(x_t=x_t, gt=gt)

    p_shim, _ = pas.calibrate(spec.make_solver(), gmm.eps, x_t, gt, CFG)

    np.testing.assert_array_equal(pipe.params.active, p_shim.active)
    np.testing.assert_array_equal(np.asarray(pipe.params.coords),
                                  np.asarray(p_shim.coords))

    pipe.save(tmp_path)
    pipe2 = Pipeline.load(tmp_path, gmm.eps, dim=DIM)
    x_eval = gmm.sample_prior(jax.random.key(5), 32, T_MAX)
    a = np.asarray(pipe2.sample(x_eval))
    b = np.asarray(Pipeline(spec, gmm.eps, dim=DIM,
                            params=p_shim).sample(x_eval))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# final-state gate
# ---------------------------------------------------------------------------


def _seed_gate(solver, eps_fn, x_gate, gt_gate, params, cfg):
    """The pre-engine gate, verbatim: eager seed-path rollouts per trial."""
    x_plain = solvers.sample(solver, eps_fn, x_gate)
    e_plain = float(jnp.mean(jnp.linalg.norm(x_plain - gt_gate[-1], axis=-1)))
    active = params.active.copy()
    dropped = []
    while active.any():
        trial = PASParams(active=active, coords=params.coords)
        x_pas, _ = pas.pas_sample_trajectory(solver, eps_fn, x_gate, trial, cfg)
        e_pas = float(jnp.mean(jnp.linalg.norm(x_pas - gt_gate[-1], axis=-1)))
        if e_pas <= e_plain * (1.0 + 1e-4):
            break
        j_drop = int(np.max(np.nonzero(active)[0]))
        active[j_drop] = False
        dropped.append(j_drop)
    return PASParams(active=active, coords=params.coords), dropped


def _harmful_params():
    """A correction pattern the gate must prune: step 2 is a no-op correction
    (coords [1,0,0,0] reproduces d exactly: u_1 = d/||d||), step 5 inflates
    the direction by 40% — unambiguously harmful end to end."""
    active = np.zeros(NFE, dtype=bool)
    active[[2, 5]] = True
    coords = np.zeros((NFE, 4), np.float32)
    coords[2] = [1.0, 0.0, 0.0, 0.0]
    coords[5] = [1.4, 0.0, 0.0, 0.0]
    return PASParams(active=active, coords=jnp.asarray(coords))


def test_gate_result_unchanged_vs_seed_gate(setup):
    """Satellite regression: routing the gate through the cached
    SamplingEngine (and the fused candidate scan) changes no decision."""
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver("ddim", s_ts)
    cfg = PASConfig(final_gate=True)
    params = _harmful_params()
    x_gate, gt_gate = x_t[:24], gt[:, :24]

    p_seed, dropped_seed = _seed_gate(sol, gmm.eps, x_gate, gt_gate,
                                      params, cfg)
    p_eng, dropped_eng = pas._final_state_gate(sol, gmm.eps, x_gate, gt_gate,
                                               params, cfg)
    np.testing.assert_array_equal(p_eng.active, p_seed.active)
    assert dropped_eng == dropped_seed == [5]

    # the fused CalibrationEngine gate agrees too
    ceng = calibration_engine_for_solver(sol, cfg)
    p_fused, dropped_fused = ceng._final_gate(gmm.eps, x_gate, gt_gate[-1],
                                              params)
    np.testing.assert_array_equal(p_fused.active, p_seed.active)
    assert dropped_fused == dropped_seed


def test_gate_drops_everything_when_nothing_helps(setup):
    """All-harmful corrections: the gate empties the active set and reports
    the full drop order (largest step index first)."""
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver("ddim", s_ts)
    active = np.zeros(NFE, dtype=bool)
    active[[1, 4]] = True
    coords = np.zeros((NFE, 4), np.float32)
    coords[1] = [1.6, 0.0, 0.0, 0.0]
    coords[4] = [1.6, 0.0, 0.0, 0.0]
    params = PASParams(active=active, coords=jnp.asarray(coords))
    ceng = calibration_engine_for_solver(sol, PASConfig())
    p, dropped = ceng._final_gate(gmm.eps, x_t[:16], gt[-1][:16], params)
    assert not p.active.any()
    assert dropped == [4, 1]


# ---------------------------------------------------------------------------
# fused teacher builder
# ---------------------------------------------------------------------------


def test_fused_teacher_matches_reference(setup):
    gmm, s_ts, x_t, gt = setup
    spec = SamplerSpec(solver="ddim", nfe=NFE,
                       schedule=ScheduleSpec(t_min=T_MIN, t_max=T_MAX))
    # default teacher heun@100 - rebuild the eager reference on that grid
    s, t, m = spec.teacher_grid()
    ref = solvers.ground_truth_trajectory(
        gmm.eps, s, t, m, x_t[:16], teacher=spec.make_teacher(t))
    eng = get_calibration_engine_for_spec(spec)
    fused = eng.teacher_trajectory(gmm.eps, x_t[:16])
    assert fused.shape == ref.shape == (NFE + 1, 16, DIM)
    np.testing.assert_array_equal(np.asarray(fused[0]), np.asarray(x_t[:16]))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_teacher_requires_spec(setup):
    gmm, s_ts, x_t, gt = setup
    # raw schedules lift to a spec, so shim-built engines *have* a teacher
    eng = calibration_engine_for_solver(
        solvers.make_solver("ddim", np.array([80.0, 1.0, 0.002])))
    assert eng.spec is not None
    # a truly solver-only engine does not: gt must be passed explicitly
    bare = CalibrationEngine(solver=solvers.make_solver(
        "ddim", np.array([80.0, 1.0, 0.002])))
    with pytest.raises(ValueError, match="spec"):
        bare.teacher_trajectory(gmm.eps, x_t[:4])


# ---------------------------------------------------------------------------
# keying, caching, errors
# ---------------------------------------------------------------------------


def test_engine_cache_keys_on_spec_pas_and_teacher():
    spec = SamplerSpec(solver="ddim", nfe=NFE)
    e1 = get_calibration_engine_for_spec(spec)
    assert get_calibration_engine_for_spec(spec) is e1
    assert (get_calibration_engine_for_spec(
        spec.replace(pas=PASConfig(n_sgd_iters=7))) is not e1)
    st = calibration_engine_cache_stats()
    assert st["engines"] >= 2 and st["hits"] >= 1


def test_calibration_shares_sampling_engine():
    """One spec = one sampling binding: the calibration engine's rollouts and
    ``Pipeline.sample`` run the same compiled tables."""
    from repro.engine import get_engine_for_spec
    spec = SamplerSpec(solver="ipndm2", nfe=NFE)
    assert get_calibration_engine_for_spec(spec).sampling \
        is get_engine_for_spec(spec)


def test_two_eval_solver_raises_typeerror(setup):
    gmm, s_ts, x_t, gt = setup
    heun = solvers.make_solver("heun", s_ts)
    with pytest.raises(TypeError, match="1-eval"):
        pas.calibrate(heun, gmm.eps, x_t, gt, PASConfig())
    with pytest.raises(TypeError, match="1-eval"):
        pas.calibrate_reference(heun, gmm.eps, x_t, gt, PASConfig())


# ---------------------------------------------------------------------------
# dp=8 sharded calibration (subprocess, 8 virtual devices)
# ---------------------------------------------------------------------------

_SHARDED = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.api import MeshSpec, PASConfig, SamplerSpec, TeacherSpec
from repro.core import two_mode_gmm
from repro.engine import get_calibration_engine_for_spec

assert len(jax.devices()) == 8, jax.devices()
DIM, NFE = 24, 6
gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)
base = SamplerSpec(solver="ddim", nfe=NFE, teacher=TeacherSpec(nfe=30),
                   pas=PASConfig(n_sgd_iters=60, val_fraction=0.25))
x_t = gmm.sample_prior(jax.random.key(0), 64, 80.0)

e1 = get_calibration_engine_for_spec(base)
e8 = get_calibration_engine_for_spec(base.replace(mesh=MeshSpec(dp=8)))

# the teacher scan is row-parallel: dp=8 must be bit-identical
gt1 = e1.teacher_trajectory(gmm.eps, x_t)
gt8 = e8.teacher_trajectory(gmm.eps, x_t)
assert np.array_equal(np.asarray(gt1), np.asarray(gt8))
print("TEACHER_BITEXACT_OK")

# calibration reduces over the sharded batch axis (SGD loss, adoption
# metrics), so the partitioned reduction reassociates: decisions identical,
# coords tightly allclose (see module docstring of the host test file)
p1, d1 = e1.calibrate(gmm.eps, x_t, gt1)
p8, d8 = e8.calibrate(gmm.eps, x_t, gt8)
assert np.array_equal(p1.active, p8.active), (p1.active, p8.active)
assert d1.get("final_gate_dropped") == d8.get("final_gate_dropped")
assert p1.n_stored_params == p8.n_stored_params
np.testing.assert_allclose(np.asarray(p1.coords), np.asarray(p8.coords),
                           rtol=0, atol=2e-2)
print("DP8_CALIBRATION_OK")

# state sharding routes the basis through the shard_map psum collectives
e24 = get_calibration_engine_for_spec(
    base.replace(mesh=MeshSpec(dp=2, state=4)))
p24, _ = e24.calibrate(gmm.eps, x_t, gt1)
assert np.array_equal(p1.active, p24.active), (p1.active, p24.active)
np.testing.assert_allclose(np.asarray(p1.coords), np.asarray(p24.coords),
                           rtol=0, atol=5e-2)
print("STATE_SHARD_CALIBRATION_OK")
"""


@pytest.mark.slow
def test_sharded_calibration_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", _SHARDED],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    for marker in ("TEACHER_BITEXACT_OK", "DP8_CALIBRATION_OK",
                   "STATE_SHARD_CALIBRATION_OK"):
        assert marker in out.stdout
