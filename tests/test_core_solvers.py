"""Solver correctness: closed-form Gaussian oracle, convergence order, nesting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analytic, schedules, solvers

jax.config.update("jax_enable_x64", False)

DIM = 8
T_MAX, T_MIN = 80.0, 0.002


@pytest.fixture(scope="module")
def gauss():
    mean = jnp.asarray(np.linspace(-1.0, 1.0, DIM), jnp.float32)
    var = jnp.asarray(np.linspace(0.2, 0.8, DIM), jnp.float32)
    gmm = analytic.GaussianMixture(
        means=mean[None], variances=var[None], log_weights=jnp.zeros((1,)))
    return gmm, mean, var


def _exact(mean, var, x_t, t_from, t_to):
    return analytic.gaussian_ode_solution(mean, var, x_t, t_from, t_to)


def test_schedule_shape_and_endpoints():
    ts = schedules.polynomial_schedule(10, T_MIN, T_MAX)
    assert ts.shape == (11,)
    assert ts[0] == T_MAX and ts[-1] == T_MIN
    assert np.all(np.diff(ts) < 0)


def test_teacher_grid_nests_student():
    s, t, m = schedules.nested_teacher_schedule(10, 100, T_MIN, T_MAX)
    assert len(t) == 10 * (m + 1) + 1
    np.testing.assert_allclose(t[:: m + 1], s, rtol=1e-12)


@pytest.mark.parametrize("name", ["ddim", "euler", "ipndm1"])
def test_first_order_solvers_agree(name, gauss):
    gmm, mean, var = gauss
    ts = schedules.polynomial_schedule(8, T_MIN, T_MAX)
    sol = solvers.make_solver(name, ts)
    x_t = 80.0 * jax.random.normal(jax.random.key(0), (4, DIM))
    x0 = solvers.sample(sol, gmm.eps, x_t)
    ref = solvers.sample(solvers.make_solver("euler", ts), gmm.eps, x_t)
    np.testing.assert_allclose(np.asarray(x0), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,order", [
    ("euler", 1.0), ("heun", 2.0), ("dpm2", 2.0),
    ("dpmpp2m", 2.0), ("deis2", 2.0), ("ipndm2", 2.0), ("ipndm3", 2.5),
])
def test_convergence_order(name, order, gauss):
    """Empirical order on a smooth segment [10 -> 1] with a uniform grid.

    (iPNDM uses constant AB coefficients, exact only on uniform grids; the
    full Karras-grid behaviour is covered by test_multistep_beats_euler.)
    """
    gmm, mean, var = gauss
    key = jax.random.key(1)
    t_hi, t_lo = 10.0, 1.0
    x_hi = jnp.sqrt(t_hi**2 + 0.5) * jax.random.normal(key, (8, DIM))
    exact = _exact(mean, var, x_hi, jnp.asarray(t_hi), jnp.asarray(t_lo))
    errs = []
    for n_steps in (10, 20, 40, 80):
        ts = np.linspace(t_hi, t_lo, n_steps + 1)
        sol = solvers.make_solver(name, ts)
        x0 = solvers.sample(sol, gmm.eps, x_hi)
        errs.append(float(jnp.mean(jnp.linalg.norm(x0 - exact, axis=-1))) + 1e-9)
    rates = [np.log2(errs[i] / errs[i + 1]) for i in range(len(errs) - 1)]
    # multistep warmup (one Euler start step) delays the asymptotic rate;
    # require it on the finest refinement and monotone error decrease overall
    assert rates[-1] > order - 0.45, (name, errs, rates)
    assert errs[-1] < errs[0] / (2 ** (3 * order) / 2), (name, errs)


@pytest.mark.parametrize("name", ["ipndm3", "deis3", "dpmpp2m"])
def test_multistep_beats_euler_low_nfe(name, gauss):
    """On the Karras grid at NFE=12, multistep solvers beat DDIM/Euler
    (the paper's Table 2 ordering; Heun is *worse* there per Table 5)."""
    gmm, mean, var = gauss
    ts = schedules.polynomial_schedule(12, T_MIN, T_MAX)
    x_t = 80.0 * jax.random.normal(jax.random.key(2), (16, DIM))
    exact = _exact(mean, var, x_t, jnp.asarray(T_MAX), jnp.asarray(T_MIN))

    def err(solver_name):
        sol = solvers.make_solver(solver_name, ts)
        x0 = solvers.sample(sol, gmm.eps, x_t)
        return float(jnp.mean(jnp.linalg.norm(x0 - exact, axis=-1)))

    assert err(name) < err("euler"), name


def test_trajectory_matches_sample(gauss):
    gmm, *_ = gauss
    ts = schedules.polynomial_schedule(6, T_MIN, T_MAX)
    sol = solvers.make_solver("ipndm3", ts)
    x_t = 80.0 * jax.random.normal(jax.random.key(3), (2, DIM))
    xs, ds = solvers.sample_trajectory(sol, gmm.eps, x_t)
    assert xs.shape == (7, 2, DIM) and ds.shape == (6, 2, DIM)
    x0 = solvers.sample(sol, gmm.eps, x_t)
    np.testing.assert_allclose(np.asarray(xs[-1]), np.asarray(x0), rtol=1e-6)


def test_ground_truth_alignment(gauss):
    gmm, mean, var = gauss
    s_ts, t_ts, m = schedules.nested_teacher_schedule(5, 40, T_MIN, T_MAX)
    x_t = 80.0 * jax.random.normal(jax.random.key(4), (2, DIM))
    gt = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_t)
    assert gt.shape == (6, 2, DIM)
    np.testing.assert_allclose(np.asarray(gt[0]), np.asarray(x_t))
    # teacher endpoint should be near the closed form
    exact = _exact(mean, var, x_t, jnp.asarray(T_MAX), jnp.asarray(T_MIN))
    err = float(jnp.mean(jnp.linalg.norm(gt[-1] - exact, axis=-1)))
    assert err < 0.05, err


def test_deis_exact_for_polynomial_eps():
    """DEIS-tAB3 integrates eps that is polynomial (deg<=2) in t exactly."""
    coef = jnp.asarray([0.3, -0.02, 0.001])

    def eps_fn(x, t):
        return jnp.ones_like(x) * (coef[0] + coef[1] * t + coef[2] * t**2)

    ts = schedules.polynomial_schedule(8, 0.1, 10.0)
    sol = solvers.make_solver("deis3", ts)
    x_t = jnp.zeros((1, 3))
    x0 = solvers.sample(sol, eps_fn, x_t)
    # integral of eps dt from 10 -> 0.1 (plus 2-step warmup error, which for
    # deg<=order-1 polynomials only affects the first two steps)
    anti = lambda t: coef[0] * t + coef[1] * t**2 / 2 + coef[2] * t**3 / 3
    exact = anti(jnp.asarray(0.1)) - anti(jnp.asarray(10.0))
    np.testing.assert_allclose(np.asarray(x0[0, 0]), float(exact), rtol=2e-2)
