"""Distributed (shard_map/psum) PAS == single-device PAS.

The in-process tests use a 1-device mesh (shapes/specs exercised, psum
trivial); the subprocess test runs the same comparison on 8 virtual devices so
the collectives actually communicate.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import distributed

_COMPARE_SNIPPET = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import distributed, pca

n_dev = {n_dev}
mesh = jax.make_mesh((n_dev,), ("model",))
rng = np.random.default_rng(0)
n, d = 7, 64 * n_dev
q = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
mask = jnp.asarray([1.0] * 5 + [0.0] * 2)
dvec = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
coords = jnp.asarray([1.1, 0.3, -0.2, 0.05], jnp.float32)

step = distributed.make_sharded_pas_step(mesh, "model")
d_tilde_dist = np.asarray(step(q, mask, dvec, coords))

u_ref = pca.pas_basis(q, mask, dvec, n_basis=4)
d_norm = jnp.linalg.norm(dvec)
d_tilde_ref = np.asarray(jnp.einsum("k,kd->d", coords * d_norm, u_ref))
np.testing.assert_allclose(d_tilde_dist, d_tilde_ref, rtol=2e-3, atol=2e-3)
print("DIST_OK")
"""


def test_sharded_pas_step_single_device():
    code = _COMPARE_SNIPPET.format(n_dev=1)
    exec(compile(code, "<single-dev>", "exec"), {})


@pytest.mark.slow
def test_sharded_pas_step_8_devices_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", _COMPARE_SNIPPET.format(n_dev=8)],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DIST_OK" in out.stdout


def test_psum_gram_matches_dense():
    mesh = jax.make_mesh((1,), ("model",))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(5, 32)).astype(np.float32))

    def f(xl):
        return distributed.psum_gram(xl, "model")

    g = distributed.shard_map(f, mesh=mesh, in_specs=P(None, "model"),
                              out_specs=P(None, None))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(x @ x.T), rtol=1e-5)
