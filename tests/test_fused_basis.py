"""Fused PAS basis path: gram tiling, weight-space projection, mesh parity.

The corrected step is two D passes — ``ops.gram_qd`` (the one reduction,
psummed on a mesh) and ``ops.fused_pas_project_step`` (elementwise along D).
These tests pin:

* gram / gram_qd Pallas tail-masking: any ``block_d`` is legal for any D
  (non-divisible tails, oversize blocks) — the regression for the old
  hardcoded ``block_d=2048`` divisibility assumption;
* interpret-mode kernel bodies == jnp oracles;
* the dp=1 collective weights path is *bitwise* the replicated
  ``_batched_weights`` / ``_batched_basis`` oracle (psum is identity, the
  Gram reduction order is unchanged);
* on 8 virtual devices (subprocess): dp=8 engines are bitwise the
  single-device engine, 2x4 and state-8 meshes match within float tolerance
  (psum reassociates the Gram), for ddim + ipndm4 and active/inactive
  patterns — and an uneven state dim degrades to the replicated weights
  with exactly one ``PASShardingFallbackWarning`` and a counted fallback.

No hypothesis dependency: these run in the container as well as CI.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pas import (_batched_basis, _batched_weights,
                            _projected_coords, _QBuffer)
from repro.kernels import ops, ref

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# gram / gram_qd tiling: block_d need not divide D (the old 2048 assumption)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d_total,block_d", [
    (300, 128),   # two full tiles + a 44-lane tail
    (300, 512),   # single oversize tile
    (256, 128),   # exact division (the old assumption's only legal case)
    (130, 128),   # 2-lane tail
])
def test_gram_block_d_tail_masking(d_total, block_d):
    rng = _rng(1)
    x = jnp.asarray(rng.normal(size=(5, d_total)).astype(np.float32))
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0, 0.0])
    got = ops.gram(x, mask=mask, block_d=block_d, interpret=True)
    want = ref.gram(x, mask=mask)
    assert np.all(np.isfinite(np.asarray(got))), "tail lanes leaked"
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("d_total,block_d", [(300, 128), (192, 256), (384, 128)])
def test_gram_qd_block_d_tail_masking(d_total, block_d):
    rng = _rng(2)
    r, b = 4, 3
    rows = jnp.asarray(rng.normal(size=(r, b, d_total)).astype(np.float32))
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    d = jnp.asarray(rng.normal(size=(b, d_total)).astype(np.float32))
    got = ops.gram_qd(rows, mask, d, block_d=block_d, interpret=True)
    want = ref.gram_qd(rows, mask, d)
    assert got.shape == (b, r + 1, r + 1) and got.dtype == jnp.float32
    assert np.all(np.isfinite(np.asarray(got))), "tail lanes leaked"
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gram_default_block_covers_any_d():
    # default block_d (2048) with a D it does not divide — the regression
    rng = _rng(3)
    x = jnp.asarray(rng.normal(size=(3, 2500)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(ops.gram(x, interpret=True)), np.asarray(ref.gram(x)),
        rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# fused project+step kernel == oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("native_x0", [False, True])
@pytest.mark.parametrize("d_total", [256, 300])
def test_fused_pas_project_step_interpret_matches_ref(native_x0, d_total):
    rng = _rng(4)
    r, b, k_hist = 4, 3, 2
    x = jnp.asarray(rng.normal(size=(b, d_total)).astype(np.float32))
    rows = jnp.asarray(rng.normal(size=(r, b, d_total)).astype(np.float32))
    d = jnp.asarray(rng.normal(size=(b, d_total)).astype(np.float32))
    pw = jnp.asarray(rng.normal(size=(b, r + 1)).astype(np.float32))
    hist = jnp.asarray(rng.normal(size=(k_hist, b, d_total)).astype(np.float32))
    coef = jnp.asarray(rng.normal(size=(k_hist + 2,)).astype(np.float32))
    got = ops.fused_pas_project_step(x, rows, d, pw, hist, coef,
                                     native_x0=native_x0, interpret=True)
    want = ref.fused_pas_project_step(x, rows, d, pw, hist, coef,
                                      native_x0=native_x0)
    for g, w, nm in zip(got, want, ("x_next", "d_tilde", "native")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5, err_msg=nm)


def test_projection_bitwise_same_as_oracle_association():
    """The fused-path d~ is bitwise the oracle einsum at the same association
    (pw @ Xp); the *materialised* reassociation cs @ (W @ Xp) is only close —
    that gap is the documented noise-subspace sensitivity, so the whole repo
    (engine, seed reference, sharded step) runs the pw association."""
    rng = _rng(5)
    r, b, d_total, k = 4, 4, 96, 4
    rows = jnp.asarray(rng.normal(size=(r, b, d_total)).astype(np.float32))
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    d = jnp.asarray(rng.normal(size=(b, d_total)).astype(np.float32))
    q = _QBuffer(rows, mask)
    w, d_norm = _batched_weights(q, d, k)
    coords = jnp.asarray([1.0, 0.05, -0.02, 0.01], jnp.float32)
    pw = _projected_coords(coords, w, d_norm, "relative")

    x = jnp.asarray(rng.normal(size=(b, d_total)).astype(np.float32))
    hist = jnp.zeros((1, b, d_total), jnp.float32)
    coef = jnp.asarray([1.0, -0.5, 0.0, 0.1], jnp.float32)
    _, d_tilde, _ = ops.fused_pas_project_step(x, rows, d, pw, hist, coef)

    pwx = pw.astype(d.dtype)
    want = jnp.einsum("br,rbd->bd", pwx[:, :-1], rows) + pwx[:, -1:] * d
    np.testing.assert_array_equal(np.asarray(d_tilde), np.asarray(want))

    u = _batched_basis(q, d, k)
    reassoc = jnp.einsum("bk,bkd->bd",
                         coords[None, :] * d_norm[:, None], u)
    np.testing.assert_allclose(np.asarray(d_tilde), np.asarray(reassoc),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# dp=1 collective path is bitwise the replicated oracle
# ---------------------------------------------------------------------------


def test_sharded_weights_dp1_bitwise():
    from repro.core import distributed
    rng = _rng(6)
    r, b, d_total, k = 4, 8, 64, 4
    rows = jnp.asarray(rng.normal(size=(r, b, d_total)).astype(np.float32))
    mask = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    d = jnp.asarray(rng.normal(size=(b, d_total)).astype(np.float32))
    q = _QBuffer(rows, mask)
    mesh = jax.make_mesh((1,), ("model",))
    w_ref, dn_ref = _batched_weights(q, d, k)
    w_sh, dn_sh = distributed.batched_pas_weights_sharded(
        mesh, "model", None, k)(rows, mask, d)
    np.testing.assert_array_equal(np.asarray(w_sh), np.asarray(w_ref))
    np.testing.assert_array_equal(np.asarray(dn_sh), np.asarray(dn_ref))
    u_ref = _batched_basis(q, d, k)
    u_sh = distributed.batched_pas_basis_sharded(
        mesh, "model", None, k)(rows, mask, d)
    np.testing.assert_array_equal(np.asarray(u_sh), np.asarray(u_ref))


# ---------------------------------------------------------------------------
# 8 virtual devices: engine parity across meshes + fallback accounting
# ---------------------------------------------------------------------------

_MESH_PAYLOAD = r"""
import warnings
import jax, jax.numpy as jnp, numpy as np
from repro.api import MeshSpec, SamplerSpec
from repro.core import analytic
from repro.core.pas import PASParams
from repro.engine import (PASShardingFallbackWarning, engine_cache_stats,
                          get_engine_for_spec)

DIM, NFE, B = 32, 5, 16
gmm = analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)
x = gmm.sample_prior(jax.random.key(0), B, 80.0)


def params(active_js, full=False):
    # full=True weights every basis component, including near-degenerate
    # ones — legal for *bitwise* comparisons (identical programs), but the
    # eigh noise subspace rotates under the Gram psum's reassociation, so
    # float-tolerance mesh comparisons weight only the well-separated top-2
    # components (the repo-wide convention, see tests/test_mesh.py).
    active = np.zeros(NFE, dtype=bool)
    coords = np.zeros((NFE, 4), np.float32)
    for j in active_js:
        active[j] = True
        c2 = 0.05 if j % 2 else -0.04
        coords[j] = [1.0, c2, -0.02, 0.01] if full else [1.0, c2, 0.0, 0.0]
    return PASParams(active=active, coords=jnp.asarray(coords))


def run(name, mesh, p):
    spec = SamplerSpec(solver=name, nfe=NFE)
    if mesh is not None:
        spec = spec.replace(mesh=mesh)
    return np.asarray(get_engine_for_spec(spec).sample(gmm.eps, x, params=p))


for name in ("ddim", "ipndm4"):
    for pattern in ((1, 3), ()):
        p = params(pattern)
        base = run(name, None, p)
        # dp-only partitions a batch-parallel program: bitwise
        dp8 = run(name, MeshSpec(dp=8), p)
        assert np.array_equal(base, dp8), (name, pattern, "dp8",
                                           np.abs(base - dp8).max())
        # state sharding psums the Gram: float-tolerance, same math
        for tag, ms in (("2x4", MeshSpec(dp=2, state=4)),
                        ("st8", MeshSpec(state=8))):
            got = run(name, ms, p)
            np.testing.assert_allclose(got, base, rtol=2e-3, atol=2e-3,
                                       err_msg=f"{name}/{pattern}/{tag}")

# all-component coords stay bitwise under dp-only sharding
p_full = params((1, 3), full=True)
assert np.array_equal(run("ddim", None, p_full),
                      run("ddim", MeshSpec(dp=8), p_full)), "dp8 full coords"
print("MESH_PARITY_OK")

# --- fallback accounting: uneven state dim degrades, warns once, counts ---
gmm2 = analytic.two_mode_gmm(36, sep=6.0, var=0.25)   # 36 % 8 != 0
x2 = gmm2.sample_prior(jax.random.key(1), 8, 80.0)
eng = get_engine_for_spec(
    SamplerSpec(solver="ddim", nfe=NFE, mesh=MeshSpec(state=8)))
p = params((1, 3))
with warnings.catch_warnings(record=True) as rec:
    warnings.simplefilter("always")
    a = np.asarray(eng.sample(gmm2.eps, x2, params=p))
ws = [r for r in rec if issubclass(r.category, PASShardingFallbackWarning)]
assert len(ws) == 1, [str(r.message) for r in rec]
assert ws[0].message.reason == "uneven_state", ws[0].message.reason
assert ws[0].message.shape[1] == 36
# one fallback per corrected step at trace time: 2 active steps -> 2
assert eng.basis_fallback_stats() == {"uneven_state": 2}, \
    eng.basis_fallback_stats()
assert engine_cache_stats()["basis_fallbacks"] >= 1
# the degraded program still samples correctly (replicated weights)
ref_eng = get_engine_for_spec(SamplerSpec(solver="ddim", nfe=NFE))
b = np.asarray(ref_eng.sample(gmm2.eps, x2, params=p))
np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
# a second degraded trace counts again but does NOT warn again
with warnings.catch_warnings(record=True) as rec2:
    warnings.simplefilter("always")
    eng.sample(gmm2.eps, x2[:4], params=p)
assert not [r for r in rec2
            if issubclass(r.category, PASShardingFallbackWarning)], \
    "fallback warned twice for one reason"
assert eng.basis_fallback_stats()["uneven_state"] == 4
print("FALLBACK_OK")
"""


@pytest.mark.slow
def test_mesh_parity_and_fallbacks_8_devices_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", _MESH_PAYLOAD],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MESH_PARITY_OK" in out.stdout
    assert "FALLBACK_OK" in out.stdout
