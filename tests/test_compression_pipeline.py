"""Gradient compression (error feedback) + pipeline parallelism.

Multi-device behaviour runs in subprocesses with virtual devices (the main
test process keeps the default 1-device view per the dry-run isolation rule).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.optim.compression import ef_compress_leaf

# the whole module drives the explicit-sharding APIs (jax.sharding.AxisType,
# jax.set_mesh, top-level jax.shard_map) introduced after jax 0.4.x
pytestmark = pytest.mark.skipif(
    not (hasattr(jax.sharding, "AxisType") and hasattr(jax, "set_mesh")),
    reason="needs jax>=0.5 explicit-sharding APIs")

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run_sub(code: str, n_dev: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_compression_single_device_identity_ish():
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)
    e = jnp.zeros_like(g)

    def f(g, e):
        return ef_compress_leaf(g, e, "data")

    with jax.set_mesh(mesh):
        out, new_e = jax.shard_map(f, mesh=mesh, in_specs=(P(), P()),
                                   out_specs=(P(), P()))(g, e)
    # int8 quantisation error bounded by scale = max|g|/127
    bound = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(out - g))) <= bound + 1e-6
    # error feedback buffer holds exactly the residual
    np.testing.assert_allclose(np.asarray(g - out), np.asarray(new_e),
                               rtol=1e-5, atol=1e-7)


def test_error_feedback_drives_bias_to_zero():
    """Repeatedly compressing the same gradient: EF makes the *average*
    applied update converge to the true gradient."""
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = jnp.asarray(np.random.default_rng(1).normal(size=(128,)), jnp.float32)
    e = jnp.zeros_like(g)

    def f(g, e):
        return ef_compress_leaf(g, e, "data")

    applied = []
    with jax.set_mesh(mesh):
        step = jax.shard_map(f, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P()))
        for _ in range(50):
            out, e = step(g, e)
            applied.append(np.asarray(out))
    mean_applied = np.mean(applied, axis=0)
    np.testing.assert_allclose(mean_applied, np.asarray(g), atol=2e-3)


COMPRESS_8DEV = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compression import ef_compress_leaf
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
g_all = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)  # per-device grads
e_all = jnp.zeros_like(g_all)
def f(g, e):
    out, ne = ef_compress_leaf(g[0], e[0], "data")
    return out[None], ne[None]
step = jax.shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=(P("data"), P("data")))
with jax.set_mesh(mesh):
    out, _ = step(g_all, e_all)
true_mean = np.mean(np.asarray(g_all), axis=0)
got = np.asarray(out)[0]
scale = np.max(np.abs(np.asarray(g_all))) / 127.0
assert np.max(np.abs(got - true_mean)) <= scale * 1.01 + 1e-6, \
    (np.max(np.abs(got - true_mean)), scale)
print("COMPRESS_OK")
"""


@pytest.mark.slow
def test_compressed_allreduce_8dev():
    assert "COMPRESS_OK" in _run_sub(COMPRESS_8DEV, 8)


PIPELINE_4DEV = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_apply
mesh = jax.make_mesh((4,), ("stage",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
S, M, mb, d = 4, 6, 3, 8
w = jnp.asarray(rng.normal(size=(S, d, d)) / np.sqrt(d), jnp.float32)
x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)
def stage_fn(w_stage, h):
    return jnp.tanh(h @ w_stage)
out = pipeline_apply(mesh, stage_fn, w, x, axis="stage")
# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential_4dev():
    assert "PIPELINE_OK" in _run_sub(PIPELINE_4DEV, 4)
