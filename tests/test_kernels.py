"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per the deliverable: sweep shapes/dtypes per kernel, assert_allclose against
ref.py.  Includes hypothesis property tests on kernel invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ref  # noqa: E402
from repro.kernels.flash_attention import flash_attention  # noqa: E402
from repro.kernels.gram import gram  # noqa: E402
from repro.kernels.rmsnorm import rmsnorm  # noqa: E402
from repro.kernels.ssm_scan import ssm_scan  # noqa: E402


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # (b, s, t, h, kv, dh, causal, window, cap, dtype)
    (2, 64, 64, 4, 2, 32, True, None, None, jnp.float32),
    (1, 128, 128, 4, 1, 64, True, 32, None, jnp.float32),
    (2, 96, 96, 2, 2, 16, True, None, None, jnp.float32),   # pad path
    (1, 64, 64, 8, 8, 128, False, None, None, jnp.float32),
    (1, 64, 64, 4, 4, 32, True, None, 30.0, jnp.float32),   # soft cap
    (2, 64, 64, 4, 2, 64, True, 16, None, jnp.bfloat16),
    (1, 256, 256, 2, 1, 64, True, 64, None, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_matches_ref(case):
    b, s, t, h, kv, dh, causal, window, cap, dtype = case
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, t, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, t, kv, dh), dtype)
    out_k = flash_attention(q, k, v, causal=causal, window=window,
                            logits_soft_cap=cap, block_q=32, block_kv=32,
                            interpret=True)
    out_r = ref.attention(q, k, v, causal=causal, window=window,
                          logits_soft_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_first_row_attends_self_only():
    """Causal row 0 must equal v[0] exactly (invariant, any block size)."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    out = flash_attention(q, k, v, causal=True, block_q=16, block_kv=16,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(v[0, 0]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,dtype", [
    (4, 512, jnp.float32), (12, 4096, jnp.float32), (7, 1000, jnp.float32),
    (12, 2048, jnp.bfloat16), (16, 8192, jnp.float32),
])
def test_gram_matches_ref(n, d, dtype):
    x = jax.random.normal(jax.random.key(0), (n, d), dtype)
    g_k = gram(x, block_d=512, interpret=True)
    g_r = ref.gram(x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                               rtol=tol, atol=tol * d ** 0.5)


def test_gram_mask():
    x = jax.random.normal(jax.random.key(1), (6, 700))
    mask = jnp.asarray([1, 1, 0, 1, 0, 1], jnp.float32)
    g_k = gram(x, mask=mask, block_d=256, interpret=True)
    g_r = ref.gram(x, mask=mask)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-4,
                               atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 12), d=st.integers(64, 600),
       seed=st.integers(0, 2**31 - 1))
def test_gram_psd_and_symmetric_property(n, d, seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)),
                    jnp.float32)
    g = np.asarray(gram(x, block_d=128, interpret=True))
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-4)
    evals = np.linalg.eigvalsh(g)
    assert evals.min() > -1e-2 * max(evals.max(), 1.0)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,dtype", [
    ((4, 16, 128), jnp.float32), ((3, 100), jnp.float32),
    ((2, 8, 256), jnp.bfloat16), ((1, 1, 64), jnp.float32),
])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    scale = 0.1 * jax.random.normal(jax.random.key(1), (shape[-1],))
    y_k = rmsnorm(x, scale, block_rows=32, interpret=True)
    y_r = ref.rmsnorm(x, scale)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 64), e=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 2**31 - 1))
def test_rmsnorm_unit_rms_property(rows, e, seed):
    """With scale=0 the output rows have RMS ~= 1."""
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(rows, e)) * 3,
                    jnp.float32)
    y = rmsnorm(x, jnp.zeros((e,)), block_rows=16, interpret=True)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,l,di,n,dtype", [
    (2, 64, 128, 8, jnp.float32),
    (1, 100, 128, 16, jnp.float32),   # time padding path
    (2, 128, 256, 4, jnp.bfloat16),
])
def test_ssm_scan_matches_ref(b, l, di, n, dtype):
    ks = jax.random.split(jax.random.key(0), 5)
    u = jax.random.normal(ks[0], (b, l, di), dtype)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (b, l, di), dtype))
    a = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    bb = jax.random.normal(ks[3], (b, l, n), dtype)
    cc = jax.random.normal(ks[4], (b, l, n), dtype)
    d = jnp.ones((di,), jnp.float32)
    y_k, h_k = ssm_scan(u, delta, a, bb, cc, d, block_d=64, block_t=32,
                        interpret=True)
    y_r, h_r = ref.ssm_scan(u, delta, a, bb, cc, d)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), rtol=tol,
                               atol=tol * 10)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), rtol=tol,
                               atol=tol * 10)


def test_ssm_scan_state_continuation():
    """Scanning [x1; x2] == scanning x1 then x2 seeded with h(x1) (oracle)."""
    ks = jax.random.split(jax.random.key(3), 5)
    b, l, di, n = 1, 32, 16, 4
    u = jax.random.normal(ks[0], (b, 2 * l, di))
    delta = jax.nn.softplus(jax.random.normal(ks[1], (b, 2 * l, di)))
    a = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    bb = jax.random.normal(ks[3], (b, 2 * l, n))
    cc = jax.random.normal(ks[4], (b, 2 * l, n))
    y_full, h_full = ref.ssm_scan(u, delta, a, bb, cc)
    y1, h1 = ref.ssm_scan(u[:, :l], delta[:, :l], a, bb[:, :l], cc[:, :l])
    y2, h2 = ref.ssm_scan(u[:, l:], delta[:, l:], a, bb[:, l:], cc[:, l:],
                          h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, l:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2), rtol=1e-4,
                               atol=1e-4)
