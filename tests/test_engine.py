"""SamplingEngine parity vs the seed sampling paths, plus kernel + cache tests.

Parity contract: the engine must reproduce ``solvers.sample`` (plain) and
``pas.pas_sample_trajectory`` (corrected) within float32 tolerance.  The
plain path is bit-compatible (identical accumulation order).  For the
corrected path the reference is the *jitted* seed function: eager execution
of the seed path is itself non-reproducible (~1e-2) whenever coordinates
weight near-degenerate principal components, because ``eigh`` returns
arbitrary eigenvectors in the noise subspace and eager/compiled programs
round differently into it.  Under jit the engine matches the seed to
<= 2e-5 across every LMS solver, both coord modes, and batch 1/4 (observed);
tests assert atol=1e-3 for platform headroom.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analytic, pas, schedules, solvers
from repro.engine import (SamplingEngine, clear_engine_cache,
                          engine_cache_stats, engine_for_solver, get_engine)
from repro.kernels import ops, ref

DIM = 16
NFE = 5
T_MAX, T_MIN = 80.0, 0.002

LMS_NAMES = tuple(n for n in solvers.SOLVER_NAMES if n not in ("heun", "dpm2"))
PAS_ATOL = 1e-3


@pytest.fixture(scope="module")
def setup():
    gmm = analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)
    ts = schedules.polynomial_schedule(NFE, T_MIN, T_MAX)
    x4 = gmm.sample_prior(jax.random.key(0), 4, T_MAX)
    return gmm, ts, x4


def _params(active_js=(1, 3)) -> pas.PASParams:
    """Synthetic correction weighting every basis component."""
    active = np.zeros(NFE, dtype=bool)
    active[list(active_js)] = True
    coords = np.zeros((NFE, 4), np.float32)
    for j in active_js:
        coords[j] = [1.0, 0.05 if j % 2 else -0.04, -0.02, 0.01]
    return pas.PASParams(active=active, coords=jnp.asarray(coords))


def _seed_pas_jit(sol, eps_fn, p, cfg):
    """The parity reference: the seed path under jit (see module docstring)."""
    return jax.jit(
        lambda xx: pas.pas_sample_trajectory(sol, eps_fn, xx, p, cfg)[0])


# ---------------------------------------------------------------------------
# plain-path parity: every solver in the zoo
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", solvers.SOLVER_NAMES)
def test_plain_parity(name, setup):
    gmm, ts, x4 = setup
    sol = solvers.make_solver(name, ts)
    a = solvers.sample(sol, gmm.eps, x4)
    b = engine_for_solver(sol).sample(gmm.eps, x4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# PAS-path parity: every LMS solver x coord mode (batch 4),
# batch 1 on a representative subset
# ---------------------------------------------------------------------------


def _pas_parity(name, mode, x, gmm, ts):
    sol = solvers.make_solver(name, ts)
    coords_scale = 30.0 if mode == "absolute" else 1.0  # ~||d|| at these steps
    p = _params()
    p = pas.PASParams(active=p.active,
                      coords=p.coords * jnp.asarray(coords_scale))
    cfg = pas.PASConfig(coord_mode=mode)
    want = _seed_pas_jit(sol, gmm.eps, p, cfg)(x)
    got = engine_for_solver(sol).sample(gmm.eps, x, params=p, cfg=cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=PAS_ATOL)
    # sanity: the correction actually changed the trajectory
    plain = engine_for_solver(sol).sample(gmm.eps, x)
    assert float(jnp.max(jnp.abs(want - plain))) > 10 * PAS_ATOL


@pytest.mark.parametrize("mode", ["relative", "absolute"])
@pytest.mark.parametrize("name", LMS_NAMES)
def test_pas_parity_batch4(name, mode, setup):
    gmm, ts, x4 = setup
    _pas_parity(name, mode, x4, gmm, ts)


@pytest.mark.parametrize("mode", ["relative", "absolute"])
@pytest.mark.parametrize("name", ["ddim", "ipndm3", "deis2", "dpmpp2m"])
def test_pas_parity_batch1(name, mode, setup):
    gmm, ts, _ = setup
    x1 = gmm.sample_prior(jax.random.key(7), 1, T_MAX)
    _pas_parity(name, mode, x1, gmm, ts)


def test_pas_parity_calibrated(setup):
    """End-to-end: engine matches the reference path on *learned* params."""
    gmm, _, _ = setup
    s_ts, t_ts, m = schedules.nested_teacher_schedule(NFE, 50, T_MIN, T_MAX)
    sol = solvers.make_solver("ddim", s_ts)
    x_c = gmm.sample_prior(jax.random.key(1), 64, T_MAX)
    gt = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_c)
    params, _ = pas.calibrate(sol, gmm.eps, x_c, gt,
                              pas.PASConfig(n_sgd_iters=60))
    x_e = gmm.sample_prior(jax.random.key(2), 4, T_MAX)
    cfg = pas.PASConfig()
    want = _seed_pas_jit(sol, gmm.eps, params, cfg)(x_e)
    got = engine_for_solver(sol).sample(gmm.eps, x_e, params=params, cfg=cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=PAS_ATOL)


def test_pas_sample_entry_point_uses_engine(setup):
    """core.pas.pas_sample is the engine path (the one sampling entry point)."""
    gmm, ts, x4 = setup
    sol = solvers.make_solver("ipndm2", ts)
    p = _params()
    cfg = pas.PASConfig()
    got = pas.pas_sample(sol, gmm.eps, x4, p, cfg)
    want = engine_for_solver(sol).sample(gmm.eps, x4, params=p, cfg=cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=PAS_ATOL)


def test_two_eval_rejects_pas(setup):
    gmm, ts, x4 = setup
    eng = engine_for_solver(solvers.make_solver("heun", ts))
    with pytest.raises(TypeError):
        eng.sample(gmm.eps, x4, params=_params())


# ---------------------------------------------------------------------------
# coefficient/engine cache
# ---------------------------------------------------------------------------


def test_engine_cache_hit():
    clear_engine_cache()
    ts = schedules.polynomial_schedule(NFE, T_MIN, T_MAX)
    e1 = get_engine("ipndm3", ts)
    stats = engine_cache_stats()
    assert (stats["engines"], stats["hits"], stats["misses"]) == (1, 0, 1)
    e2 = get_engine("ipndm3", ts.copy())      # equal schedule -> same binding
    assert e2 is e1
    assert engine_cache_stats()["hits"] == 1
    # a bound solver with the same (name, ts, dtype) shares the entry
    e3 = engine_for_solver(solvers.make_solver("ipndm3", ts))
    assert e3 is e1
    # any key component changing -> new engine
    assert get_engine("ipndm2", ts) is not e1
    assert get_engine("ipndm3", ts, dtype=jnp.bfloat16) is not e1
    assert get_engine("ipndm3", ts[:-1]) is not e1
    assert engine_cache_stats()["engines"] == 4


def test_compiled_variant_reuse(setup):
    """Same model + same correction pattern -> one compiled program."""
    gmm, ts, x4 = setup
    eng = SamplingEngine(solvers.make_solver("ddim", ts))
    eng.sample(gmm.eps, x4)
    eng.sample(gmm.eps, x4)
    assert eng.compiled_variants() == 1
    p = _params()
    eng.sample(gmm.eps, x4, params=p)
    eng.sample(gmm.eps, x4, params=p)
    assert eng.compiled_variants() == 2


def test_cache_stats_report_compiled_variants(setup):
    """engine_cache_stats sums per-engine compiled programs (CI observability)."""
    gmm, ts, x4 = setup
    clear_engine_cache()
    eng = get_engine("ddim", ts)
    assert engine_cache_stats()["compiled_variants"] == 0
    eng.sample(gmm.eps, x4)
    assert engine_cache_stats()["compiled_variants"] == 1
    eng.sample(gmm.eps, x4, params=_params())
    eng2 = get_engine("ipndm2", ts)
    eng2.sample(gmm.eps, x4)
    assert engine_cache_stats()["compiled_variants"] == 3


def test_donated_input_variant_matches(setup):
    """donate_x compiles a separate variant with identical outputs; the
    donated input buffer is invalidated."""
    gmm, ts, _ = setup
    eng = SamplingEngine(solvers.make_solver("ddim", ts))
    x = gmm.sample_prior(jax.random.key(5), 4, T_MAX)
    want = np.asarray(eng.sample(gmm.eps, x))
    x_donate = x + 0.0                       # fresh buffer to give away
    got = np.asarray(eng.sample(gmm.eps, x_donate, donate_x=True))
    np.testing.assert_array_equal(got, want)
    assert eng.compiled_variants() == 2
    with pytest.raises((RuntimeError, ValueError)):
        np.asarray(x_donate)                 # buffer was donated


def test_pas_q_buffer_bounded_matches_old_layout(setup, monkeypatch):
    """Q rows past last_active+2 are dead HBM: the bounded allocation must
    reproduce the old full-cap (n+1) layout.

    Dead rows are mask-zeroed out of every Gram, so all basis components
    whose eigenvalue clears the degeneracy floor are unchanged; only
    noise-floor components (arbitrary in *both* layouts — see module
    docstring on eigh's degenerate subspace) may rotate.  The parity
    contract is therefore: (a) floor-clearing basis components bit-equal,
    (b) trajectories equal to fusion-noise tolerance whenever coords don't
    weight the noise floor (the two cap layouts are different compiled
    programs, and bitwise equality only holds within one program).
    """
    gmm, ts, x4 = setup
    sol = solvers.make_solver("ipndm3", ts)
    active_js = (2, 3)                       # last_active=3 -> cap 5 < 6
    active = np.zeros(NFE, dtype=bool)
    active[list(active_js)] = True
    coords = np.zeros((NFE, 4), np.float32)
    for j in active_js:                      # weight only well-conditioned
        coords[j] = [1.0, 0.05, -0.02, 0.0]  # components (noise floor = 0)
    p = pas.PASParams(active=active, coords=jnp.asarray(coords))
    cfg = pas.PASConfig()
    assert pas._sampling_q_cap(3, NFE) == 5 < NFE + 1

    # (a) basis parity on a real mid-trajectory Q buffer
    x, hist = x4, sol.init_hist(x4)
    q_bounded = pas._QBuffer.create(x4, cap=5)
    q_full = pas._QBuffer.create(x4, cap=NFE + 1)
    for j in range(3):
        x, hist, d_j = sol.step(gmm.eps, x, j, hist)
        q_bounded = q_bounded.push(d_j, j + 1)
        q_full = q_full.push(d_j, j + 1)
    d = gmm.eps(x, sol.ts_jax[3])
    u_b = jax.jit(lambda q, dd: pas._batched_basis(q, dd, 4))(q_bounded, d)
    u_f = jax.jit(lambda q, dd: pas._batched_basis(q, dd, 4))(q_full, d)
    np.testing.assert_array_equal(np.asarray(u_b)[:, :3],
                                  np.asarray(u_f)[:, :3])

    # (b) trajectory parity, reference path and engine path
    want_bounded = np.asarray(_seed_pas_jit(sol, gmm.eps, p, cfg)(x4))
    got_bounded = np.asarray(
        engine_for_solver(sol).sample(gmm.eps, x4, params=p, cfg=cfg))
    monkeypatch.setattr(pas, "_sampling_q_cap", lambda last, n: n + 1)
    want_full = np.asarray(_seed_pas_jit(sol, gmm.eps, p, cfg)(x4))
    eng_full = SamplingEngine(sol)           # fresh: no cached bounded program
    got_full = np.asarray(eng_full.sample(gmm.eps, x4, params=p, cfg=cfg))
    # bounded vs full cap are *different compiled programs* (5-row vs 6-row
    # buffers), so XLA may fuse their float arithmetic differently — the
    # repo-wide convention is bitwise only for identical programs (see
    # test_mesh.py's dp-vs-replicated) and float tolerance otherwise; the
    # buffers' extra rows are mask-zeroed, so the math is the same and the
    # drift is pure last-bit fusion noise
    np.testing.assert_allclose(want_bounded, want_full, rtol=0, atol=1e-5)
    np.testing.assert_allclose(got_bounded, got_full, rtol=0, atol=PAS_ATOL)


def test_coef_table_layout(setup):
    """Packed rows are [alpha, beta_0..beta_{K-1}, t] straight from the solver."""
    _, ts, _ = setup
    sol = solvers.make_solver("dpmpp2m", ts)
    eng = SamplingEngine(sol)
    np.testing.assert_allclose(np.asarray(eng.coef[:, 0]),
                               np.asarray(sol.alpha), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(eng.coef[:, 1:-1]),
                               np.asarray(sol.beta), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(eng.coef[:, -1]), sol.ts[:-1],
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# fused kernels (Pallas interpret mode) vs the XLA reference
# ---------------------------------------------------------------------------


def _step_inputs(b=4, d=300, k=3, h=2, n_basis=4):
    keys = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(keys[0], (b, d))
    nat = jax.random.normal(keys[1], (b, d))
    hist = jax.random.normal(keys[2], (h, b, d))
    u = jax.random.normal(keys[3], (b, n_basis, d))
    cs = jax.random.normal(keys[4], (b, n_basis))
    coef = jnp.asarray([0.9, 0.5, -0.2, 0.1, 3.0])[:k + 2]
    return x, nat, hist, u, cs, coef


def test_fused_step_kernel_matches_ref():
    x, nat, hist, _, _, coef = _step_inputs()
    want = ref.fused_step(x, nat, hist, coef)
    got = ops.fused_step(x, nat, hist, coef, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("native_x0", [False, True])
def test_fused_pas_step_kernel_matches_ref(native_x0):
    x, _, hist, u, cs, coef = _step_inputs()
    want = ref.fused_pas_step(x, u, cs, hist, coef, native_x0=native_x0)
    got = ops.fused_pas_step(x, u, cs, hist, coef, native_x0=native_x0,
                             interpret=True)
    for w, g in zip(want, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


def test_fused_step_euler_semantics():
    """coef row [1, dt, t] must reduce to the Euler update x + dt*d."""
    x, nat, hist, _, _, _ = _step_inputs(k=1, h=1)
    coef = jnp.asarray([1.0, -0.5, 3.0])
    out = ref.fused_step(x, nat, hist[:1], coef)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x - 0.5 * nat),
                               rtol=1e-6)
