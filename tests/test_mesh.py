"""Mesh-native sampling: placement is not part of the sampler's math.

In-process tests run on the single CPU device (MeshSpec semantics, spec/
artifact plumbing, trivial-mesh engines).  The subprocess tests re-run the
real programs on 8 virtual host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and assert the
ISSUE acceptance contract:

* a ``MeshSpec(dp=8)`` pipeline is **bit-identical** in fp32 to the
  single-device engine for ddim and ipndm4, plain and PAS-corrected
  (pjit partitions a batch-parallel program; nothing crosses rows);
* the shard_map PAS collective path (state sharding) matches replicated PAS
  within float tolerance (psum reassociates the D reduction);
* serve flushes pad-and-mask to DP-divisible batches and the eval counter
  reflects the pad;
* a PAS artifact calibrated and saved on an 8-device mesh reloads and
  samples on this process's 1-device mesh, bit-identical to the mesh run;
* the existing engine-parity and serve-chunking suites hold verbatim under
  a populated device table.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import MeshSpec, PASArtifact, Pipeline, SamplerSpec
from repro.core import analytic
from repro.core.pas import PASParams
from repro.engine import SamplingEngine, get_engine_for_spec

DIM = 16
NFE = 5

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _env8():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    return env


@pytest.fixture(scope="module")
def gmm():
    return analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)


def _params():
    active = np.zeros(NFE, dtype=bool)
    active[[1, 3]] = True
    coords = np.zeros((NFE, 4), np.float32)
    coords[1] = [1.0, 0.05, 0.0, 0.0]
    coords[3] = [0.98, -0.04, 0.0, 0.0]
    return PASParams(active=active, coords=jnp.asarray(coords))


# ---------------------------------------------------------------------------
# MeshSpec semantics (single device)
# ---------------------------------------------------------------------------


def test_meshspec_validation_and_geometry():
    ms = MeshSpec(dp=4, state=2)
    assert ms.n_devices == 8 and not ms.is_single
    assert MeshSpec().is_single
    assert tuple(ms.x_pspec()) == ("data", "model")
    assert tuple(MeshSpec(dp=4).x_pspec()) == ("data", None)
    assert tuple(MeshSpec(state=4).x_pspec()) == (None, "model")
    assert ms.pad_batch(10) == 2 and ms.pad_batch(8) == 0
    assert MeshSpec().pad_batch(7) == 0
    with pytest.raises(ValueError):
        MeshSpec(dp=0)
    with pytest.raises(ValueError):
        MeshSpec(batch_axis="model", state_axis="model")


def test_meshspec_json_round_trip_and_hash():
    ms = MeshSpec(dp=8, state=2, batch_axis="data", state_axis="model")
    assert MeshSpec.from_dict(json.loads(json.dumps(ms.to_dict()))) == ms
    assert hash(MeshSpec(dp=8, state=2)) == hash(ms)
    assert MeshSpec.from_dict(None) == MeshSpec()


def test_meshspec_build_requires_devices():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        MeshSpec(dp=1 + len(jax.devices())).build()


def test_spec_mesh_in_engine_key_and_sans_mesh():
    s1 = SamplerSpec(solver="ddim", nfe=NFE)
    s8 = s1.replace(mesh=MeshSpec(dp=8))
    assert s1.engine_key != s8.engine_key
    assert s1.sans_mesh() == s8.sans_mesh() == s1
    # JSON round trip carries placement
    assert SamplerSpec.from_json(s8.to_json()) == s8
    # specs lacking a mesh field (pre-mesh artifacts) default to trivial
    d = s1.to_dict()
    del d["mesh"]
    assert SamplerSpec.from_dict(d) == s1


def test_trivial_mesh_engine_is_single_device(gmm):
    """dp=1 x state=1 binds no mesh at all — the exact pre-mesh program."""
    eng = SamplingEngine(
        SamplerSpec(solver="ddim", nfe=NFE).make_solver(),
        mesh=MeshSpec(dp=1, state=1))
    assert eng.mesh is None and eng.mesh_spec is None
    x = gmm.sample_prior(jax.random.key(0), 4, 80.0)
    assert eng.shard(x) is x
    want = SamplingEngine(
        SamplerSpec(solver="ddim", nfe=NFE).make_solver()).sample(gmm.eps, x)
    np.testing.assert_array_equal(np.asarray(eng.sample(gmm.eps, x)),
                                  np.asarray(want))


def test_engine_cache_keys_on_mesh():
    s = SamplerSpec(solver="ipndm2", nfe=NFE)
    e1 = get_engine_for_spec(s)
    assert get_engine_for_spec(s.replace(mesh=MeshSpec())) is e1
    # a different placement is a different compiled binding (can't build an
    # 8-device engine here; key inequality is the contract)
    assert s.engine_key != s.replace(mesh=MeshSpec(dp=8)).engine_key


def test_artifact_spec_compare_is_modulo_mesh(tmp_path, gmm):
    """An artifact records placement but never gates on it."""
    spec8 = SamplerSpec(solver="ddim", nfe=NFE, mesh=MeshSpec(dp=8))
    art = PASArtifact(spec8, _params(), {"note": "mesh test"})
    art.save(tmp_path)
    # expected_spec on a *different* mesh: loads (modulo-mesh compare)
    art2 = PASArtifact.load(tmp_path,
                            expected_spec=spec8.replace(mesh=MeshSpec()))
    assert art2.spec == spec8                      # recorded mesh kept
    # re-place onto this process's single device and actually sample
    art3 = PASArtifact.load(tmp_path, mesh=MeshSpec())
    assert art3.spec == spec8.sans_mesh()
    pipe = Pipeline(art3.spec, gmm.eps, dim=DIM, params=art3.params)
    assert pipe.sample(key=jax.random.key(0), batch=4).shape == (4, DIM)
    # the math still gates: a different solver raises
    with pytest.raises(Exception, match="does not match"):
        PASArtifact.load(tmp_path,
                         expected_spec=spec8.replace(solver="ipndm2"))


def test_aot_compile_reports_single_device(gmm):
    pipe = Pipeline.from_spec(SamplerSpec(solver="ddim", nfe=NFE), gmm.eps,
                              dim=DIM)
    info = pipe.engine.aot_compile(gmm.eps, batch=4, dim=DIM)
    assert info["devices"] == 1 and info["mesh"] is None
    assert info["collectives"] == {}


# ---------------------------------------------------------------------------
# 8 virtual devices: the acceptance contract (subprocess)
# ---------------------------------------------------------------------------

_MESH_ACCEPTANCE = r"""
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.api import (MeshSpec, PASConfig, Pipeline, SamplerSpec, TeacherSpec)
from repro.core import two_mode_gmm
from repro.core.pas import PASParams
from repro.runtime import DiffusionServer, Request, ServeConfig

assert len(jax.devices()) == 8, jax.devices()
DIM, NFE = 24, 6
gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)
art_dir = sys.argv[1]

active = np.zeros(NFE, bool); active[[1, 3]] = True
coords = np.zeros((NFE, 4), np.float32)
coords[1] = [1.0, 0.05, 0.0, 0.0]; coords[3] = [0.98, -0.04, 0.0, 0.0]
params = PASParams(active=active, coords=jnp.asarray(coords))

x = np.asarray(gmm.sample_prior(jax.random.key(3), 16, 80.0))

# 1) dp=8 == single device, bit for bit, plain + PAS, ddim + ipndm4
for solver in ("ddim", "ipndm4"):
    s1 = SamplerSpec(solver=solver, nfe=NFE)
    p1 = Pipeline.from_spec(s1, gmm.eps, dim=DIM).set_params(params)
    p8 = Pipeline.from_spec(s1.replace(mesh=MeshSpec(dp=8)), gmm.eps,
                            dim=DIM).set_params(params)
    for use_pas in (False, True):
        a = np.asarray(p1.sample(jnp.asarray(x), use_pas=use_pas))
        b = np.asarray(p8.sample(jnp.asarray(x), use_pas=use_pas))
        assert np.array_equal(a, b), (solver, use_pas, np.abs(a - b).max())
print("DP8_BITEXACT_OK")

# 2) shard_map PAS collectives (state sharding) == replicated PAS
p_state = Pipeline.from_spec(
    SamplerSpec(solver="ddim", nfe=NFE, mesh=MeshSpec(dp=2, state=4)),
    gmm.eps, dim=DIM).set_params(params)
p_ref = Pipeline.from_spec(SamplerSpec(solver="ddim", nfe=NFE),
                           gmm.eps, dim=DIM).set_params(params)
a = np.asarray(p_ref.sample(jnp.asarray(x)))
b = np.asarray(p_state.sample(jnp.asarray(x)))
np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
print("SHARDMAP_PAS_OK")

# 3) serve pads flushes to DP-divisible batches and counts real evals
cfg = ServeConfig(nfe=NFE, solver="ddim", max_batch=16, use_pas=False,
                  mesh=MeshSpec(dp=8))
server = DiffusionServer(gmm.eps, DIM, cfg)
sizes = []
orig = server._run_batch
server._run_batch = lambda xt: (sizes.append(int(xt.shape[0])), orig(xt))[1]
outs = server.serve([Request(seed=0, n_samples=5), Request(seed=1, n_samples=6)])
assert [o.shape[0] for o in outs] == [5, 6]
assert sizes == [16], sizes                       # 11 rows padded to 16
assert server.stats["padded_samples"] == 5
assert server.stats["nfe_total"] == 16 * NFE, server.stats
print("SERVE_PAD_OK")

# 4) calibrate on the 8-device mesh, save artifact + the samples it produced
spec8 = SamplerSpec(solver="ddim", nfe=NFE, teacher=TeacherSpec(nfe=30),
                    pas=PASConfig(n_sgd_iters=40), mesh=MeshSpec(dp=8))
pipe8 = Pipeline.from_spec(spec8, gmm.eps, dim=DIM)
pipe8.calibrate(key=jax.random.key(0), batch=64)
pipe8.save(art_dir)
x_eval = np.asarray(gmm.sample_prior(jax.random.key(9), 8, 80.0))
y_mesh = np.asarray(pipe8.sample(jnp.asarray(x_eval)))
np.savez(art_dir + "/mesh_samples.npz", x_eval=x_eval, y_mesh=y_mesh)
print("ARTIFACT_SAVED_OK")
"""


@pytest.mark.slow
def test_mesh_acceptance_8_devices(tmp_path):
    """The subprocess half of the acceptance contract, then the cross-mesh
    artifact reload back in this (1-device) process."""
    out = subprocess.run(
        [sys.executable, "-c", _MESH_ACCEPTANCE, str(tmp_path)],
        capture_output=True, text=True, env=_env8(), timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    for marker in ("DP8_BITEXACT_OK", "SHARDMAP_PAS_OK", "SERVE_PAD_OK",
                   "ARTIFACT_SAVED_OK"):
        assert marker in out.stdout

    # artifact calibrated on an 8-device mesh -> sampled on 1 device
    gmm = analytic.two_mode_gmm(24, sep=6.0, var=0.25)
    art = PASArtifact.load(tmp_path)
    assert art.spec.mesh == MeshSpec(dp=8)         # placement was recorded
    pipe = Pipeline.load(tmp_path, gmm.eps, dim=24, mesh=MeshSpec())
    assert pipe.mesh_spec.is_single
    data = np.load(tmp_path / "mesh_samples.npz")
    y_local = np.asarray(pipe.sample(jnp.asarray(data["x_eval"])))
    # bit-exactness is a same-process contract (asserted inside the
    # subprocess); across processes the forced 8-device host partitioning
    # changes XLA-CPU codegen/threading, so fp32 rounding drifts (mean
    # ~1e-4, observed max ~2.3e-3 with the fused-calibration operating
    # point of 3 corrected steps)
    np.testing.assert_allclose(y_local, data["y_mesh"], rtol=0, atol=5e-3)


@pytest.mark.slow
def test_parity_and_serve_suites_under_8_devices():
    """The satellite sweep: the single-device engine parity suite and the
    serve chunking tests must hold verbatim on a populated device table."""
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.join(_ROOT, "tests", "test_engine.py"),
         os.path.join(_ROOT, "tests", "test_api.py"),
         "-k", "parity or serve"],
        capture_output=True, text=True, env=_env8(), cwd=_ROOT, timeout=1500)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
