"""repro.api: SamplerSpec / Pipeline / PASArtifact + serve-loop chunking.

Covers the acceptance contract of the api redesign:
* specs are hashable, JSON-round-trippable, and the canonical engine-cache
  key (legacy ``(name, ts, dtype)`` lookups share entries with spec lookups);
* ``Pipeline.from_spec(...).calibrate(...).save(d)`` then
  ``Pipeline.load(d, eps_fn).sample(...)`` is bit-identical to the in-memory
  pipeline — including across a cleared engine cache (fresh compile);
* artifacts are checksummed: tampering with the payload raises;
* ``DiffusionServer`` chunks oversized requests instead of silently running
  one oversized batch.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ArtifactError, PASArtifact, PASConfig, Pipeline,
                       SamplerSpec, ScheduleSpec, TeacherSpec,
                       spec_from_schedule)
from repro.core import analytic, schedules
from repro.engine import (clear_engine_cache, engine_cache_stats,
                          engine_for_solver, get_engine, get_engine_for_spec)
from repro.engine.engine import _fn_key
from repro.runtime import DiffusionServer, Request, ServeConfig

DIM = 16
NFE = 5


@pytest.fixture(scope="module")
def gmm():
    return analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)


def _spec(solver="ddim", **kw) -> SamplerSpec:
    base = dict(solver=solver, nfe=NFE,
                teacher=TeacherSpec(solver="heun", nfe=25),
                pas=PASConfig(n_sgd_iters=30))
    base.update(kw)
    return SamplerSpec(**base)


# ---------------------------------------------------------------------------
# SamplerSpec
# ---------------------------------------------------------------------------


def test_spec_hashable_and_json_round_trip():
    spec = _spec()
    assert hash(spec) == hash(_spec())
    s2 = SamplerSpec.from_json(spec.to_json())
    assert s2 == spec and hash(s2) == hash(spec)
    # dict round trip too (the artifact header path)
    assert SamplerSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) == spec


def test_spec_raw_schedule_round_trip():
    ts = np.linspace(50.0, 0.01, NFE + 1)
    spec = _spec(schedule=ScheduleSpec.raw(ts))
    np.testing.assert_array_equal(spec.ts(), ts)
    assert SamplerSpec.from_json(spec.to_json()) == spec
    # raw teacher grid nests the student grid exactly
    s, t, m = spec.teacher_grid()
    np.testing.assert_array_equal(t[:: m + 1], s)
    assert np.all(np.diff(t) < 0)


def test_spec_validation():
    with pytest.raises(ValueError):
        SamplerSpec(solver="no-such-solver")
    with pytest.raises(ValueError):
        SamplerSpec(teacher=TeacherSpec(solver="no-such-teacher"))
    with pytest.raises(ValueError):
        ScheduleSpec(kind="raw")                       # raw needs points
    with pytest.raises(ValueError):
        _spec(schedule=ScheduleSpec.raw([80.0, 1.0])).ts()   # wrong length
    with pytest.raises(ValueError):
        _spec(teacher=TeacherSpec(nfe=NFE)).teacher_grid()   # teacher too small


def test_spec_polynomial_grid_matches_schedules():
    spec = _spec()
    np.testing.assert_array_equal(
        spec.ts(), schedules.polynomial_schedule(NFE, 0.002, 80.0))
    s, t, m = spec.teacher_grid()
    s2, t2, m2 = schedules.nested_teacher_schedule(NFE, 25, 0.002, 80.0)
    np.testing.assert_array_equal(s, s2)
    np.testing.assert_array_equal(t, t2)
    assert m == m2


# ---------------------------------------------------------------------------
# spec-canonical engine cache + legacy shim
# ---------------------------------------------------------------------------


def test_engine_cache_spec_is_canonical_key():
    clear_engine_cache()
    spec = _spec(solver="ipndm3")
    e1 = get_engine_for_spec(spec)
    # legacy tuple keying lands on the same entry
    assert get_engine("ipndm3", spec.ts()) is e1
    assert engine_for_solver(spec.make_solver()) is e1
    # teacher/PASConfig changes don't re-bind the engine
    assert get_engine_for_spec(
        spec.replace(pas=PASConfig(n_basis=2),
                     teacher=TeacherSpec(nfe=50))) is e1
    assert engine_cache_stats()["engines"] == 1
    # engine-relevant changes do
    assert get_engine_for_spec(spec.replace(solver="ddim")) is not e1
    assert get_engine_for_spec(spec.replace(dtype="bfloat16")) is not e1


def test_engine_cache_raw_schedule_shim(gmm):
    clear_engine_cache()
    ts = np.linspace(40.0, 0.01, NFE + 1)          # not a polynomial schedule
    e1 = get_engine("ddim", ts)
    assert get_engine("ddim", ts.copy()) is e1
    assert spec_from_schedule("ddim", ts).schedule.kind == "raw"
    x = gmm.sample_prior(jax.random.key(0), 2, 40.0)
    assert e1.sample(gmm.eps, x).shape == x.shape


def test_engine_for_solver_accepts_unregistered_solver(gmm):
    """Custom solver objects outside the registry still get an engine."""
    import dataclasses

    from repro.core import solvers as solvers_mod
    base = solvers_mod.make_solver("ddim", schedules.polynomial_schedule(NFE))
    custom = dataclasses.replace(base, name="my-custom-lms")
    e1 = engine_for_solver(custom)
    assert engine_for_solver(custom) is e1           # cached
    x = gmm.sample_prior(jax.random.key(0), 2, 80.0)
    np.testing.assert_allclose(
        np.asarray(e1.sample(gmm.eps, x)),
        np.asarray(engine_for_solver(base).sample(gmm.eps, x)),
        rtol=1e-6, atol=1e-6)


def test_fn_key_pins_hashable_callables(gmm):
    def f(x, t):
        return x
    assert _fn_key(f) is f                          # the key pins the fn
    assert _fn_key(gmm.eps) == _fn_key(gmm.eps)     # bound methods stay equal


def test_unhashable_eps_fn_still_cached(gmm):
    class UnhashableEps:
        __hash__ = None

        def __call__(self, x, t):
            return 0.1 * x

    eps = UnhashableEps()
    key = _fn_key(eps)
    assert not isinstance(key, UnhashableEps)       # fell back to id keying
    eng = get_engine_for_spec(_spec())
    x = jnp.ones((2, DIM))
    before = eng.compiled_variants()
    eng.sample(eps, x)
    eng.sample(eps, x)                              # second call: cache hit
    assert eng.compiled_variants() == before + 1


# ---------------------------------------------------------------------------
# PASArtifact + Pipeline persistence
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def calibrated(gmm):
    pipe = Pipeline.from_spec(_spec(), gmm.eps, dim=DIM)
    pipe.calibrate(key=jax.random.key(0), batch=48)
    assert pipe.calibrated and pipe.params.active.any()
    return pipe


def test_artifact_round_trip(tmp_path, calibrated):
    calibrated.save(tmp_path)
    art = PASArtifact.load(tmp_path)
    assert art.spec == calibrated.spec
    np.testing.assert_array_equal(np.asarray(art.params.active),
                                  np.asarray(calibrated.params.active))
    np.testing.assert_array_equal(np.asarray(art.params.coords),
                                  np.asarray(calibrated.params.coords))
    assert art.params.coords.dtype == calibrated.params.coords.dtype
    assert art.diag["n_stored_params"] == calibrated.params.n_stored_params


def test_artifact_checksum_tamper_raises(tmp_path, calibrated):
    calibrated.save(tmp_path)
    payload = next(PASArtifact.root(tmp_path).glob("step_*/[0-9]*coords*.npy"))
    raw = bytearray(payload.read_bytes())
    raw[-1] ^= 0xFF
    payload.write_bytes(bytes(raw))
    with pytest.raises(Exception, match="checksum"):
        PASArtifact.load(tmp_path)


def test_artifact_missing_and_spec_mismatch(tmp_path, calibrated):
    with pytest.raises(ArtifactError, match="no PAS artifact"):
        PASArtifact.load(tmp_path / "empty")
    calibrated.save(tmp_path)
    with pytest.raises(ArtifactError, match="does not match"):
        PASArtifact.load(tmp_path, expected_spec=_spec(solver="ipndm2"))


def test_artifact_version_gate(tmp_path, calibrated):
    calibrated.save(tmp_path)
    manifest_path = next(
        PASArtifact.root(tmp_path).glob("step_*/manifest.json"))
    manifest = json.loads(manifest_path.read_text())
    manifest["extra"]["version"] = 999
    manifest_path.write_text(json.dumps(manifest))
    with pytest.raises(ArtifactError, match="version"):
        PASArtifact.load(tmp_path)


@pytest.mark.parametrize("solver", ["ddim", "ipndm4"])
def test_pipeline_load_sample_parity(tmp_path, gmm, solver):
    """Loaded pipeline == in-memory pipeline, bit for bit, fresh compile."""
    pipe = Pipeline.from_spec(_spec(solver=solver), gmm.eps, dim=DIM)
    pipe.calibrate(key=jax.random.key(0), batch=48)
    x_e = gmm.sample_prior(jax.random.key(9), 4, 80.0)
    want = np.asarray(pipe.sample(x_e))
    d = tmp_path / solver
    pipe.save(d)

    clear_engine_cache()                   # force a fresh engine + compile
    pipe2 = Pipeline.load(d, gmm.eps, dim=DIM)
    assert pipe2.spec == pipe.spec
    got = np.asarray(pipe2.sample(x_e))
    np.testing.assert_array_equal(got, want)
    # plain path parity rides along
    np.testing.assert_array_equal(np.asarray(pipe2.sample(x_e, use_pas=False)),
                                  np.asarray(pipe.sample(x_e, use_pas=False)))


def test_pipeline_save_requires_calibration(tmp_path, gmm):
    pipe = Pipeline.from_spec(_spec(), gmm.eps, dim=DIM)
    with pytest.raises(ValueError, match="not calibrated"):
        pipe.save(tmp_path)


def test_pipeline_stats_and_trajectory(gmm, calibrated):
    x = gmm.sample_prior(jax.random.key(3), 4, 80.0)
    x0, xs = calibrated.trajectory(x)
    assert xs.shape == (NFE + 1, 4, DIM)
    np.testing.assert_array_equal(np.asarray(xs[-1]), np.asarray(x0))
    st = calibrated.stats()
    assert st["calibrated"] and st["n_stored_params"] >= 1
    assert st["spec"]["solver"] == "ddim"


# ---------------------------------------------------------------------------
# DiffusionServer: micro-batching shell + oversized-request chunking
# ---------------------------------------------------------------------------


def _tracking_server(gmm, max_batch):
    cfg = ServeConfig(nfe=NFE, solver="ddim", max_batch=max_batch,
                      use_pas=False)
    server = DiffusionServer(gmm.eps, DIM, cfg)
    seen = []
    orig = server._run_batch

    def tracked(x_t):
        seen.append(int(x_t.shape[0]))
        return orig(x_t)

    server._run_batch = tracked
    return server, seen


def test_serve_chunks_oversized_request(gmm):
    server, seen = _tracking_server(gmm, max_batch=8)
    outs = server.serve([Request(seed=0, n_samples=20)])
    assert outs[0].shape == (20, DIM)
    assert sum(seen) == 20 and max(seen) <= 8 and len(seen) >= 3
    assert server.stats["batches"] == len(seen)
    # row-level parity with the unchunked pipeline run
    want = np.asarray(server.pipeline.sample(
        server.pipeline.prior(jax.random.key(0), 20), use_pas=False))
    np.testing.assert_allclose(outs[0], want, rtol=1e-5, atol=1e-5)


def test_serve_packs_remainder_with_later_requests(gmm):
    server, seen = _tracking_server(gmm, max_batch=8)
    reqs = [Request(seed=0, n_samples=4), Request(seed=1, n_samples=20),
            Request(seed=2, n_samples=4)]
    outs = server.serve(reqs)
    assert [o.shape[0] for o in outs] == [4, 20, 4]
    assert sum(seen) == 28 and max(seen) <= 8
    # every request's rows come from its own seed
    for req, out in zip(reqs, outs):
        want = np.asarray(server.pipeline.sample(
            server.pipeline.prior(jax.random.key(req.seed), req.n_samples),
            use_pas=False))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_serve_small_requests_unchanged(gmm):
    """Requests within budget are never split (pre-chunking behaviour)."""
    server, seen = _tracking_server(gmm, max_batch=8)
    outs = server.serve([Request(seed=i, n_samples=3) for i in range(5)])
    assert [o.shape[0] for o in outs] == [3] * 5
    assert seen == [6, 6, 3]


def test_serve_nfe_counts_model_evals_executed(gmm):
    """nfe_total = per-row model evals actually executed, chunked flushes
    included — regression for the per-batch x nominal-NFE accounting.

    A 2-eval teacher (heun) over NFE intervals costs 2*NFE evals per row;
    a 20-row request chunked at max_batch=8 executes 8+8+4 rows.
    """
    cfg = ServeConfig(nfe=NFE, solver="heun", max_batch=8, use_pas=False)
    server = DiffusionServer(gmm.eps, DIM, cfg)
    evals_per_row = server.engine.nfe
    assert evals_per_row == 2 * NFE              # evals, not steps
    server.serve([Request(seed=0, n_samples=20)])
    assert server.stats["nfe_total"] == 20 * evals_per_row
    assert server.stats["padded_samples"] == 0
    # a second, packed flush keeps counting real rows
    server.serve([Request(seed=1, n_samples=3), Request(seed=2, n_samples=4)])
    assert server.stats["nfe_total"] == 27 * evals_per_row


def test_serve_config_to_spec_round_trip():
    cfg = ServeConfig(nfe=7, solver="ipndm2", t_min=0.01, t_max=40.0)
    spec = cfg.to_spec()
    assert spec.nfe == 7 and spec.solver == "ipndm2"
    ts = spec.ts()
    assert ts[0] == 40.0 and ts[-1] == 0.01
    # from_pipeline reproduces the pipeline's spec *exactly* (regression:
    # rebuilding from schedule endpoints dropped raw points / custom rho)
    gmm = analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)
    server = DiffusionServer.from_pipeline(
        Pipeline.from_spec(spec, gmm.eps, dim=DIM))
    assert server.cfg.nfe == 7 and server.cfg.t_max == 40.0
    assert server.cfg.to_spec() == spec
    for tricky in (spec.replace(schedule=ScheduleSpec.raw(ts)),
                   spec.replace(schedule=ScheduleSpec(
                       t_min=0.01, t_max=40.0, rho=3.0))):
        pipe = Pipeline.from_spec(tricky, gmm.eps, dim=DIM)
        assert DiffusionServer.from_pipeline(pipe).cfg.to_spec() == tricky
    assert Path(PASArtifact.root("x")).name == "pas_artifact"
