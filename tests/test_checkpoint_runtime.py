"""Checkpointing (integrity, atomicity, resume) + fault-tolerant train loop."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data.pipeline import Prefetcher, TokenStream
from repro.optim import SGD
from repro.runtime import TrainLoopConfig, run_train_loop


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 4)),
            "layers": [{"a": jax.random.normal(k2, (3,))},
                       {"a": jnp.zeros((3,))}],
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.key(0))
    ckpt.save(tmp_path, 5, tree, extra={"note": "hi"})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, extra = ckpt.restore(tmp_path, like)
    assert extra["note"] == "hi"
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_checksum_detects_corruption(tmp_path):
    tree = _tree(jax.random.key(1))
    d = ckpt.save(tmp_path, 1, tree)
    victim = sorted(d.glob("*.npy"))[0]
    arr = np.load(victim)
    arr_flat = arr.reshape(-1)
    arr_flat[0] += 1.0
    np.save(victim, arr)
    with pytest.raises(ckpt.CheckpointError, match="checksum"):
        ckpt.restore(tmp_path, tree)


def test_latest_step_survives_missing_pointer(tmp_path):
    tree = _tree(jax.random.key(2))
    ckpt.save(tmp_path, 3, tree)
    ckpt.save(tmp_path, 9, tree)
    (tmp_path / "LATEST").unlink()          # simulate crash before pointer
    assert ckpt.latest_step(tmp_path) == 9


def test_cleanup_keeps_n(tmp_path):
    tree = {"x": jnp.ones(2)}
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, tree)
    ckpt.cleanup(tmp_path, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]


def test_shape_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"x": jnp.ones((4,))})
    with pytest.raises(ckpt.CheckpointError, match="shape"):
        ckpt.restore(tmp_path, {"x": jnp.ones((5,))})


# ---------------------------------------------------------------------------
# train loop: resume determinism + straggler monitor
# ---------------------------------------------------------------------------

def _toy_problem():
    """Tiny linear regression 'trainer' with deterministic data."""
    opt = SGD(lr=0.05, momentum=0.0)
    w_true = jnp.asarray([1.5, -2.0, 0.5])

    def batches():
        step = 0
        while True:
            key = jax.random.key(step)
            x = jax.random.normal(key, (32, 3))
            y = x @ w_true
            yield {"x": x, "y": y}
            step += 1

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            pred = batch["x"] @ p["w"]
            return jnp.mean((pred - batch["y"]) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, {"ce_loss": loss}

    params = {"w": jnp.zeros(3)}
    return step_fn, params, opt.init(params), batches


def test_train_loop_runs_and_checkpoints(tmp_path):
    step_fn, params, opt_state, batches = _toy_problem()
    cfg = TrainLoopConfig(total_steps=30, ckpt_dir=str(tmp_path / "ck"),
                          ckpt_every=10, log_every=5,
                          metrics_path=str(tmp_path / "m.jsonl"))
    p, o, summary = run_train_loop(step_fn, params, opt_state, batches(), cfg)
    assert summary["final_step"] == 30
    assert ckpt.latest_step(tmp_path / "ck") == 30
    rows = [json.loads(l) for l in open(tmp_path / "m.jsonl")]
    assert rows[0]["ce_loss"] > rows[-1]["ce_loss"]


def test_train_loop_resume_is_deterministic(tmp_path):
    """Interrupted run + resume == uninterrupted run (bitwise on params)."""
    # uninterrupted reference
    step_fn, params, opt_state, batches = _toy_problem()
    cfg_a = TrainLoopConfig(total_steps=20, ckpt_dir=str(tmp_path / "a"),
                            ckpt_every=100)
    p_ref, _, _ = run_train_loop(step_fn, params, opt_state, batches(), cfg_a)

    # interrupted at 10, then resumed to 20 from disk
    step_fn, params, opt_state, batches = _toy_problem()
    cfg_b1 = TrainLoopConfig(total_steps=10, ckpt_dir=str(tmp_path / "b"),
                             ckpt_every=5)
    run_train_loop(step_fn, params, opt_state, batches(), cfg_b1)
    step_fn, params, opt_state, batches = _toy_problem()
    cfg_b2 = TrainLoopConfig(total_steps=20, ckpt_dir=str(tmp_path / "b"),
                             ckpt_every=5)
    p_res, _, summary = run_train_loop(step_fn, params, opt_state, batches(),
                                       cfg_b2)
    assert summary["resumed_from"] == 10
    np.testing.assert_allclose(np.asarray(p_ref["w"]), np.asarray(p_res["w"]),
                               rtol=1e-6)


def test_straggler_monitor_trips():
    from repro.runtime import StragglerMonitor
    mon = StragglerMonitor(factor=3.0, warmup=2)
    for step in range(10):
        assert not mon.observe(step, 0.1)
    assert mon.observe(10, 1.0)          # 10x the EMA
    assert mon.events and mon.events[0]["step"] == 10


def test_prefetcher_yields_and_propagates_errors():
    stream = TokenStream(vocab_size=50, seq_len=8, global_batch=4)
    pf = Prefetcher(iter(stream), depth=2)
    b = next(pf)
    assert b["tokens"].shape == (4, 8)
    pf.close()

    def bad():
        yield {"ok": 1}
        raise RuntimeError("loader died")

    pf2 = Prefetcher(bad(), depth=1)
    next(pf2)
    with pytest.raises(RuntimeError, match="loader died"):
        next(pf2)


def test_tokenstream_deterministic_and_sharded():
    a = TokenStream(vocab_size=100, seq_len=16, global_batch=8).batch(3)
    b = TokenStream(vocab_size=100, seq_len=16, global_batch=8).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    s0 = TokenStream(vocab_size=100, seq_len=16, global_batch=8,
                     shard=0, n_shards=2).batch(3)
    s1 = TokenStream(vocab_size=100, seq_len=16, global_batch=8,
                     shard=1, n_shards=2).batch(3)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
