"""Real backbones on the mesh: ``repro.models.eps`` + TP-in-the-scan parity.

In-process tests run on the single CPU device: ``build_eps`` semantics
(seq/seed plumbing, the deprecated launcher shim, the one-shared-param-tree
cache that deduplicates ladder lanes), ``MeshSpec.tp`` geometry, and the
launcher's ``--mesh DPxSTATE[xTP]`` parsing.

The subprocess test re-runs on 8 virtual host devices and asserts the ISSUE
acceptance contract: an attention backbone materialized TP-sharded (params
born on their shards, per-layer ``constrain`` active inside the engine scan)
samples and calibrates inside the DP sampler, matching the replicated oracle
within the documented ``EPS_TP_TOL`` — plus the same forward parity for one
MoE and one SSM architecture.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import MeshSpec, SamplerSpec
from repro.models import build_eps, clear_eps_cache, get_eps_model
from repro.runtime import NFELadder, ServeConfig
from repro.launch.serve import parse_mesh

ARCH = "qwen1.5-0.5b"

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _env8():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    return env


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_eps_cache()
    yield
    clear_eps_cache()


# ---------------------------------------------------------------------------
# build_eps semantics (single device)
# ---------------------------------------------------------------------------


def test_build_eps_smoke_and_model_key():
    m = build_eps(ARCH, seq=8)
    assert m.dim == 8 * m.cfg.d_model
    assert m.model_key == f"diffusion:{ARCH}:seq8:seed0:{m.dim}"
    assert m.n_params > 0
    x = jax.random.normal(jax.random.key(1), (4, m.dim))
    eps = m.fn(x, jnp.float32(2.0))
    assert eps.shape == (4, m.dim)
    assert bool(jnp.isfinite(eps).all())


def test_build_eps_seq_and_seed_are_plumbed():
    m8 = build_eps(ARCH, seq=8)
    m4 = build_eps(ARCH, seq=4)
    assert m4.dim == m8.dim // 2
    # a different model seed is a different weight tree (same shapes)
    m8b = build_eps(ARCH, seq=8, seed=1)
    la, lb = (jax.tree_util.tree_leaves(m.params) for m in (m8, m8b))
    assert any(not np.array_equal(a, b) for a, b in zip(la, lb))
    with pytest.raises(ValueError, match="seq"):
        build_eps(ARCH, seq=0)


def test_get_eps_model_is_one_shared_tree():
    """The ladder-lane dedupe: same (arch, seq, seed, mesh) -> the SAME
    EpsModel — one param tree, one eps closure, one engine fn key."""
    m1 = get_eps_model(ARCH, seq=8)
    m2 = get_eps_model(ARCH, seq=8)
    assert m1 is m2
    assert m1.params is m2.params and m1.fn is m2.fn
    assert get_eps_model(ARCH, seq=8, seed=1) is not m1
    assert get_eps_model(ARCH, seq=4) is not m1
    clear_eps_cache()
    assert get_eps_model(ARCH, seq=8) is not m1


def test_ladder_lanes_share_one_param_tree():
    """Regression (satellite): building a full NFE ladder router from the
    cached model must not re-init per lane — every lane closes over the
    identical param leaves."""
    model = get_eps_model(ARCH, seq=4)
    ladder = NFELadder(SamplerSpec(solver="ddim", nfe=4), nfes=(2, 4))
    router = ladder.build_router(model.fn, model.dim)
    fns = {id(p.eps_fn) for p in router.pipelines.values()}
    assert fns == {id(model.fn)}
    # the "second launch" path: a re-resolve hands back identical leaf ids
    again = get_eps_model(ARCH, seq=4)
    ids1 = [id(l) for l in jax.tree_util.tree_leaves(model.params)]
    ids2 = [id(l) for l in jax.tree_util.tree_leaves(again.params)]
    assert ids1 == ids2
    # and the router actually samples with the shared tree
    out = router.pipelines["nfe2"].sample(key=jax.random.key(0), batch=2,
                                          use_pas=False)
    assert out.shape == (2, model.dim)


def test_deprecated_launcher_shim_is_bit_identical():
    from repro.launch.serve import _diffusion_lm_eps
    with pytest.warns(DeprecationWarning, match="build_eps"):
        fn, dim = _diffusion_lm_eps(ARCH, seq=8)
    m = build_eps(ARCH, seq=8)
    assert dim == m.dim
    x = jax.random.normal(jax.random.key(2), (3, dim))
    np.testing.assert_array_equal(np.asarray(fn(x, jnp.float32(1.5))),
                                  np.asarray(m.fn(x, jnp.float32(1.5))))


# ---------------------------------------------------------------------------
# MeshSpec tp geometry + launcher plumbing (single device)
# ---------------------------------------------------------------------------


def test_meshspec_tp_geometry():
    ms = MeshSpec(dp=2, state=1, tp=4)
    assert ms.n_devices == 8 and not ms.is_single
    assert MeshSpec(tp=1).is_single
    # engine identity: tp is part of placement, hence of the engine key
    s = SamplerSpec(solver="ddim", nfe=4)
    assert (s.replace(mesh=MeshSpec(dp=2)).engine_key
            != s.replace(mesh=MeshSpec(dp=2, tp=2)).engine_key)
    # pre-TP dicts (no "tp" key) load as tp=1; round trip keeps tp
    d = ms.to_dict()
    assert MeshSpec.from_dict(d) == ms
    del d["tp"], d["tp_axis"]
    assert MeshSpec.from_dict(d) == MeshSpec(dp=2, state=1)
    with pytest.raises(ValueError):
        MeshSpec(tp=0)
    with pytest.raises(ValueError):
        MeshSpec(state_axis="tensor")     # collides with tp_axis


def test_meshspec_tp1_build_is_legacy_two_axis():
    """tp=1 must build the exact pre-TP 2-axis mesh (same axis names), so
    cache keys and compiled programs of existing specs are untouched."""
    assert MeshSpec(dp=1, state=1, tp=1).is_single
    built = MeshSpec(dp=len(jax.devices()), state=1).build()
    assert built.axis_names == ("data", "model")


def test_parse_mesh_accepts_optional_tp():
    import argparse
    assert parse_mesh("4x2") == (4, 2, 1)
    assert parse_mesh("2x1x4") == (2, 1, 4)
    for bad in ("8", "x4", "2x", "2x2x", "0x1", "2x1x0", "axb"):
        with pytest.raises(argparse.ArgumentTypeError):
            parse_mesh(bad)


def test_serve_config_seq_and_model_seed():
    cfg = ServeConfig(nfe=4, solver="ddim")
    assert cfg.seq == 32 and cfg.model_seed == 0
    assert ServeConfig(nfe=4, solver="ddim", seq=8, model_seed=3).seq == 8
    with pytest.raises(ValueError):
        ServeConfig(nfe=4, solver="ddim", seq=0)


# ---------------------------------------------------------------------------
# 8 virtual devices: TP-sharded backbone inside the DP scan (subprocess)
# ---------------------------------------------------------------------------

_TP_PARITY = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.api import MeshSpec, PASConfig, Pipeline, SamplerSpec, TeacherSpec
from repro.models import EPS_TP_TOL, build_eps

assert len(jax.devices()) == 8, jax.devices()
SEQ, B = 8, 8
ARCHS = {"attn": "qwen1.5-0.5b", "moe": "mixtral-8x7b", "ssm": "falcon-mamba-7b"}

# 1) params are born on their shards AND value-identical to replicated init
#    (threefry is placement-independent); forward agrees within EPS_TP_TOL
ref = build_eps(ARCHS["attn"], seq=SEQ)
x = jax.random.normal(jax.random.key(0), (B, ref.dim))
y_ref = np.asarray(ref.fn(x, jnp.float32(2.0)))
for ms in (MeshSpec(tp=2), MeshSpec(tp=4), MeshSpec(dp=2, tp=2)):
    m = build_eps(ARCHS["attn"], seq=SEQ, mesh=ms)
    sharded = [l for l in jax.tree_util.tree_leaves(m.params)
               if len(l.sharding.device_set) > 1]
    if ms.tp > 1:
        assert sharded, f"no TP-sharded leaves under {ms}"
    for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(m.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(m.fn(x, jnp.float32(2.0))), y_ref,
                               **EPS_TP_TOL)
print("ATTN_PARAMS_FORWARD_OK")

# 2) same forward contract for a MoE (expert-sharded) and an SSM backbone
for kind in ("moe", "ssm"):
    r = build_eps(ARCHS[kind], seq=SEQ)
    t = build_eps(ARCHS[kind], seq=SEQ, mesh=MeshSpec(tp=2))
    xk = jax.random.normal(jax.random.key(1), (B, r.dim))
    np.testing.assert_allclose(np.asarray(t.fn(xk, jnp.float32(2.0))),
                               np.asarray(r.fn(xk, jnp.float32(2.0))),
                               **EPS_TP_TOL)
print("MOE_SSM_FORWARD_OK")

# 3) the acceptance contract: the attention backbone samples TP-sharded
#    INSIDE the DP scan, matching the replicated oracle within EPS_TP_TOL
mtp = build_eps(ARCHS["attn"], seq=SEQ, mesh=MeshSpec(dp=2, tp=2))
s = SamplerSpec(solver="ddim", nfe=4)
p1 = Pipeline.from_spec(s, ref.fn, dim=ref.dim)
ptp = Pipeline.from_spec(s.replace(mesh=MeshSpec(dp=2, tp=2)), mtp.fn,
                         dim=mtp.dim)
xs = np.asarray(p1.prior(jax.random.key(3), B))
a = np.asarray(p1.sample(jnp.asarray(xs), use_pas=False))
b = np.asarray(ptp.sample(jnp.asarray(xs), use_pas=False))
np.testing.assert_allclose(b, a, **EPS_TP_TOL)
print("SAMPLE_TP_OK", float(np.abs(a - b).max()))

# 4) calibration runs on the same composed mesh: Algorithm 1 with the
#    TP backbone matches replicated calibration (same adopted steps,
#    coords within tolerance)
cal = s.replace(nfe=3, teacher=TeacherSpec(nfe=6),
                pas=PASConfig(n_sgd_iters=20))
c1 = Pipeline.from_spec(cal, ref.fn, dim=ref.dim)
ctp = Pipeline.from_spec(cal.replace(mesh=MeshSpec(dp=2, tp=2)), mtp.fn,
                         dim=mtp.dim)
c1.calibrate(key=jax.random.key(0), batch=16)
ctp.calibrate(key=jax.random.key(0), batch=16)
assert np.array_equal(np.asarray(c1.params.active),
                      np.asarray(ctp.params.active)), (
    c1.params.active, ctp.params.active)
np.testing.assert_allclose(np.asarray(ctp.params.coords),
                           np.asarray(c1.params.coords), rtol=1e-3, atol=1e-3)
print("CALIBRATE_TP_OK")
"""


@pytest.mark.slow
def test_backbone_tp_parity_8_devices():
    out = subprocess.run([sys.executable, "-c", _TP_PARITY],
                         capture_output=True, text=True, env=_env8(),
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    for marker in ("ATTN_PARAMS_FORWARD_OK", "MOE_SSM_FORWARD_OK",
                   "SAMPLE_TP_OK", "CALIBRATE_TP_OK"):
        assert marker in out.stdout, out.stdout
