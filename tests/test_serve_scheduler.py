"""Async serve scheduler: the serve-loop refactor's acceptance contract.

* the sync facade (``DiffusionServer.serve`` routed through the scheduler)
  is **bit-identical** to the legacy synchronous flush loop on the same
  seeds — mixed sizes, oversized chunking, zero-sample requests — in
  process here and on a dp=8 virtual mesh in the slow subprocess half;
* deadline-aware batch formation: a lone request flushes partial when its
  slack expires instead of waiting for the budget;
* per-request streaming: oversized requests yield chunks in row order
  *before* their last chunk lands;
* donation safety under double-buffering: every flush stages a fresh
  buffer, the engine refuses to donate an already-donated one;
* serve-loop round-trip bugfixes: ``ServeConfig`` carries the full spec
  (raw points / non-default rho round-trip ``cfg.to_spec() ==
  pipeline.spec``), ``Request(n_samples=0)`` gets an empty (0, dim)
  response, and ``launch.serve`` rejects malformed ``--mesh`` values;
* the hypothesis property: every request gets back exactly ``n_samples``
  rows in order and no flush exceeds ``max_batch`` + DP pad.
"""
import argparse
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import (PASConfig, Pipeline, SamplerSpec, ScheduleSpec,
                       TeacherSpec)
from repro.core import analytic
from repro.launch.serve import parse_mesh
from repro.runtime import DiffusionServer, Request, ServeConfig

DIM = 16
NFE = 5

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="module")
def gmm():
    return analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)


def _server(gmm, *, scheduler="async", max_batch=8, **kw) -> DiffusionServer:
    cfg = ServeConfig(nfe=NFE, solver="ddim", max_batch=max_batch,
                      use_pas=False, scheduler=scheduler, **kw)
    return DiffusionServer(gmm.eps, DIM, cfg)


def _track_flushes(server):
    seen = []
    orig = server._run_batch

    def tracked(x_t):
        seen.append(int(x_t.shape[0]))
        return orig(x_t)

    server._run_batch = tracked
    return seen


# ---------------------------------------------------------------------------
# sync facade == legacy flush loop, bit for bit
# ---------------------------------------------------------------------------


def test_facade_bit_identical_to_sync_loop(gmm):
    """Same seeds, mixed sizes (packed, oversized, zero): identical bits
    and identical flush composition/stats."""
    reqs = [Request(seed=0, n_samples=4), Request(seed=1, n_samples=20),
            Request(seed=2, n_samples=0), Request(seed=3, n_samples=3),
            Request(seed=4, n_samples=8)]
    sync = _server(gmm, scheduler="sync")
    sync_seen = _track_flushes(sync)
    want = sync.serve(reqs)

    srv = _server(gmm, scheduler="async")
    seen = _track_flushes(srv)
    got = srv.serve(reqs)

    assert [o.shape for o in got] == [(4, DIM), (20, DIM), (0, DIM),
                                      (3, DIM), (8, DIM)]
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    assert seen == sync_seen                  # same flush composition
    for k in ("requests", "samples", "batches", "nfe_total",
              "padded_samples"):
        assert srv.stats[k] == sync.stats[k], k
    srv.close()


def test_facade_bit_identical_with_pas_correction(gmm):
    """The corrected prefix (donated PAS variant) is identical too."""
    from repro.core.pas import PASParams
    import jax.numpy as jnp
    active = np.zeros(NFE, bool)
    active[[1, 3]] = True
    coords = np.zeros((NFE, 4), np.float32)
    coords[1] = [1.0, 0.05, 0.0, 0.0]
    coords[3] = [0.98, -0.04, 0.0, 0.0]
    params = PASParams(active=active, coords=jnp.asarray(coords))
    reqs = [Request(seed=0, n_samples=4), Request(seed=1, n_samples=12)]

    def pas_server(mode):
        cfg = ServeConfig(nfe=NFE, solver="ddim", max_batch=8, use_pas=True,
                          scheduler=mode)
        srv = DiffusionServer(gmm.eps, DIM, cfg)
        srv.set_pas(params)
        return srv

    want = pas_server("sync").serve(reqs)
    srv = pas_server("async")
    got = srv.serve(reqs)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    srv.close()


def test_facade_serve_repeated_calls_accumulate_stats(gmm):
    srv = _server(gmm, scheduler="async")
    srv.serve([Request(seed=0, n_samples=3)])
    srv.serve([Request(seed=1, n_samples=5)])
    assert srv.stats["requests"] == 2 and srv.stats["samples"] == 8
    assert srv.stats["batches"] == 2
    assert srv.stats["nfe_total"] == 8 * NFE
    assert srv.stats["wall_s"] > 0
    srv.close()


# ---------------------------------------------------------------------------
# zero-sample requests (bugfix)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheduler", ["sync", "async"])
def test_zero_sample_request_returns_empty(gmm, scheduler):
    """A Request(n_samples=0) answers with an empty (0, dim) array — it
    never joins a flush and never crashes response assembly."""
    srv = _server(gmm, scheduler=scheduler)
    seen = _track_flushes(srv)
    outs = srv.serve([Request(seed=0, n_samples=0)])
    assert outs[0].shape == (0, DIM)
    assert outs[0].dtype == np.float32
    assert seen == []                         # no flush was dispatched
    assert srv.stats["requests"] == 1 and srv.stats["samples"] == 0
    assert srv.stats["batches"] == 0
    srv.close()


def test_zero_sample_handle_completes_immediately(gmm):
    srv = _server(gmm, scheduler="async")
    h = srv.submit(Request(seed=0, n_samples=0))
    assert h.done()
    assert h.result(timeout=1).shape == (0, DIM)
    assert list(h.chunks(timeout=1)) == []
    srv.close()


# ---------------------------------------------------------------------------
# deadline-aware batch formation
# ---------------------------------------------------------------------------


def test_deadline_forces_partial_flush(gmm):
    """A lone 4-row request against a 256 budget flushes when its slack
    expires, not when the budget fills (which would be never)."""
    srv = _server(gmm, max_batch=256, deadline_ms=50)
    h = srv.submit(Request(seed=7, n_samples=4))
    out = h.result(timeout=60)
    assert out.shape == (4, DIM)
    assert srv.stats["flushes_deadline"] == 1
    assert srv.stats["flushes_budget"] == 0
    assert srv.stats["batches"] == 1
    srv.close()


def test_per_request_deadline_overrides_default(gmm):
    srv = _server(gmm, max_batch=256)         # no default deadline
    h = srv.submit(Request(seed=1, n_samples=2, deadline_ms=40))
    assert h.result(timeout=60).shape == (2, DIM)
    assert srv.stats["flushes_deadline"] == 1
    srv.close()


def test_no_deadline_waits_for_drain(gmm):
    srv = _server(gmm, max_batch=256)
    h = srv.submit(Request(seed=1, n_samples=2))
    assert not h.done()
    srv.drain(timeout=60)
    assert h.done() and srv.stats["flushes_drain"] == 1
    srv.close()


def test_budget_fill_still_wins_over_deadline(gmm):
    """Requests already queued pack into a full flush even when a deadline
    has technically expired by the time the scheduler gets to them."""
    srv = _server(gmm, max_batch=8, deadline_ms=200)
    seen = _track_flushes(srv)
    handles = [srv.submit(Request(seed=i, n_samples=4)) for i in range(4)]
    for h in handles:
        assert h.result(timeout=60).shape == (4, DIM)
    assert seen == [8, 8]
    assert srv.stats["flushes_budget"] == 2
    srv.close()


# ---------------------------------------------------------------------------
# per-request streaming
# ---------------------------------------------------------------------------


def test_streaming_chunk_ordering_and_early_yield(gmm):
    """An oversized request streams budget-sized chunks in row order; the
    first chunks arrive while the request is still incomplete."""
    srv = _server(gmm, max_batch=8, deadline_ms=150)
    h = srv.submit(Request(seed=1, n_samples=20))
    chunks, done_flags = [], []
    for c in h.chunks(timeout=60):
        chunks.append(c)
        done_flags.append(h.done())
    assert [c.shape[0] for c in chunks] == [8, 8, 4]
    assert done_flags[-1] is True
    assert not all(done_flags[:-1])   # rows landed before the last chunk
    got = np.concatenate(chunks, axis=0)
    np.testing.assert_array_equal(got, h.result())

    # row-identical to the legacy loop on the same seed
    sync = _server(gmm, scheduler="sync", max_batch=8)
    np.testing.assert_array_equal(
        got, sync.serve([Request(seed=1, n_samples=20)])[0])
    assert h.latency_s is not None and h.latency_s > 0
    srv.close()


def test_result_timeout_raises(gmm):
    srv = _server(gmm, max_batch=256)         # nothing will flush
    h = srv.submit(Request(seed=0, n_samples=2))
    with pytest.raises(TimeoutError, match="rows outstanding"):
        h.result(timeout=0.05)
    with pytest.raises(TimeoutError, match="no chunk within"):
        next(iter(h.chunks(timeout=0.05)))
    srv.drain(timeout=60)
    srv.close()


def test_submit_requires_async_scheduler(gmm):
    srv = _server(gmm, scheduler="sync")
    with pytest.raises(RuntimeError, match="scheduler='async'"):
        srv.submit(Request(seed=0, n_samples=2))


def test_flush_failure_fails_handles_without_deadlock(gmm):
    """A failing flush executor must surface through the handles (and a
    raising serve()/drain()), never as a hung consumer — regression for
    orphaned chunks and the drain deadlock."""
    srv = _server(gmm, scheduler="async", max_batch=8)

    def boom(x_t):
        raise RuntimeError("device on fire")

    srv._run_batch = boom
    h = srv.submit(Request(seed=0, n_samples=20))   # oversized: flushes now
    with pytest.raises(RuntimeError, match="device on fire"):
        h.result(timeout=60)
    with pytest.raises(RuntimeError, match="device on fire"):
        list(h.chunks(timeout=60))
    srv.drain(timeout=60)                           # must not deadlock
    with pytest.raises(RuntimeError, match="device on fire"):
        srv.serve([Request(seed=1, n_samples=4)])
    # the scheduler survives an aborted flush: restore and serve again
    del srv._run_batch                              # back to the real path
    out = srv.serve([Request(seed=2, n_samples=4)])
    assert out[0].shape == (4, DIM)
    srv.close()


# ---------------------------------------------------------------------------
# donation safety under double-buffering
# ---------------------------------------------------------------------------


def test_engine_rejects_reuse_of_donated_buffer(gmm):
    pipe = Pipeline.from_spec(
        SamplerSpec(solver="ddim", nfe=NFE,
                    pas=PASConfig(n_sgd_iters=20)), gmm.eps, dim=DIM)
    x = pipe.prior(jax.random.key(0), 4)
    y, valid = pipe.sample_async(x, use_pas=False, donate_x=True)
    assert valid.all() and np.asarray(y).shape == (4, DIM)
    with pytest.raises(ValueError, match="already donated"):
        pipe.sample_async(x, use_pas=False, donate_x=True)


def test_double_buffered_flushes_stay_correct(gmm):
    """Back-to-back in-flight flushes (depth 2) never cross-contaminate:
    every request's rows match the legacy loop bit for bit."""
    reqs = [Request(seed=i, n_samples=8) for i in range(12)]
    sync = _server(gmm, scheduler="sync", max_batch=8)
    want = sync.serve(reqs)
    srv = _server(gmm, max_batch=8, max_in_flight=2)
    got = srv.serve(reqs)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    assert srv.stats["batches"] == 12
    srv.close()


def test_sample_async_pads_and_masks(gmm):
    """sample_async returns the device future plus the host-side row mask
    (all-valid on a trivial mesh; DP padding is exercised in the
    subprocess half on 8 virtual devices)."""
    pipe = Pipeline.from_spec(SamplerSpec(solver="ddim", nfe=NFE), gmm.eps,
                              dim=DIM)
    x = pipe.prior(jax.random.key(0), 6)
    y, valid = pipe.sample_async(x, use_pas=False)
    assert valid.shape == (6,) and valid.all()
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(pipe.sample(x, use_pas=False)))


# ---------------------------------------------------------------------------
# ServeConfig round-trip (bugfix) + validation
# ---------------------------------------------------------------------------


def test_serve_config_round_trips_raw_schedule(gmm):
    """from_pipeline must reproduce the pipeline's spec exactly — a raw
    grid used to collapse to a default polynomial over its endpoints."""
    ts = np.linspace(50.0, 0.01, NFE + 1)
    spec = SamplerSpec(solver="ipndm2", nfe=NFE,
                       schedule=ScheduleSpec.raw(ts))
    server = DiffusionServer.from_pipeline(
        Pipeline.from_spec(spec, gmm.eps, dim=DIM))
    assert server.cfg.to_spec() == spec
    np.testing.assert_array_equal(server.cfg.to_spec().ts(), ts)


def test_serve_config_round_trips_non_default_rho(gmm):
    spec = SamplerSpec(solver="ddim", nfe=NFE,
                       schedule=ScheduleSpec(rho=3.0),
                       dtype="bfloat16",
                       teacher=TeacherSpec(solver="dpm2", nfe=60))
    server = DiffusionServer.from_pipeline(
        Pipeline.from_spec(spec, gmm.eps, dim=DIM))
    assert server.cfg.to_spec() == spec
    # the scalar shortcut fields stay coherent for introspection
    assert server.cfg.nfe == NFE and server.cfg.solver == "ddim"


def test_serve_config_scalar_fields_still_build_specs():
    cfg = ServeConfig(nfe=7, solver="ipndm2", t_min=0.01, t_max=40.0)
    spec = cfg.to_spec()
    assert spec.nfe == 7 and spec.schedule.t_max == 40.0


def test_serve_config_validation():
    with pytest.raises(ValueError, match="scheduler"):
        ServeConfig(scheduler="turbo")
    with pytest.raises(ValueError, match="max_in_flight"):
        ServeConfig(max_in_flight=0)


# ---------------------------------------------------------------------------
# --mesh parsing (bugfix)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("value,expect", [
    ("8x1", (8, 1, 1)), ("2x4", (2, 4, 1)), (" 1x1 ", (1, 1, 1)),
    ("2x1x4", (2, 1, 4))])
def test_parse_mesh_accepts_valid_grids(value, expect):
    assert parse_mesh(value) == expect


@pytest.mark.parametrize("value", ["8", "x4", "8x", "2x3x4x5", "axb", "-1x2",
                                   "0x2", "2x0", "2x1x0", ""])
def test_parse_mesh_rejects_malformed(value):
    with pytest.raises(argparse.ArgumentTypeError):
        parse_mesh(value)


# ---------------------------------------------------------------------------
# the serving property: exact rows, in order, bounded flushes
# ---------------------------------------------------------------------------


def test_serve_property_rows_in_order_bounded_flushes(gmm):
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    budget = 4
    srv = _server(gmm, scheduler="async", max_batch=budget)
    seen = _track_flushes(srv)
    ref = _server(gmm, scheduler="sync", max_batch=budget)

    @hyp.settings(max_examples=12, deadline=None)
    @hyp.given(sizes=st.lists(st.integers(min_value=0, max_value=11),
                              min_size=1, max_size=6))
    def check(sizes):
        seen.clear()
        reqs = [Request(seed=i, n_samples=n) for i, n in enumerate(sizes)]
        outs = srv.serve(reqs)
        # every request: exactly n_samples rows, in order, right values
        assert [o.shape[0] for o in outs] == sizes
        want = ref.serve(reqs)
        for a, b in zip(want, outs):
            np.testing.assert_array_equal(a, b)
        # no flush exceeds the budget (+ DP pad — trivial mesh: 0)
        assert all(0 < s <= budget for s in seen)
        assert sum(s for s in seen) == sum(sizes)

    check()
    srv.close()


# ---------------------------------------------------------------------------
# dp=8 virtual mesh: facade bit-identity + padded deadline flushes
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import jax, numpy as np
from repro.api import MeshSpec
from repro.core import two_mode_gmm
from repro.runtime import DiffusionServer, Request, ServeConfig

assert len(jax.devices()) == 8, jax.devices()
DIM, NFE = 24, 6
gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)

def server(mode, **kw):
    return DiffusionServer(gmm.eps, DIM, ServeConfig(
        nfe=NFE, solver="ddim", max_batch=16, use_pas=False,
        mesh=MeshSpec(dp=8), scheduler=mode, **kw))

reqs = [Request(seed=0, n_samples=5), Request(seed=1, n_samples=6),
        Request(seed=2, n_samples=20), Request(seed=3, n_samples=0),
        Request(seed=4, n_samples=3)]

# 1) facade == legacy loop, bit for bit, on the dp=8 mesh
sync = server("sync")
want = sync.serve(reqs)
srv = server("async")
got = srv.serve(reqs)
assert [o.shape[0] for o in got] == [5, 6, 20, 0, 3]
for a, b in zip(want, got):
    assert np.array_equal(a, b), np.abs(a - b).max()
for k in ("batches", "nfe_total", "padded_samples"):
    assert srv.stats[k] == sync.stats[k], (k, srv.stats[k], sync.stats[k])
assert srv.stats["padded_samples"] > 0          # DP padding really happened
print("DP8_FACADE_BITEXACT_OK")

# 2) a deadline flush pads to a DP-divisible row count and masks back out
d = server("async", deadline_ms=50)
h = d.submit(Request(seed=9, n_samples=5))
out = h.result(timeout=120)
assert out.shape == (5, DIM)
assert d.stats["flushes_deadline"] == 1
assert d.stats["padded_samples"] == 3           # 5 rows padded to 8
assert d.stats["nfe_total"] == 8 * NFE
print("DP8_DEADLINE_PAD_OK")
"""


@pytest.mark.slow
def test_facade_bit_identity_dp8_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DP8_FACADE_BITEXACT_OK" in out.stdout
    assert "DP8_DEADLINE_PAD_OK" in out.stdout
