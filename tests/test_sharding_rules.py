"""Sharding-rule unit tests: divisibility fallbacks, dedup, param roles."""
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import models
from repro.configs import get_config
from repro.parallel import AxisRules, param_partition_specs, spec_for


@pytest.fixture(scope="module")
def rules():
    class FakeMesh:  # divisibility math only needs .shape
        shape = {"data": 16, "model": 16}

    return AxisRules(mesh=FakeMesh(), batch=("data",), model=("model",),
                     fsdp=("data",), seq=("model",))


def test_spec_divisibility_fallback(rules):
    # 8 kv heads don't divide the 16-way model axis -> replicate that dim
    assert spec_for((128, 32768, 8, 128), ("batch", None, "model", None),
                    rules) == P("data", None, None, None)
    # 16 divides -> sharded
    assert spec_for((128, 32768, 16, 128), ("batch", None, "model", None),
                    rules) == P("data", None, "model", None)


def test_spec_dedup_first_wins(rules):
    # seq->model and vocab->model collide; earlier dim keeps the axis
    s = spec_for((16, 4096, 152064), ("batch", "seq", "model"), rules)
    assert s == P("data", "model", None)


def test_param_roles_right_aligned(rules):
    cfg = get_config("qwen2-72b")
    specs_sds = models.param_specs(cfg)
    parts = param_partition_specs(specs_sds, rules)
    blocks0 = parts["blocks"][0]
    # scan-stacked (n_groups, E, H*Dh): group dim replicated, (fsdp, model)
    assert blocks0["attn"]["wq"] == P(None, "data", "model")
    assert blocks0["attn"]["wo"] == P(None, "model", "data")
    assert blocks0["mlp"]["w2"] == P(None, "model", "data")
    assert parts["tok_embed"] == P("model", "data")
    # norms replicate
    assert blocks0["ln1"]["scale"] == P(None, None)


def test_moe_expert_sharding(rules):
    cfg = get_config("llama4-scout-17b-16e")   # 16 experts == 16-way axis
    specs_sds = models.param_specs(cfg)
    parts = param_partition_specs(specs_sds, rules)
    w1 = parts["blocks"][0]["moe"]["experts"]["w1"]
    assert w1 == P(None, "model", "data", None)  # (groups, n_exp, E, F)


def test_no_rules_is_noop():
    from repro.parallel import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", "model") is x
