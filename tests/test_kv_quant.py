"""int8 KV-cache serving: decode logits stay close to the bf16-cache path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma3-1b"])
def test_int8_kv_decode_matches_native(arch):
    cfg = get_config(arch).reduced()
    params = models.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)

    last_n, cache_n = models.prefill(params, tokens[:, :8], cfg, max_len=24)
    last_q, cache_q = models.prefill(params, tokens[:, :8], cfg, max_len=24,
                                     cache_dtype="int8")
    np.testing.assert_allclose(np.asarray(last_n), np.asarray(last_q),
                               rtol=0.1, atol=0.15)

    for j in range(8, 16):
        log_n, cache_n = models.decode_step(params, cache_n, tokens[:, j], cfg)
        log_q, cache_q = models.decode_step(params, cache_q, tokens[:, j], cfg)
        # int8 quantisation noise, but the argmax (greedy token) must agree
        # for the vast majority of positions and logits stay close
        np.testing.assert_allclose(np.asarray(log_n), np.asarray(log_q),
                                   rtol=0.2, atol=0.3, err_msg=f"step {j}")
    agree = np.mean(np.argmax(np.asarray(log_n), -1)
                    == np.argmax(np.asarray(log_q), -1))
    assert agree >= 0.5, agree


def test_quantize_kv_roundtrip():
    from repro.models.attention import KVCache, quantize_kv
    k = jax.random.normal(jax.random.key(0), (2, 16, 4, 32))
    v = jax.random.normal(jax.random.key(1), (2, 16, 4, 32))
    q = quantize_kv(KVCache(k=k, v=v))
    assert q.k.dtype == jnp.int8
    k_deq = q.k.astype(jnp.float32) * q.k_scale
    np.testing.assert_allclose(np.asarray(k_deq), np.asarray(k),
                               atol=float(jnp.max(jnp.abs(k))) / 100)
