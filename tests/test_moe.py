"""MoE routing exactness vs a dense (all-experts) reference + drop behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe


def _cfg(n_experts=4, top_k=2, cf=8.0, act="swiglu"):
    base = get_config("mixtral-8x7b").reduced()
    return dataclasses.replace(base, n_experts=n_experts, moe_top_k=top_k,
                               capacity_factor=cf, act=act,
                               shared_expert=False)


def _dense_reference(p, x, cfg):
    """Compute every expert for every token, mix by renormalised top-k gates."""
    b, s, e = x.shape
    xf = x.reshape(-1, e)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    outs = []
    for ex in range(cfg.n_experts):
        h = xf @ p["experts"]["w1"][ex]
        if cfg.act == "swiglu":
            h = jax.nn.silu(h) * (xf @ p["experts"]["w3"][ex])
        else:
            h = jax.nn.gelu(h, approximate=True)
        outs.append(h @ p["experts"]["w2"][ex])
    dense = jnp.stack(outs, axis=1)                       # (T, n, E)
    mask = jnp.zeros((xf.shape[0], cfg.n_experts))
    for j in range(cfg.moe_top_k):
        mask = mask + jax.nn.one_hot(idx[:, j], cfg.n_experts) * gate[:, j:j+1]
    y = jnp.einsum("tne,tn->te", dense, mask.astype(x.dtype))
    return y.reshape(b, s, e)


@pytest.mark.parametrize("top_k,act", [(1, "swiglu"), (2, "swiglu"), (2, "gelu")])
def test_moe_matches_dense_reference(top_k, act):
    cfg = _cfg(top_k=top_k, act=act)
    key = jax.random.key(0)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, aux = moe.apply_moe(p, x, cfg)
    y_ref = _dense_reference(p, x, cfg)
    assert float(aux["dropped_fraction"]) == 0.0  # cf=8 -> dropless
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_single_group_path_matches():
    """Decode-shaped call (S=1) routes as one group, same math."""
    cfg = _cfg(top_k=2)
    p = moe.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(2), (8, 1, cfg.d_model))
    y, _ = moe.apply_moe(p, x, cfg)
    y_ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    cfg = _cfg(top_k=1, cf=0.05)
    p = moe.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(3), (1, 128, cfg.d_model))
    y, aux = moe.apply_moe(p, x, cfg)
    assert float(aux["dropped_fraction"]) > 0.3
    assert np.isfinite(np.asarray(y)).all()


def test_moe_load_balance_loss_penalises_collapse():
    cfg = _cfg(top_k=1, cf=8.0)
    p = moe.init_moe(jax.random.key(0), cfg)
    # router biased hard to expert 0 -> lb loss near n (vs ~1 when uniform)
    p_bad = dict(p)
    p_bad["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(20.0)
    # positive inputs so the biased column dominates every token's logits
    x = jnp.abs(jax.random.normal(jax.random.key(4), (2, 64, cfg.d_model)))
    _, aux_ok = moe.apply_moe(p, x, cfg)
    _, aux_bad = moe.apply_moe(p_bad, x, cfg)
    # full collapse -> loss == n_experts; healthy routing stays well below
    assert float(aux_bad["load_balance_loss"]) > cfg.n_experts - 0.1
    assert float(aux_ok["load_balance_loss"]) < cfg.n_experts - 0.5