"""PCA/Gram-trick/Schmidt correctness, incl. property-based tests (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pca


def test_topk_matches_numpy_svd():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(6, 97)).astype(np.float32)
    v = np.asarray(pca.topk_right_singular(jnp.asarray(x), 3))
    _, s_np, vt_np = np.linalg.svd(x, full_matrices=False)
    for j in range(3):
        # right singular vectors defined up to sign
        dot = abs(float(np.dot(v[j], vt_np[j])))
        np.testing.assert_allclose(dot, 1.0, atol=1e-3)
        np.testing.assert_allclose(np.linalg.norm(v[j]), 1.0, atol=1e-4)


def test_topk_handles_rank_deficiency():
    x = jnp.zeros((4, 50)).at[0].set(jnp.ones(50))
    v = pca.topk_right_singular(x, 3)
    # one real component, rest zeroed
    np.testing.assert_allclose(np.linalg.norm(np.asarray(v[0])), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v[1:]), 0.0, atol=1e-5)


def test_masked_rows_are_ignored():
    rng = np.random.default_rng(1)
    x_valid = rng.normal(size=(3, 40)).astype(np.float32)
    garbage = 1e6 * rng.normal(size=(2, 40)).astype(np.float32)
    x_full = jnp.asarray(np.concatenate([x_valid, garbage]))
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0])
    v_masked = pca.topk_right_singular(x_full, 2, mask=mask)
    v_ref = pca.topk_right_singular(jnp.asarray(x_valid), 2)
    for j in range(2):
        dot = abs(float(jnp.vdot(v_masked[j], v_ref[j])))
        np.testing.assert_allclose(dot, 1.0, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 8),
    d=st.integers(8, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_schmidt_orthonormal_property(n, d, seed):
    """Property: Schmidt output rows are orthonormal-or-zero, span input."""
    rng = np.random.default_rng(seed)
    vs = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    u = pca.schmidt(vs)
    g = np.asarray(u @ u.T)
    norms = np.diag(g)
    for i in range(n):
        assert norms[i] == pytest.approx(1.0, abs=1e-3) or norms[i] == pytest.approx(0.0, abs=1e-6)
    off = g - np.diag(norms)
    np.testing.assert_allclose(off, 0.0, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_schmidt_zeroes_collinear(seed):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(32,)).astype(np.float32)
    vs = jnp.asarray(np.stack([v, 2.0 * v, -0.5 * v]))
    u = pca.schmidt(vs)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(u[0])), 1.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(u[1:]), 0.0, atol=1e-5)


def test_pas_basis_pins_v1_and_is_orthonormal():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(5, 80)).astype(np.float32))
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0])
    d = jnp.asarray(rng.normal(size=(80,)).astype(np.float32))
    u = pca.pas_basis(q, mask, d, n_basis=4)
    assert u.shape == (4, 80)
    np.testing.assert_allclose(
        np.asarray(u[0]), np.asarray(d / jnp.linalg.norm(d)), atol=1e-5)
    g = np.asarray(u @ u.T)
    np.testing.assert_allclose(g, np.diag(np.diag(g)), atol=1e-3)


def test_pas_basis_spans_trajectory():
    """The basis must (with the buffer) span any direction in the buffer span."""
    rng = np.random.default_rng(4)
    basis_true = rng.normal(size=(3, 60)).astype(np.float32)
    coef = rng.normal(size=(4, 3)).astype(np.float32)
    rows = coef @ basis_true  # 4 buffer rows in a 3-dim subspace
    d = (rng.normal(size=(3,)).astype(np.float32) @ basis_true)
    q = jnp.asarray(rows)
    u = pca.pas_basis(q, jnp.ones(4), jnp.asarray(d), n_basis=4)
    # project d onto U: should reconstruct it (d lies in the span)
    proj = (u @ d) @ u
    np.testing.assert_allclose(np.asarray(proj), d, rtol=1e-3, atol=1e-3)


def test_cumulative_variance_monotone_and_saturating():
    rng = np.random.default_rng(5)
    low_rank = rng.normal(size=(20, 3)) @ rng.normal(size=(3, 100))
    noise = 1e-4 * rng.normal(size=(20, 100))
    cv = np.asarray(pca.cumulative_variance(jnp.asarray((low_rank + noise).astype(np.float32))))
    assert np.all(np.diff(cv) >= -1e-6)
    assert cv[2] > 0.999  # 3 PCs capture ~everything
