"""Multi-pipeline SLA router + priority scheduling: PR-6 acceptance contract.

* **routing** — explicit lane keys (``Request.pipeline`` /
  ``submit(pipeline=...)``) and deadline-slack tiering: tight deadline ⇒
  cheap low-NFE lane, slack/no deadline ⇒ teacher-grade lane, unknown keys
  rejected with the zoo listed;
* **deadline precedence** — per-call ``submit(deadline_ms=)`` >
  ``Request.deadline_ms`` > ``ServeConfig.deadline_ms``, observable through
  the lane the slack router picks;
* **priority packing** — ``interactive`` chunks pack ahead of ``batch``
  backfill when a flush forms (asserted on the staged flush rows), while a
  uniform-priority stream keeps FIFO admit order;
* **the acceptance bit-identity** — a single-lane router serving one
  priority class is bit-identical (responses, flush composition, stats) to
  the PR-5 sync flush loop;
* **the hypothesis property** — across mixed-priority multi-lane streams
  with per-lane budgets, every request's rows come back exactly once, in
  order, on the lane it was routed to, and no flush exceeds its lane's
  budget;
* **traffic** — Poisson schedules are seed-deterministic and CSV traces
  round-trip;
* **the public surface** — the serving types resolve through ``repro.api``
  (lazily) and the legacy engine entry points warn with a migration hint.
"""
import importlib
import warnings

import jax
import numpy as np
import pytest

from repro.api import (DiffusionServer, Pipeline, PipelineRouter, Request,
                       SamplerSpec, ServeConfig)
from repro.core import analytic

DIM = 16
FAST_NFE = 2
HQ_NFE = 8


@pytest.fixture(scope="module")
def gmm():
    return analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)


def _pipe(gmm, nfe, solver="ddim") -> Pipeline:
    return Pipeline.from_spec(SamplerSpec(solver=solver, nfe=nfe), gmm.eps,
                              dim=DIM)


def _router(gmm, *, budgets=None, run_batch=None, **cfg_kw) -> PipelineRouter:
    """Two-lane zoo: ``fast`` (ddim@2, est cost 2ms) + ``hq`` (ddim@8,
    est cost 8ms) under the default 1.0 ms/eval slack model."""
    cfg = ServeConfig(max_batch=8, use_pas=False, **cfg_kw)
    return PipelineRouter({"fast": _pipe(gmm, FAST_NFE),
                           "hq": _pipe(gmm, HQ_NFE)},
                          cfg=cfg, use_pas=False, budgets=budgets,
                          run_batch=run_batch)


def _prior(router, lane, seed, n) -> np.ndarray:
    return np.asarray(router.pipelines[lane].prior(jax.random.key(seed), n))


# ---------------------------------------------------------------------------
# routing: explicit keys, slack tiers, validation
# ---------------------------------------------------------------------------


def test_explicit_key_routes_and_unknown_key_rejected(gmm):
    router = _router(gmm)
    try:
        h1 = router.submit(Request(seed=0, n_samples=2), pipeline="fast")
        h2 = router.submit(Request(seed=1, n_samples=2, pipeline="hq"))
        assert (h1.lane, h2.lane) == ("fast", "hq")
        with pytest.raises(ValueError, match=r"unknown pipeline.*'fast'"):
            router.submit(Request(seed=2, n_samples=2, pipeline="teacher"))
        router.drain(timeout=60)
        assert h1.result().shape == (2, DIM)
    finally:
        router.close()


def test_slack_routing_tiers(gmm):
    """No deadline ⇒ teacher-grade; generous slack ⇒ most expensive lane
    that fits; tight slack ⇒ cheap lane; impossible slack ⇒ cheapest."""
    router = _router(gmm)
    try:
        cases = [(None, "hq"), (100.0, "hq"), (3.0, "fast"), (1.0, "fast")]
        for i, (ddl, lane) in enumerate(cases):
            h = router.submit(Request(seed=i, n_samples=1, deadline_ms=ddl))
            assert h.lane == lane, (ddl, h.lane)
        router.drain(timeout=60)
    finally:
        router.close()
    assert router.lane_cost_ms("fast") == FAST_NFE * 1.0
    assert router.lane_cost_ms("hq") == HQ_NFE * 1.0


def test_route_by_explicit_requires_key(gmm):
    router = _router(gmm, route_by="explicit")
    try:
        with pytest.raises(ValueError, match="route_by='explicit'"):
            router.submit(Request(seed=0, n_samples=2))
        h = router.submit(Request(seed=0, n_samples=2, pipeline="fast"))
        router.drain(timeout=60)
        assert h.lane == "fast"
    finally:
        router.close()


def test_budgets_for_unknown_lane_rejected(gmm):
    with pytest.raises(ValueError, match="unknown lanes.*teacher"):
        PipelineRouter({"fast": _pipe(gmm, FAST_NFE)},
                       cfg=ServeConfig(max_batch=8, use_pas=False),
                       use_pas=False, budgets={"teacher": 4})


def test_invalid_priority_rejected(gmm):
    router = _router(gmm)
    try:
        with pytest.raises(ValueError, match="priority"):
            router.submit(Request(seed=0, n_samples=2, priority="urgent"))
    finally:
        router.close()
    with pytest.raises(ValueError, match="default_priority"):
        ServeConfig(default_priority="urgent")


# ---------------------------------------------------------------------------
# deadline precedence: per-call > Request > ServeConfig
# ---------------------------------------------------------------------------


def test_deadline_precedence_call_beats_request_beats_config(gmm):
    """The slack router sees the *resolved* deadline, so precedence is
    observable as the lane choice: 3ms ⇒ fast, 100ms ⇒ hq."""
    router = _router(gmm, deadline_ms=100.0)      # config default: hq tier
    try:
        # config default applies when nothing else is set
        assert router.submit(Request(seed=0, n_samples=1)).lane == "hq"
        # Request.deadline_ms overrides the config default
        assert router.submit(
            Request(seed=1, n_samples=1, deadline_ms=3.0)).lane == "fast"
        # per-call submit(deadline_ms=) overrides the Request field
        assert router.submit(Request(seed=2, n_samples=1, deadline_ms=3.0),
                             deadline_ms=100.0).lane == "hq"
        # per-call None clears the Request deadline: teacher-grade lane
        assert router.submit(Request(seed=3, n_samples=1, deadline_ms=3.0),
                             deadline_ms=None).lane == "hq"
        router.drain(timeout=60)
    finally:
        router.close()


# ---------------------------------------------------------------------------
# priority packing: interactive pre-empts batch backfill
# ---------------------------------------------------------------------------


def _staging_tracker(flushes):
    """A lane runner that records each staged flush (copied to host before
    the identity return — compositions stay inspectable, nothing is
    donated)."""
    def run(key, x_t):
        x = np.array(x_t)
        flushes.append((key, x))
        return x
    return run


def test_interactive_packs_ahead_of_batch(gmm):
    """A batch chunk admitted *first* still flushes *behind* an interactive
    chunk that arrives before the budget fills."""
    flushes = []
    router = _router(gmm, budgets={"fast": 8, "hq": 8},
                     run_batch=_staging_tracker(flushes))
    try:
        router.submit(Request(seed=0, n_samples=4, pipeline="fast",
                              priority="batch"))
        router.submit(Request(seed=1, n_samples=4, pipeline="fast",
                              priority="interactive"))   # fills the budget
        router.drain(timeout=60)
    finally:
        router.close()
    assert len(flushes) == 1 and flushes[0][0] == "fast"
    staged = flushes[0][1]
    np.testing.assert_array_equal(staged[:4], _prior(router, "fast", 1, 4))
    np.testing.assert_array_equal(staged[4:], _prior(router, "fast", 0, 4))


def test_uniform_priority_keeps_fifo_order(gmm):
    flushes = []
    router = _router(gmm, budgets={"fast": 8, "hq": 8},
                     run_batch=_staging_tracker(flushes))
    try:
        router.submit(Request(seed=0, n_samples=4, pipeline="fast"))
        router.submit(Request(seed=1, n_samples=4, pipeline="fast"))
        router.drain(timeout=60)
    finally:
        router.close()
    staged = flushes[0][1]
    np.testing.assert_array_equal(staged[:4], _prior(router, "fast", 0, 4))
    np.testing.assert_array_equal(staged[4:], _prior(router, "fast", 1, 4))


def test_latency_stats_bucketed_by_priority(gmm):
    router = _router(gmm)
    try:
        router.submit(Request(seed=0, n_samples=2, priority="interactive"))
        router.submit(Request(seed=1, n_samples=2, priority="batch"))
        router.drain(timeout=60)
        by_prio = router.stats["latency_by_priority"]
        assert len(by_prio["interactive"]) == 1
        assert len(by_prio["batch"]) == 1
        assert all(v >= 0 for vs in by_prio.values() for v in vs)
    finally:
        router.close()


# ---------------------------------------------------------------------------
# acceptance: single-lane router == PR-5 sync flush loop, bit for bit
# ---------------------------------------------------------------------------


def test_single_lane_router_bit_identical_to_sync_loop(gmm):
    """One lane, one priority class: the router *is* the PR-5 scheduler —
    same bits, same flush composition, same stats."""
    reqs = [Request(seed=0, n_samples=4), Request(seed=1, n_samples=20),
            Request(seed=2, n_samples=0), Request(seed=3, n_samples=3),
            Request(seed=4, n_samples=8)]
    cfg = ServeConfig(nfe=HQ_NFE, solver="ddim", max_batch=8, use_pas=False,
                      scheduler="sync")
    sync = DiffusionServer(gmm.eps, DIM, cfg)
    sync_seen = []
    orig = sync._run_batch
    sync._run_batch = lambda x_t: (sync_seen.append(int(x_t.shape[0])),
                                   orig(x_t))[1]
    want = sync.serve(reqs)

    seen = []
    pipe = _pipe(gmm, HQ_NFE)

    def tracked(key, x_t):
        seen.append(int(x_t.shape[0]))
        return pipe.sample(x_t, use_pas=False)

    router = PipelineRouter({"only": pipe},
                            cfg=ServeConfig(max_batch=8, use_pas=False),
                            run_batch=tracked)
    try:
        got = router.serve(reqs)
    finally:
        router.close()
    assert [o.shape for o in got] == [(4, DIM), (20, DIM), (0, DIM),
                                     (3, DIM), (8, DIM)]
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    assert seen == sync_seen                  # same flush composition
    for k in ("requests", "samples", "batches", "nfe_total",
              "padded_samples"):
        assert router.stats[k] == sync.stats[k], k


# ---------------------------------------------------------------------------
# the router property: exactly-once rows, in order, per-lane budgets
# ---------------------------------------------------------------------------


_BUDGETS = {"fast": 4, "hq": 6}


def _check_stream(router, flushes, reqs) -> None:
    """One mixed stream through a two-lane router with an identity
    executor: every request's rows come back exactly once, in order, on the
    lane it was routed to; no flush exceeds its lane's budget; per-lane
    rows are conserved."""
    flushes.clear()
    handles = [
        router.submit(Request(seed=1000 + i, n_samples=n, priority=prio,
                              deadline_ms=ddl, pipeline=lane))
        for i, (n, prio, ddl, lane) in enumerate(reqs)]
    router.drain(timeout=60)
    routed_rows = {"fast": 0, "hq": 0}
    for i, (h, (n, prio, ddl, lane)) in enumerate(zip(handles, reqs)):
        # explicit key wins; else the slack tier decides
        want_lane = lane or ("hq" if ddl is None or ddl >= HQ_NFE
                             else "fast")
        assert h.lane == want_lane and h.priority == prio
        # exactly n rows, in order, bit-equal to this request's staged
        # prior (identity executor ⇒ any loss/duplication/reorder of
        # rows across flush compositions would break equality)
        out = h.result(timeout=60)
        assert out.shape == (n, DIM)
        np.testing.assert_array_equal(
            out, _prior(router, want_lane, 1000 + i, n))
        routed_rows[want_lane] += n
    # no flush exceeds its lane's budget; per-lane rows conserved
    flushed = {"fast": 0, "hq": 0}
    for key, staged in flushes:
        assert 0 < staged.shape[0] <= _BUDGETS[key]
        flushed[key] += staged.shape[0]
    assert flushed == routed_rows


def test_router_mixed_stream_fixed_cases(gmm):
    """The exactly-once property on hand-picked adversarial streams —
    oversized chunking, zero-sample, explicit pins, every deadline tier and
    priority interleaving (runs even without hypothesis installed)."""
    flushes = []
    router = _router(gmm, budgets=_BUDGETS,
                     run_batch=_staging_tracker(flushes))
    streams = [
        # oversized vs both budgets + zero-sample + explicit pins
        [(11, "batch", None, None), (0, "interactive", 3.0, None),
         (5, "interactive", 3.0, "hq"), (4, "batch", 100.0, "fast")],
        # priority interleaving on one lane, budget-exact fills
        [(2, "batch", 3.0, None), (2, "interactive", 3.0, None),
         (2, "batch", 3.0, None), (2, "interactive", 3.0, None)],
        # everything on the teacher lane, mixed priorities
        [(6, "interactive", None, None), (6, "batch", 100.0, None),
         (1, "interactive", None, None)],
    ]
    try:
        for reqs in streams:
            _check_stream(router, flushes, reqs)
    finally:
        router.close()


def test_router_property_exactly_once_in_order(gmm):
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    flushes = []
    router = _router(gmm, budgets=_BUDGETS,
                     run_batch=_staging_tracker(flushes))

    req_st = st.tuples(
        st.integers(min_value=0, max_value=11),            # n_samples
        st.sampled_from(["interactive", "batch"]),         # priority
        st.sampled_from([None, 3.0, 100.0]),               # deadline tier
        st.sampled_from([None, "fast", "hq"]))             # explicit lane

    @hyp.settings(max_examples=12, deadline=None)
    @hyp.given(reqs=st.lists(req_st, min_size=1, max_size=7))
    def check(reqs):
        _check_stream(router, flushes, reqs)

    try:
        check()
    finally:
        router.close()


# ---------------------------------------------------------------------------
# traffic: determinism + trace round-trip
# ---------------------------------------------------------------------------


def test_poisson_arrivals_deterministic_and_classed():
    from repro.api import poisson_arrivals

    a = poisson_arrivals(200.0, 0.5, seed=7)
    b = poisson_arrivals(200.0, 0.5, seed=7)
    assert a == b and len(a) > 10
    assert poisson_arrivals(200.0, 0.5, seed=8) != a
    assert all(x.t_s < 0.5 for x in a)
    assert sorted(a, key=lambda x: x.t_s) == a
    prios = {x.priority for x in a}
    assert prios == {"interactive", "batch"}
    for x in a:
        want = 25.0 if x.priority == "interactive" else 250.0
        assert x.deadline_ms == want
    # class knobs: all-interactive / all-batch streams
    assert {x.priority for x in poisson_arrivals(
        200.0, 0.3, seed=7, interactive_fraction=1.0)} == {"interactive"}
    with pytest.raises(ValueError, match="rate_rps"):
        poisson_arrivals(0.0, 1.0)


def test_trace_round_trip(tmp_path):
    from repro.api import load_trace, poisson_arrivals, save_trace

    import dataclasses

    a = poisson_arrivals(120.0, 0.4, seed=3)
    a[0] = dataclasses.replace(a[0], pipeline="fast")
    path = save_trace(tmp_path / "trace.csv", a)
    back = load_trace(path)
    assert len(back) == len(a)
    for x, y in zip(a, back):
        assert abs(x.t_s - y.t_s) < 1e-3          # t_ms written at 3 decimals
        assert (x.seed, x.n_samples, x.priority, x.deadline_ms,
                x.pipeline) == (y.seed, y.n_samples, y.priority,
                                y.deadline_ms, y.pipeline)
    req = back[0].request()
    assert isinstance(req, Request) and req.pipeline == "fast"
    assert req.n_samples == back[0].n_samples


# ---------------------------------------------------------------------------
# public surface: repro.api serving exports + legacy deprecations
# ---------------------------------------------------------------------------


def test_api_exports_serving_surface():
    api = importlib.import_module("repro.api")
    for name, module in (("Request", "repro.runtime.serve_loop"),
                         ("ServeConfig", "repro.runtime.serve_loop"),
                         ("DiffusionServer", "repro.runtime.serve_loop"),
                         ("ServeHandle", "repro.runtime.scheduler"),
                         ("ServeScheduler", "repro.runtime.scheduler"),
                         ("PRIORITIES", "repro.runtime.scheduler"),
                         ("PipelineRouter", "repro.runtime.router"),
                         ("Arrival", "repro.runtime.traffic"),
                         ("poisson_arrivals", "repro.runtime.traffic"),
                         ("replay", "repro.runtime.traffic")):
        assert name in api.__all__
        assert getattr(api, name) is getattr(
            importlib.import_module(module), name), name
    assert "PipelineRouter" in dir(api)


def test_legacy_engine_entry_points_warn(gmm):
    from repro.core import make_solver, pas_sample
    from repro.core.pas import PASConfig, PASParams
    from repro.engine import engine_for_solver, get_engine

    spec = SamplerSpec(solver="ddim", nfe=4)
    with pytest.warns(DeprecationWarning,
                      match="Migrating from the legacy API"):
        eng = get_engine("ddim", spec.ts())
    assert eng.nfe == 4
    with pytest.warns(DeprecationWarning,
                      match="Migrating from the legacy API"):
        eng2 = engine_for_solver(make_solver("ddim", spec.ts()))
    assert eng2 is eng                         # shims share the spec cache

    import jax.numpy as jnp
    x = gmm.sample_prior(jax.random.key(0), 2, float(spec.ts()[0]))
    params = PASParams(active=np.zeros(4, bool),
                       coords=jnp.zeros((4, 4), jnp.float32))
    with pytest.warns(DeprecationWarning, match="repro.api.Pipeline"):
        out = pas_sample(make_solver("ddim", spec.ts()), gmm.eps, x, params,
                         PASConfig())
    assert np.asarray(out).shape == (2, DIM)


def test_pipeline_path_is_warning_free(gmm):
    """The supported surface never trips its own deprecation shims."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        pipe = _pipe(gmm, FAST_NFE)
        x = pipe.prior(jax.random.key(0), 2)
        pipe.sample(x, use_pas=False)
        router = PipelineRouter({"fast": pipe},
                                cfg=ServeConfig(max_batch=8, use_pas=False),
                                use_pas=False)
        try:
            router.serve([Request(seed=0, n_samples=2)])
        finally:
            router.close()


# ---------------------------------------------------------------------------
# lane cost model: total evals per sample (PR-7)
# ---------------------------------------------------------------------------


def test_lane_cost_counts_evals_not_steps(gmm):
    """A two-eval solver at N steps prices as 2N evals in the slack model —
    the docstring's 'total model evals per sample' contract, regression per
    the cost-model audit."""
    router = PipelineRouter({"euler": _pipe(gmm, 4, solver="ddim"),
                             "heun": _pipe(gmm, 4, solver="heun")},
                            cfg=ServeConfig(max_batch=8, use_pas=False),
                            use_pas=False)
    try:
        assert router.lane_cost_ms("euler") == 4 * 1.0
        assert router.lane_cost_ms("heun") == 2 * 4 * 1.0
        # a 6ms deadline fits euler (4) but not heun (8)
        h = router.submit(Request(seed=0, n_samples=1, deadline_ms=6.0))
        assert h.lane == "euler"
        router.drain(timeout=60)
    finally:
        router.close()


def test_adaptive_lane_priced_at_worst_case(gmm):
    """An adaptive lane routes on its compiled 2*max_iters bound: the slack
    router must guarantee the deadline, so it prices capacity, not the
    optimistic mean."""
    from repro.api import ErrorControlConfig

    adaptive = Pipeline.from_spec(
        SamplerSpec(solver="ddim", nfe=4,
                    error_control=ErrorControlConfig(rtol=0.05,
                                                     max_iters=16)),
        gmm.eps, dim=DIM)
    router = PipelineRouter({"fast": _pipe(gmm, FAST_NFE),
                             "adaptive": adaptive},
                            cfg=ServeConfig(max_batch=8, use_pas=False),
                            use_pas=False)
    try:
        assert router.lane_cost_ms("adaptive") == 2 * 16 * 1.0
        # 10ms slack fits fast (2) but not the adaptive bound (32)
        h = router.submit(Request(seed=0, n_samples=1, deadline_ms=10.0))
        assert h.lane == "fast"
        # no deadline: the adaptive lane is the most expensive one
        h2 = router.submit(Request(seed=1, n_samples=1))
        assert h2.lane == "adaptive"
        router.drain(timeout=60)
    finally:
        router.close()


# ---------------------------------------------------------------------------
# NFELadder: rungs from one artifact family
# ---------------------------------------------------------------------------


def test_nfe_ladder_rungs_and_routing(gmm, tmp_path):
    from repro.api import NFELadder
    from repro.api.spec import TeacherSpec

    base = SamplerSpec(solver="ddim", nfe=8,
                       teacher=TeacherSpec(solver="heun", nfe=16))
    ladder = NFELadder(base, nfes=(2, 4))
    assert ladder.keys == ["nfe2", "nfe4", "teacher"]
    assert ladder.specs["nfe2"].nfe == 2
    assert ladder.specs["teacher"].solver == "heun"
    assert ladder.use_pas == {"nfe2": True, "nfe4": True, "teacher": False}

    router = ladder.build_router(gmm.eps, DIM,
                                 cfg=ServeConfig(max_batch=8, use_pas=False),
                                 use_pas=False)
    try:
        # teacher lane = heun@16 = 32 evals; tight slack routes to a rung
        assert router.lane_cost_ms("teacher") == 32.0
        h_tight = router.submit(Request(seed=0, n_samples=1, deadline_ms=3.0))
        h_slack = router.submit(Request(seed=1, n_samples=1))
        assert h_tight.lane == "nfe2"
        assert h_slack.lane == "teacher"
        router.drain(timeout=60)
    finally:
        router.close()

    path = ladder.save_manifest(tmp_path)
    assert path.name == "ladder.json"
    back = NFELadder.from_manifest(tmp_path)
    assert back.specs == ladder.specs
    assert back.use_pas == ladder.use_pas


def test_nfe_ladder_calibrates_pas_rungs_only(gmm, tmp_path):
    """`calibrate` fills every PAS rung, skips the teacher lane, persists
    per-rung artifacts + the manifest as one family directory."""
    from repro.api import NFELadder
    from repro.api.spec import TeacherSpec
    from repro.api.artifact import PASArtifact

    base = SamplerSpec(solver="ddim", nfe=4,
                       teacher=TeacherSpec(solver="heun", nfe=8))
    ladder = NFELadder(base, nfes=(3, 4))
    router = ladder.build_router(gmm.eps, DIM,
                                 cfg=ServeConfig(max_batch=8))
    try:
        ladder.calibrate(router, jax.random.key(0), batch=32,
                         artifact_dir=tmp_path)
        assert router.pipelines["nfe3"].calibrated
        assert router.pipelines["nfe4"].calibrated
        assert not router.pipelines["teacher"].calibrated
    finally:
        router.close()
    assert PASArtifact.exists(tmp_path / "nfe3")
    assert PASArtifact.exists(tmp_path / "nfe4")
    assert not PASArtifact.exists(tmp_path / "teacher")
    assert (tmp_path / "ladder.json").exists()

    # the family round-trips: a fresh router over the artifact dir loads
    # the calibrated floats without recalibrating
    ladder2 = NFELadder.from_manifest(tmp_path)
    router2 = ladder2.build_router(gmm.eps, DIM, artifact_dir=tmp_path,
                                   cfg=ServeConfig(max_batch=8))
    try:
        assert router2.pipelines["nfe3"].calibrated
        assert not router2.pipelines["teacher"].calibrated
    finally:
        router2.close()


def test_nfe_ladder_validation():
    from repro.api import NFELadder

    base = SamplerSpec(solver="ddim", nfe=8)
    with pytest.raises(ValueError, match="at least one"):
        NFELadder(base, nfes=())
    with pytest.raises(ValueError, match="duplicate"):
        NFELadder(base, nfes=(4, 4))
    ladder = NFELadder(base, nfes=(4,), teacher_rung=False)
    assert ladder.keys == ["nfe4"]
