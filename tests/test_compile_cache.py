"""Persistent compile cache + fleet pre-warm (ISSUE 9).

The restart-simulation contract: a process that dies and relaunches against
the same cache directory must (a) restore serialized AOT executables that
sample *bit-identically* to what the first process compiled, and (b) reject
any stale entry — wrong runtime fingerprint, tampered blob — as a counted
miss that falls back to recompilation, never a crash.  In-process restarts
are simulated by clearing every engine cache + the stats counters and
rebuilding engines from specs (fresh jit closures, so nothing hits the
in-memory trace caches).

Donation hazard (see ``engine._aot_program``): deserialized executables
must never be used for donating variants — these tests only serialize
``donate=False`` programs, matching the engines' own ``serialize_ok``
policy.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PASConfig, SamplerSpec, ScheduleSpec, TeacherSpec
from repro.core import analytic
from repro.engine import (clear_calibration_engine_cache, clear_engine_cache,
                          compile_cache, engine_cache_stats,
                          get_calibration_engine_for_spec, get_engine_for_spec)
from repro.engine.compile_cache import CompileCache

DIM, NFE, BATCH = 8, 4, 8
T_MIN, T_MAX = 0.01, 3.0
MODEL_KEY = "oracle:gmm:test"


def _spec() -> SamplerSpec:
    return SamplerSpec(
        solver="ipndm4", nfe=NFE,
        schedule=ScheduleSpec(t_min=T_MIN, t_max=T_MAX),
        teacher=TeacherSpec(solver="heun", nfe=8),
        pas=PASConfig(n_basis=2, n_sgd_iters=8, val_fraction=0.25))


@pytest.fixture()
def gmm():
    return analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)


@pytest.fixture()
def cache(tmp_path):
    """An isolated active cache; restores pristine global state after."""
    prev = {k: getattr(jax.config, k) for k in
            ("jax_compilation_cache_dir",
             "jax_persistent_cache_min_compile_time_secs",
             "jax_persistent_cache_min_entry_size_bytes")}
    c = compile_cache.configure(tmp_path / "cache")
    compile_cache.reset_cache_stats()
    clear_engine_cache()
    clear_calibration_engine_cache()
    yield c
    compile_cache.deactivate()
    compile_cache.reset_cache_stats()
    clear_engine_cache()
    clear_calibration_engine_cache()
    for k, v in prev.items():
        jax.config.update(k, v)


def _restart():
    """Simulate a process restart: drop every in-process engine/program
    cache and zero the counters (the disk cache is what survives)."""
    clear_engine_cache()
    clear_calibration_engine_cache()
    compile_cache.reset_cache_stats()


# ---------------------------------------------------------------------------
# executable round-trip: bit-identical across a simulated restart
# ---------------------------------------------------------------------------


def test_sampling_executable_roundtrip_bit_identical(gmm, cache):
    spec = _spec()
    x = gmm.sample_prior(jax.random.key(0), BATCH, T_MAX)

    eng = get_engine_for_spec(spec)
    rep = eng.aot_compile(gmm.eps, BATCH, DIM, model_key=MODEL_KEY)
    assert rep["source"] == "compiled" and rep["dispatchable"]
    assert rep["serialized"] is True
    assert compile_cache.cache_stats()["executable_saves"] >= 1
    y_cold = np.asarray(eng.sample(gmm.eps, x))

    _restart()
    eng2 = get_engine_for_spec(spec)
    assert eng2 is not eng
    rep2 = eng2.aot_compile(gmm.eps, BATCH, DIM, model_key=MODEL_KEY)
    assert rep2["source"] == "deserialized"
    y_warm = np.asarray(eng2.sample(gmm.eps, x))

    assert np.array_equal(y_cold, y_warm)          # bit-identical, not close
    stats = engine_cache_stats()["persistent"]
    assert stats["executable_hits"] >= 1
    assert stats["executable_stale"] == 0


def test_calibration_executables_roundtrip_bit_identical(gmm, cache):
    spec = _spec()
    x = gmm.sample_prior(jax.random.key(1), BATCH, T_MAX)

    ceng = get_calibration_engine_for_spec(spec)
    rep = ceng.aot_compile(gmm.eps, BATCH, DIM, donate=False,
                           model_key=MODEL_KEY)
    assert set(rep["programs"]) == {"teacher", "calibrate", "gate"}
    assert all(p["source"] == "compiled" for p in rep["programs"].values())
    gt_cold = np.asarray(ceng.teacher_trajectory(gmm.eps, x))
    p_cold, _ = ceng.calibrate(gmm.eps, x, jnp.asarray(gt_cold), donate=False)
    coords_cold = np.asarray(p_cold.coords)

    _restart()
    ceng2 = get_calibration_engine_for_spec(spec)
    rep2 = ceng2.aot_compile(gmm.eps, BATCH, DIM, donate=False,
                             model_key=MODEL_KEY)
    assert all(p["source"] == "deserialized"
               for p in rep2["programs"].values())
    gt_warm = np.asarray(ceng2.teacher_trajectory(gmm.eps, x))
    p_warm, _ = ceng2.calibrate(gmm.eps, x, jnp.asarray(gt_warm),
                                donate=False)

    assert np.array_equal(gt_cold, gt_warm)
    assert np.array_equal(coords_cold, np.asarray(p_warm.coords))
    assert np.array_equal(np.asarray(p_cold.active),
                          np.asarray(p_warm.active))
    assert engine_cache_stats()["persistent"]["executable_hits"] >= 3


def test_donating_variants_skip_serialization(gmm, cache):
    """Donating programs must never enter the executable layer (deserialized
    executables lose jit's donation bookkeeping — calling one corrupts the
    freed buffer); they rely on the XLA-level disk cache alone."""
    spec = _spec()
    eng = get_engine_for_spec(spec)
    rep = eng.aot_compile(gmm.eps, BATCH, DIM, donate_x=True,
                          model_key=MODEL_KEY)
    assert rep["source"] == "compiled"
    assert "serialized" not in rep
    saves = compile_cache.cache_stats()["executable_saves"]

    _restart()
    eng2 = get_engine_for_spec(spec)
    rep2 = eng2.aot_compile(gmm.eps, BATCH, DIM, donate_x=True,
                            model_key=MODEL_KEY)
    assert rep2["source"] == "compiled"            # never deserialized
    stats = compile_cache.cache_stats()
    assert stats["executable_hits"] == 0
    assert stats["executable_saves"] == saves == 0


def test_xla_persistent_cache_hits_after_restart(gmm, cache):
    """The HLO-keyed XLA disk cache covers what serialization cannot: a
    restarted process recompiling the identical program takes counted
    persistent hits (the acceptance counter for warm fleets)."""
    spec = _spec()
    eng = get_engine_for_spec(spec)
    eng.aot_compile(gmm.eps, BATCH, DIM, donate_x=True, model_key=MODEL_KEY)

    _restart()
    eng2 = get_engine_for_spec(spec)
    eng2.aot_compile(gmm.eps, BATCH, DIM, donate_x=True, model_key=MODEL_KEY)
    stats = engine_cache_stats()["persistent"]
    assert stats["persistent_hits"] > 0
    assert stats["cache_dir"] == str(cache.cache_dir)


# ---------------------------------------------------------------------------
# stale entries: counted misses, graceful recompile, never a crash
# ---------------------------------------------------------------------------


def _toy_compiled():
    return (jax.jit(lambda v: v * 2.0 + 1.0)
            .lower(jax.ShapeDtypeStruct((4,), jnp.float32)).compile())


def test_stale_entries_fall_back_without_crashing(tmp_path):
    compile_cache.reset_cache_stats()
    c = CompileCache(tmp_path)
    if c.save_executable("k", _toy_compiled()) is None:
        pytest.skip("backend cannot serialize executables")
    bin_path, meta_path = c._entry_paths("k")

    # pristine entry restores and runs
    fn = c.load_executable("k")
    assert fn is not None
    np.testing.assert_allclose(fn(jnp.ones(4)), np.full(4, 3.0))

    # absent key: a counted plain miss
    assert c.load_executable("other") is None

    # runtime-fingerprint mismatch (jax upgraded / device count changed)
    meta = json.loads(meta_path.read_text())
    good = meta_path.read_text()
    meta["fingerprint"]["jax"] = "0.0.0"
    meta_path.write_text(json.dumps(meta))
    assert c.load_executable("k") is None

    # tampered/truncated blob: checksum rejects it
    meta_path.write_text(good)
    bin_path.write_bytes(bin_path.read_bytes()[:-7] + b"garbage")
    assert c.load_executable("k") is None

    # unreadable meta: still just a stale miss
    meta_path.write_text("{not json")
    assert c.load_executable("k") is None

    stats = compile_cache.cache_stats()
    assert stats["executable_hits"] == 1
    assert stats["executable_misses"] == 1
    assert stats["executable_stale"] == 3
    compile_cache.reset_cache_stats()


def test_stale_entry_recompiles_through_engine(gmm, cache):
    """A tampered entry behind a real engine: counted stale, then the engine
    recompiles and still samples correctly."""
    spec = _spec()
    eng = get_engine_for_spec(spec)
    eng.aot_compile(gmm.eps, BATCH, DIM, model_key=MODEL_KEY)
    blobs = list(cache.exec_dir.glob("*.bin"))
    assert blobs
    for b in blobs:
        b.write_bytes(b"corrupt")

    _restart()
    eng2 = get_engine_for_spec(spec)
    rep = eng2.aot_compile(gmm.eps, BATCH, DIM, model_key=MODEL_KEY)
    assert rep["source"] == "compiled"             # fell back, no crash
    stats = compile_cache.cache_stats()
    assert stats["executable_stale"] >= 1
    x = gmm.sample_prior(jax.random.key(2), BATCH, T_MAX)
    assert np.isfinite(np.asarray(eng2.sample(gmm.eps, x))).all()


def test_model_key_none_skips_executable_layer(gmm, cache):
    spec = _spec()
    eng = get_engine_for_spec(spec)
    rep = eng.aot_compile(gmm.eps, BATCH, DIM)     # no model_key
    assert rep["source"] == "compiled"
    assert "serialized" not in rep
    assert compile_cache.cache_stats()["executable_saves"] == 0


# ---------------------------------------------------------------------------
# stats surface
# ---------------------------------------------------------------------------


def test_engine_cache_stats_exposes_persistent_counters():
    stats = engine_cache_stats()
    assert "aot_variants" in stats
    per = stats["persistent"]
    for k in ("persistent_hits", "persistent_misses", "executable_hits",
              "executable_misses", "executable_stale", "executable_saves",
              "compile_seconds", "deserialize_seconds", "cache_dir"):
        assert k in per
