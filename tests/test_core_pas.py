"""End-to-end PAS validation against the paper's claims (on the analytic oracle).

These are the paper's core mechanism claims:
  * trajectories live in a ~3-D subspace (Fig. 2a),
  * truncation error is S-shaped (Fig. 3a),
  * PAS reduces truncation + final error (Tables 2/11 directionally),
  * adaptive search selects only a few steps (~10 params, Table 1/6),
  * correction never makes things worse (tolerance gate).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analytic, pas, pca, schedules, solvers

DIM = 64
NFE = 10
T_MAX, T_MIN = 80.0, 0.002


@pytest.fixture(scope="module")
def setup():
    gmm = analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)
    s_ts, t_ts, m = schedules.nested_teacher_schedule(NFE, 100, T_MIN, T_MAX)
    key = jax.random.key(0)
    x_t = gmm.sample_prior(key, 256, T_MAX)
    gt = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_t)
    return gmm, s_ts, x_t, gt


def test_trajectory_low_dimensional(setup):
    """Paper Fig. 2a: [x_T, d_N..d_1] has >=99.9% variance in 3 PCs."""
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver("euler", schedules.polynomial_schedule(100, T_MIN, T_MAX))
    xs, ds = solvers.sample_trajectory(sol, gmm.eps, x_t[:8])
    for b in range(4):
        traj = jnp.concatenate([x_t[b][None], ds[:, b, :]], axis=0)
        cv = np.asarray(pca.cumulative_variance(traj, center=False))
        assert cv[2] > 0.995, cv[:5]


def test_truncation_error_s_shape(setup):
    """Paper Fig. 3a: slow growth, fast growth, then slow growth again."""
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver("euler", s_ts)
    xs, _ = solvers.sample_trajectory(sol, gmm.eps, x_t)
    err = np.asarray(pas.truncation_error_curve(xs, gt))
    assert err[0] == 0.0
    total = err[-1] - err[0]
    # middle portion of the step range contributes the bulk of the error growth
    third = NFE // 3
    mid_growth = err[2 * third] - err[third]
    assert mid_growth > 0.45 * total, err
    # and error growth decelerates at the end (returns to slow growth)
    end_growth = err[-1] - err[-2]
    peak_growth = np.max(np.diff(err))
    assert end_growth < 0.6 * peak_growth, err


def _held_out(gmm, s_ts, nfe):
    key = jax.random.key(99)
    x_eval = gmm.sample_prior(key, 256, T_MAX)
    _, t_ts, m = schedules.nested_teacher_schedule(nfe, 100, T_MIN, T_MAX)
    gt_eval = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_eval)
    return x_eval, gt_eval


@pytest.mark.parametrize("solver_name,nfe,max_ratio,must_correct", [
    ("ddim", 10, 0.30, True),    # paper Table 2: large DDIM gains
    ("ddim", 5, 0.30, True),
    ("ipndm3", 5, 0.80, True),   # paper Table 11: modest iPNDM gains at low NFE
    ("ipndm3", 10, 1.02, False), # paper Table 11: L2 gains vanish at NFE 10 —
                                 # final gate must make PAS a no-op, not a loss
])
def test_pas_improves_solver(solver_name, nfe, max_ratio, must_correct):
    """PAS cuts final L2-to-teacher error on held-out samples (Tables 2/11)."""
    gmm = analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)
    s_ts, t_ts, m = schedules.nested_teacher_schedule(nfe, 100, T_MIN, T_MAX)
    x_t = gmm.sample_prior(jax.random.key(0), 512, T_MAX)
    gt = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_t)
    sol = solvers.make_solver(solver_name, s_ts)
    cfg = pas.PASConfig(lr=1e-2, n_sgd_iters=300, tolerance=1e-4, loss="l1",
                        val_fraction=0.25, final_gate=True)
    params, diag = pas.calibrate(sol, gmm.eps, x_t, gt, cfg)

    x_eval, gt_eval = _held_out(gmm, s_ts, nfe)
    x_plain = solvers.sample(sol, gmm.eps, x_eval)
    x_corr, _ = pas.pas_sample_trajectory(sol, gmm.eps, x_eval, params, cfg)
    e_plain = float(jnp.mean(jnp.linalg.norm(x_plain - gt_eval[-1], axis=-1)))
    e_corr = float(jnp.mean(jnp.linalg.norm(x_corr - gt_eval[-1], axis=-1)))
    if must_correct:
        assert params.active.any(), "adaptive search selected no steps"
    assert e_corr < e_plain * max_ratio, (solver_name, e_plain, e_corr, diag)


def test_adaptive_search_selects_few_steps(setup):
    """~10 parameters: only a small subset of steps gets corrected."""
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver("ddim", s_ts)
    cfg = pas.PASConfig(lr=1e-2, n_sgd_iters=200, tolerance=1e-2, loss="l1")
    params, diag = pas.calibrate(sol, gmm.eps, x_t, gt, cfg)
    n_corr = int(params.active.sum())
    assert 1 <= n_corr <= 6, diag
    assert params.n_stored_params == n_corr * 4
    steps = params.corrected_paper_steps()
    assert all(1 <= i <= NFE for i in steps)


def test_huge_tolerance_disables_correction(setup):
    """Paper Table 8 (tau=1e-1 row): with a huge tolerance PAS is a no-op."""
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver("ddim", s_ts)
    cfg = pas.PASConfig(lr=1e-2, n_sgd_iters=50, tolerance=1e9)
    params, _ = pas.calibrate(sol, gmm.eps, x_t, gt, cfg)
    assert not params.active.any()
    x_corr = pas.pas_sample(sol, gmm.eps, x_t, params, cfg)
    x_plain = solvers.sample(sol, gmm.eps, x_t)
    # scan vs unrolled execution differ by float32 accumulation noise only
    np.testing.assert_allclose(np.asarray(x_corr), np.asarray(x_plain),
                               rtol=1e-4, atol=1e-4)


def test_pas_never_hurts_on_calibration_set(setup):
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver("ddim", s_ts)
    cfg = pas.PASConfig(lr=1e-2, n_sgd_iters=200, tolerance=1e-4)
    params, diag = pas.calibrate(sol, gmm.eps, x_t, gt, cfg)
    assert diag["final_l2_to_gt"] <= diag["loss_before"][-1] + 1e-6


@pytest.mark.parametrize("loss", ["l1", "l2", "pseudo_huber"])
def test_loss_functions_all_work(setup, loss):
    gmm, s_ts, x_t, gt = setup
    sol = solvers.make_solver("ddim", s_ts)
    cfg = pas.PASConfig(lr=1e-2, n_sgd_iters=100, loss=loss)
    params, diag = pas.calibrate(sol, gmm.eps, x_t[:64], gt[:, :64], cfg)
    assert np.isfinite(diag["final_l2_to_gt"])
