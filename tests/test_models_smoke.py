"""Per-arch smoke tests: REDUCED config of the same family, one forward +
prefill/decode + one train-grad step on CPU; output shapes + no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ASSIGNED_ARCHS, get_config

B, S = 2, 16


def _batch(cfg, key):
    kt, kp, ke = jax.random.split(key, 3)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "vision_patches":
        batch["prefix_embeds"] = jax.random.normal(
            kp, (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["enc_states"] = jax.random.normal(
            ke, (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.key(0)
    params = models.init_params(key, cfg)
    batch = _batch(cfg, jax.random.key(1))
    logits, aux = models.forward(params, batch["tokens"], cfg,
                                 prefix_embeds=batch.get("prefix_embeds"),
                                 enc_states=batch.get("enc_states"))
    s_total = S + (cfg.frontend_len if cfg.frontend == "vision_patches" else 0)
    assert logits.shape == (B, s_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    loss, metrics = models.lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_grad_step(arch):
    cfg = get_config(arch).reduced()
    params = models.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))

    def loss_fn(p):
        return models.lm_loss(p, batch, cfg)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), arch
    # sanity: gradients point downhill — some step size must reduce the loss
    # (a single fixed lr overshoots on the stiffest archs, e.g. gemma3-1b)
    for lr in (0.5, 0.1, 0.02):
        new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        if float(loss_fn(new_params)) < float(loss) + 1e-6:
            break
    else:
        pytest.fail(f"{arch}: no step size in (0.5, 0.1, 0.02) reduced loss")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy decode logits must match the full-forward logits step by step."""
    cfg = get_config(arch).reduced()
    params = models.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    tokens = batch["tokens"]
    full_logits, _ = models.forward(params, tokens, cfg,
                                    prefix_embeds=batch.get("prefix_embeds"),
                                    enc_states=batch.get("enc_states"))

    split = S // 2
    prefix = cfg.frontend_len if cfg.frontend == "vision_patches" else 0
    last, cache = models.prefill(params, tokens[:, :split], cfg,
                                 max_len=prefix + S + 4,
                                 prefix_embeds=batch.get("prefix_embeds"),
                                 enc_states=batch.get("enc_states"))
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, prefix + split - 1]),
        rtol=2e-2, atol=2e-2)

    logits = last
    for j in range(split, S):
        logits, cache = models.decode_step(params, cache, tokens[:, j], cfg)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, prefix + j]),
            rtol=3e-2, atol=3e-2,
            err_msg=f"{arch} step {j}")


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "falcon-mamba-7b",
                                  "mixtral-8x7b", "recurrentgemma-9b"])
def test_denoise_mode(arch):
    cfg = get_config(arch).reduced()
    params = models.init_params(jax.random.key(0), cfg,
                                with_diffusion_head=True)
    x = jax.random.normal(jax.random.key(2), (B, S, cfg.d_model))
    sigma = jnp.asarray([1.0, 10.0])
    out = models.denoise(params, x, sigma, cfg)
    assert out.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(out)).all()


def test_param_specs_no_allocation():
    cfg = get_config("qwen2-72b")  # FULL config: must not allocate
    specs = models.param_specs(cfg)
    leaves = jax.tree_util.tree_leaves(specs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    total = sum(int(np.prod(l.shape)) for l in leaves)
    assert 60e9 < total < 90e9, total  # ~72B params

def test_param_count_estimates():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        specs = models.param_specs(cfg)
        total = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(specs))
        est = cfg.param_count()
        assert 0.7 < est / total < 1.4, (arch, est, total)
