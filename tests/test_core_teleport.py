"""TP (teleportation) warm-start: exactness for Gaussians + PAS synergy."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytic, schedules, solvers, teleport

DIM = 64
T_MAX, T_MIN = 80.0, 0.002


def test_teleport_exact_for_gaussian():
    mean = jnp.asarray(np.linspace(-1, 1, DIM), jnp.float32)
    var = jnp.full((DIM,), 0.3, jnp.float32)
    gmm = analytic.GaussianMixture(mean[None], var[None], jnp.zeros((1,)))
    x_t = 80.0 * jax.random.normal(jax.random.key(0), (8, DIM))
    stats = teleport.GaussianStats(mean=mean, variance=var)
    x_skip = teleport.teleport(stats, x_t, T_MAX, 10.0)
    # continue with a fine solver from sigma_skip and compare with closed form
    ts = teleport.tp_schedule(64, sigma_skip=10.0, t_min=T_MIN)
    sol = solvers.make_solver("heun", ts)
    x0 = solvers.sample(sol, gmm.eps, x_skip)
    exact = analytic.gaussian_ode_solution(mean, var, x_t, jnp.asarray(T_MAX),
                                           jnp.asarray(T_MIN))
    err = float(jnp.mean(jnp.linalg.norm(x0 - exact, axis=-1)))
    assert err < 2e-2, err  # residual = 64-step Heun discretization, not TP


def test_tp_improves_low_nfe_sampling():
    """Paper Table 2 (DDIM+TP rows): TP beats plain DDIM at low NFE."""
    gmm = analytic.two_mode_gmm(DIM, sep=6.0, var=0.25)
    key = jax.random.key(1)
    x_t = gmm.sample_prior(key, 128, T_MAX)
    # ground truth endpoint via fine teacher
    s_ts, t_ts, m = schedules.nested_teacher_schedule(10, 100, T_MIN, T_MAX)
    gt = solvers.ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_t)

    # plain DDIM, NFE=10
    x_plain = solvers.sample(solvers.make_solver("ddim", s_ts), gmm.eps, x_t)

    # TP: moment-matched Gaussian, teleport to sigma_skip, then 10-NFE DDIM
    data = gmm.sample_data(jax.random.key(2), 4096)
    stats = teleport.gaussian_stats_from_data(data)
    x_skip = teleport.teleport(stats, x_t, T_MAX, 10.0)
    tp_ts = teleport.tp_schedule(10, sigma_skip=10.0, t_min=T_MIN)
    x_tp = solvers.sample(solvers.make_solver("ddim", tp_ts), gmm.eps, x_skip)

    e_plain = float(jnp.mean(jnp.linalg.norm(x_plain - gt[-1], axis=-1)))
    e_tp = float(jnp.mean(jnp.linalg.norm(x_tp - gt[-1], axis=-1)))
    assert e_tp < e_plain, (e_tp, e_plain)
