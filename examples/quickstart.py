"""Quickstart: PAS in ~60 seconds on CPU.

Calibrates PCA-based Adaptive Search (paper Alg. 1) for a 10-NFE DDIM sampler
against a 100-NFE teacher, then samples with the learned ~10 parameters
(Alg. 2) through the fused SamplingEngine and reports the truncation-error
reduction on held-out noise.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (PASConfig, calibrate, nested_teacher_schedule,
                        make_solver, ground_truth_trajectory, two_mode_gmm)
from repro.engine import engine_for_solver

DIM, NFE = 64, 10


def main():
    gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)        # exact eps(x, t) oracle
    s_ts, t_ts, m = nested_teacher_schedule(NFE, 100, 0.002, 80.0)
    solver = make_solver("ddim", s_ts)

    print(f"== PAS quickstart: DDIM @ {NFE} NFE, D={DIM} ==")
    x_calib = gmm.sample_prior(jax.random.key(0), 512, 80.0)
    gt = ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_calib)

    cfg = PASConfig(lr=1e-2, n_sgd_iters=300, tolerance=1e-4, loss="l1",
                    val_fraction=0.25)
    params, diag = calibrate(solver, gmm.eps, x_calib, gt, cfg)
    print(f"corrected steps (paper index i): {params.corrected_paper_steps()}")
    print(f"stored parameters: {params.n_stored_params} "
          f"(~10, as the title promises)")

    x_eval = gmm.sample_prior(jax.random.key(99), 256, 80.0)
    gt_eval = ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_eval)
    err = lambda x: float(jnp.mean(jnp.linalg.norm(x - gt_eval[-1], axis=-1)))

    # one engine, one entry point: plain and corrected are the same scan
    engine = engine_for_solver(solver)
    x_plain = engine.sample(gmm.eps, x_eval)
    x_pas = engine.sample(gmm.eps, x_eval, params=params, cfg=cfg)
    e0, e1 = err(x_plain), err(x_pas)
    print(f"final L2 to teacher  DDIM: {e0:.4f}   DDIM+PAS: {e1:.4f} "
          f"({e0 / max(e1, 1e-9):.1f}x better)")
    assert e1 < e0
    print("OK")


if __name__ == "__main__":
    main()
