"""Quickstart: PAS in ~60 seconds on CPU, through the public repro.api.

One spec, one pipeline: calibrate PCA-based Adaptive Search (paper Alg. 1)
for a 10-NFE DDIM sampler against a 100-NFE teacher, sample with the learned
~10 parameters (Alg. 2) through the fused SamplingEngine, report the
truncation-error reduction on held-out noise — then make the paper's storage
claim literal: save the calibrated sampler as a ~10-float PASArtifact and
reload it bit-for-bit.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PASConfig, Pipeline, SamplerSpec, TeacherSpec
from repro.core import two_mode_gmm

DIM, NFE = 64, 10


def main():
    gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)        # exact eps(x, t) oracle

    spec = SamplerSpec(
        solver="ddim", nfe=NFE,
        teacher=TeacherSpec(solver="heun", nfe=100),
        pas=PASConfig(lr=1e-2, n_sgd_iters=300, tolerance=1e-4, loss="l1",
                      val_fraction=0.25))
    pipe = Pipeline.from_spec(spec, gmm.eps, dim=DIM)

    print(f"== PAS quickstart: DDIM @ {NFE} NFE, D={DIM} ==")
    pipe.calibrate(key=jax.random.key(0), batch=512)
    print(f"corrected steps (paper index i): "
          f"{pipe.params.corrected_paper_steps()}")
    print(f"stored parameters: {pipe.params.n_stored_params} "
          f"(~10, as the title promises)")

    x_eval = gmm.sample_prior(jax.random.key(99), 256, 80.0)
    gt_eval = pipe.teacher_trajectory(x_eval)
    err = lambda x: float(jnp.mean(jnp.linalg.norm(x - gt_eval[-1], axis=-1)))

    # one pipeline, one entry point: plain and corrected are the same scan
    x_plain = pipe.sample(x_eval, use_pas=False)
    x_pas = pipe.sample(x_eval)
    e0, e1 = err(x_plain), err(x_pas)
    print(f"final L2 to teacher  DDIM: {e0:.4f}   DDIM+PAS: {e1:.4f} "
          f"({e0 / max(e1, 1e-9):.1f}x better)")
    assert e1 < e0

    # the storage claim, literally: a calibrated sampler is a ~10-float file
    with tempfile.TemporaryDirectory() as d:
        pipe.save(d)
        pipe2 = Pipeline.load(d, gmm.eps, dim=DIM)
        assert pipe2.spec == spec
        x_loaded = pipe2.sample(x_eval)
        assert np.array_equal(np.asarray(x_loaded), np.asarray(x_pas))
        print(f"artifact round-trip: {pipe2.params.n_stored_params} params "
              f"reloaded, samples bit-identical")
    print("OK")


if __name__ == "__main__":
    main()
