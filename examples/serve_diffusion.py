"""Serving driver (the paper's kind): batched diffusion sampling requests
through the DiffusionServer, with hot-swappable PAS correction.

  PYTHONPATH=src python examples/serve_diffusion.py [--nfe 10] [--no-pas]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PASConfig, calibrate, nested_teacher_schedule,
                        ground_truth_trajectory, two_mode_gmm)
from repro.runtime import DiffusionServer, Request, ServeConfig

DIM = 64


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--no-pas", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)
    cfg = ServeConfig(nfe=args.nfe, use_pas=not args.no_pas, max_batch=128,
                      pas=PASConfig(val_fraction=0.25))
    server = DiffusionServer(gmm.eps, DIM, cfg)

    if not args.no_pas:
        # offline calibration: sub-minute, ~10 parameters (paper §3.5)
        s_ts, t_ts, m = nested_teacher_schedule(args.nfe, 100, cfg.t_min,
                                                cfg.t_max)
        x_c = gmm.sample_prior(jax.random.key(0), 512, cfg.t_max)
        gt = ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_c)
        pas_params, _ = calibrate(server.solver, gmm.eps, x_c, gt, cfg.pas)
        server.set_pas(pas_params)
        print(f"PAS hot-swapped: steps {pas_params.corrected_paper_steps()}, "
              f"{pas_params.n_stored_params} stored params")

    reqs = [Request(seed=i, n_samples=8 + 8 * (i % 3))
            for i in range(args.requests)]
    outs = server.serve(reqs)
    assert len(outs) == len(reqs)

    # quality report vs the teacher endpoint for the first request
    s_ts, t_ts, m = nested_teacher_schedule(args.nfe, 100, cfg.t_min, cfg.t_max)
    x_t = cfg.t_max * jax.random.normal(jax.random.key(reqs[0].seed),
                                        (reqs[0].n_samples, DIM))
    gt = ground_truth_trajectory(gmm.eps, s_ts, t_ts, m, x_t)
    err = float(jnp.mean(jnp.linalg.norm(outs[0] - np.asarray(gt[-1]), axis=-1)))
    print(f"served {server.stats['samples']} samples in "
          f"{server.stats['batches']} batches "
          f"({server.stats['wall_s']:.2f}s); req0 L2-to-teacher={err:.4f}")
    print("OK")


if __name__ == "__main__":
    main()
