"""Serving driver (the paper's kind): batched diffusion sampling requests
through the DiffusionServer, with hot-swappable PAS correction — all built
through the repro.api Pipeline.

Serving goes through the async continuous-batching scheduler by default
(``DiffusionServer.serve`` is a bit-identical sync facade over it);
``--deadline-ms`` bounds how long a request may wait to batch and
``--stream`` demonstrates per-request chunk streaming.

  PYTHONPATH=src python examples/serve_diffusion.py [--nfe 10] [--no-pas]
      [--artifact-dir DIR] [--deadline-ms MS] [--stream]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PASArtifact, PASConfig, Pipeline
from repro.core import two_mode_gmm
from repro.api import DiffusionServer, Request, ServeConfig

DIM = 64


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--no-pas", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--artifact-dir", default=None,
                    help="save/load the calibrated PASArtifact here")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="max batching slack per request (async scheduler)")
    ap.add_argument("--stream", action="store_true",
                    help="submit individually and stream chunk arrival")
    args = ap.parse_args()

    gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)
    cfg = ServeConfig(nfe=args.nfe, use_pas=not args.no_pas, max_batch=128,
                      pas=PASConfig(val_fraction=0.25),
                      deadline_ms=args.deadline_ms)

    if args.no_pas:
        server = DiffusionServer(gmm.eps, DIM, cfg)
    elif args.artifact_dir and PASArtifact.exists(args.artifact_dir):
        pipe = Pipeline.load(args.artifact_dir, gmm.eps, dim=DIM,
                             expected_spec=cfg.to_spec())
        server = DiffusionServer.from_pipeline(pipe, cfg)
        print(f"PAS artifact loaded: steps "
              f"{pipe.params.corrected_paper_steps()}, "
              f"{pipe.params.n_stored_params} stored params")
    else:
        # offline calibration: sub-minute, ~10 parameters (paper §3.5)
        pipe = Pipeline.from_spec(cfg.to_spec(), gmm.eps, dim=DIM)
        pipe.calibrate(x_t=gmm.sample_prior(jax.random.key(0), 512, cfg.t_max))
        server = DiffusionServer.from_pipeline(pipe, cfg)
        print(f"PAS hot-swapped: steps {pipe.params.corrected_paper_steps()}, "
              f"{pipe.params.n_stored_params} stored params")
        if args.artifact_dir:
            print(f"PAS artifact saved to {pipe.save(args.artifact_dir)}")

    reqs = [Request(seed=i, n_samples=8 + 8 * (i % 3))
            for i in range(args.requests)]
    if args.stream:
        handles = [server.submit(r) for r in reqs]
        server.drain(timeout=600)
        outs = [h.result() for h in handles]
        for i, h in enumerate(handles):
            print(f"request {i}: {h.n_samples} rows, "
                  f"latency {1e3 * h.latency_s:.1f}ms")
    else:
        outs = server.serve(reqs)
    assert len(outs) == len(reqs)

    # quality report vs the teacher endpoint for the first request
    x_t = cfg.t_max * jax.random.normal(jax.random.key(reqs[0].seed),
                                        (reqs[0].n_samples, DIM))
    gt = server.pipeline.teacher_trajectory(x_t)
    err = float(jnp.mean(jnp.linalg.norm(outs[0] - np.asarray(gt[-1]), axis=-1)))
    print(f"served {server.stats['samples']} samples in "
          f"{server.stats['batches']} batches "
          f"({server.stats['wall_s']:.2f}s); req0 L2-to-teacher={err:.4f}")
    print("OK")


if __name__ == "__main__":
    main()
