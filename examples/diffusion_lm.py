"""PAS at LM scale: a zoo backbone (reduced) in diffusion-LM mode.

The backbone runs as the denoiser eps_theta over noisy token-embedding
sequences (DESIGN.md §4); PAS corrects its PF-ODE sampler exactly as it does
for image models — the technique is solver-level and model-agnostic.

  PYTHONPATH=src python examples/diffusion_lm.py [--arch qwen1.5-0.5b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro import models
from repro.api import PASConfig, Pipeline, SamplerSpec, TeacherSpec
from repro.configs import get_config
from repro.diffusion import EDMConfig, eps_from_denoiser, precondition

SEQ = 32
NFE = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = models.init_params(jax.random.key(0), cfg,
                                with_diffusion_head=True)
    d_state = SEQ * cfg.d_model
    print(f"== diffusion-LM PAS: {args.arch} (reduced) "
          f"D = {SEQ}x{cfg.d_model} = {d_state} ==")

    def raw_fn(x_flat, c_noise):        # (B, D), (B,) -> (B, D)
        x = x_flat.reshape(-1, SEQ, cfg.d_model)
        sigma = jnp.exp(4.0 * c_noise)
        out = models.denoise(params, x, sigma, cfg)
        return out.reshape(x_flat.shape)

    denoiser = precondition(raw_fn, EDMConfig(sigma_data=1.0))
    eps_fn = jax.jit(eps_from_denoiser(denoiser))

    spec = SamplerSpec(solver="ddim", nfe=NFE,
                       teacher=TeacherSpec(solver="heun", nfe=64),
                       pas=PASConfig(n_sgd_iters=100, val_fraction=0.25))
    pipe = Pipeline.from_spec(spec, eps_fn, dim=d_state)
    pipe.calibrate(key=jax.random.key(1), batch=32)
    print(f"corrected steps: {pipe.params.corrected_paper_steps()} "
          f"({pipe.params.n_stored_params} params)")

    x_e = 80.0 * jax.random.normal(jax.random.key(2), (16, d_state))
    gt_e = pipe.teacher_trajectory(x_e)
    err = lambda x: float(jnp.mean(jnp.linalg.norm(x - gt_e[-1], axis=-1)))
    e0 = err(pipe.sample(x_e, use_pas=False))
    e1 = err(pipe.sample(x_e))
    print(f"DDIM err {e0:.4f} -> +PAS {e1:.4f}")
    print("OK" if e1 <= e0 * 1.01 else "WARN: no gain on this random model")


if __name__ == "__main__":
    main()
