"""PAS at LM scale: a zoo backbone (reduced) in diffusion-LM mode.

The backbone runs as the denoiser eps_theta over noisy token-embedding
sequences (DESIGN.md §4); PAS corrects its PF-ODE sampler exactly as it does
for image models — the technique is solver-level and model-agnostic.

  PYTHONPATH=src python examples/diffusion_lm.py [--arch qwen1.5-0.5b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import get_config
from repro.core import (PASConfig, calibrate, nested_teacher_schedule,
                        make_solver, ground_truth_trajectory,
                        pas_sample_trajectory, sample)
from repro.diffusion import EDMConfig, eps_from_denoiser, precondition

SEQ = 32
NFE = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = models.init_params(jax.random.key(0), cfg,
                                with_diffusion_head=True)
    d_state = SEQ * cfg.d_model
    print(f"== diffusion-LM PAS: {args.arch} (reduced) "
          f"D = {SEQ}x{cfg.d_model} = {d_state} ==")

    def raw_fn(x_flat, c_noise):        # (B, D), (B,) -> (B, D)
        x = x_flat.reshape(-1, SEQ, cfg.d_model)
        sigma = jnp.exp(4.0 * c_noise)
        out = models.denoise(params, x, sigma, cfg)
        return out.reshape(x_flat.shape)

    denoiser = precondition(raw_fn, EDMConfig(sigma_data=1.0))
    eps_fn = jax.jit(eps_from_denoiser(denoiser))

    s_ts, t_ts, m = nested_teacher_schedule(NFE, 64, 0.002, 80.0)
    solver = make_solver("ddim", s_ts)
    x_c = 80.0 * jax.random.normal(jax.random.key(1), (32, d_state))
    gt = ground_truth_trajectory(eps_fn, s_ts, t_ts, m, x_c)

    pas_cfg = PASConfig(n_sgd_iters=100, val_fraction=0.25)
    pas_params, diag = calibrate(solver, eps_fn, x_c, gt, pas_cfg)
    print(f"corrected steps: {pas_params.corrected_paper_steps()} "
          f"({pas_params.n_stored_params} params)")

    x_e = 80.0 * jax.random.normal(jax.random.key(2), (16, d_state))
    gt_e = ground_truth_trajectory(eps_fn, s_ts, t_ts, m, x_e)
    err = lambda x: float(jnp.mean(jnp.linalg.norm(x - gt_e[-1], axis=-1)))
    e0 = err(sample(solver, eps_fn, x_e))
    e1 = err(pas_sample_trajectory(solver, eps_fn, x_e, pas_params, pas_cfg)[0])
    print(f"DDIM err {e0:.4f} -> +PAS {e1:.4f}")
    print("OK" if e1 <= e0 * 1.01 else "WARN: no gain on this random model")


if __name__ == "__main__":
    main()
