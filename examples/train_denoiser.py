"""End-to-end training driver: train a small EDM denoiser for a few hundred
steps with the production runtime (fault-tolerant loop: async checkpoints,
resume, straggler monitor), then calibrate PAS on the *learned* model.

  PYTHONPATH=src python examples/train_denoiser.py [--steps 400] [--resume]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.api import PASConfig, Pipeline, SamplerSpec
from repro.core import two_mode_gmm
from repro.diffusion import (EDMConfig, edm_loss, eps_from_denoiser,
                             init_denoiser, precondition, raw_apply)
from repro.optim import AdamW, warmup_cosine
from repro.api import TrainLoopConfig, run_train_loop

DIM = 64


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="edm_ckpt_")

    gmm = two_mode_gmm(DIM, sep=6.0, var=0.25)
    edm_cfg = EDMConfig(sigma_data=float(jnp.std(
        gmm.sample_data(jax.random.key(11), 2048))))
    params = init_denoiser(jax.random.key(0), DIM, width=128, depth=3)
    opt = AdamW(lr=warmup_cosine(2e-3, 20, args.steps), weight_decay=0.0)
    opt_state = opt.init(params)

    def batches():
        step = 0
        while True:
            yield {"key": jax.random.key(step)}
            step += 1

    @jax.jit
    def step_fn(params, opt_state, batch):
        k1, k2 = jax.random.split(batch["key"])
        x0 = gmm.sample_data(k1, 256)

        def loss_fn(p):
            den = precondition(lambda x, c: raw_apply(p, x, c), edm_cfg)
            return edm_loss(den, k2, x0, edm_cfg)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, {"ce_loss": loss, **om}

    cfg = TrainLoopConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                          ckpt_every=100, log_every=50)
    params, _, summary = run_train_loop(step_fn, params, opt_state, batches(),
                                        cfg)
    print(f"trained {summary['final_step']} steps "
          f"(resumed from {summary['resumed_from']}); "
          f"loss {summary['history'][0]['ce_loss']:.3f} -> "
          f"{summary['history'][-1]['ce_loss']:.3f}; ckpts in {ckpt_dir}")

    # PAS on the learned model, through the public api
    den = precondition(lambda x, c: raw_apply(params, x, c), edm_cfg)
    eps_fn = eps_from_denoiser(den)
    spec = SamplerSpec(solver="ddim", nfe=10,
                       pas=PASConfig(val_fraction=0.25))
    pipe = Pipeline.from_spec(spec, eps_fn, dim=DIM)
    pipe.calibrate(key=jax.random.key(1), batch=256)

    x_e = gmm.sample_prior(jax.random.key(2), 256, 80.0)
    gt_e = pipe.teacher_trajectory(x_e)
    err = lambda x: float(jnp.mean(jnp.linalg.norm(x - gt_e[-1], axis=-1)))
    e0 = err(pipe.sample(x_e, use_pas=False))
    e1 = err(pipe.sample(x_e))
    print(f"learned-model DDIM err {e0:.4f} -> +PAS {e1:.4f} "
          f"(steps {pipe.params.corrected_paper_steps()})")
    print("OK")


if __name__ == "__main__":
    main()
