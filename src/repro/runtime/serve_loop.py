"""Batched diffusion serving with PAS correction (the paper's serving story).

Requests (each: a PRNG seed + sample count) are micro-batched up to
``max_batch``; a batch runs the PAS-corrected solver once for all requests.
The PAS coordinate table (~10 floats) is part of the server state — hot-
swappable without touching model weights (plug-and-play, paper §3.5).

Sampling goes through the fused ``SamplingEngine`` (repro/engine): the
coefficient tables are bound once at server construction, every batch reuses
the same compiled scan, and hot-swapping PAS params only re-specialises the
corrected prefix (the compiled plain path is untouched).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PASConfig, PASParams, solvers
from repro.engine import engine_for_solver

__all__ = ["ServeConfig", "DiffusionServer", "Request"]


@dataclasses.dataclass
class Request:
    seed: int
    n_samples: int


@dataclasses.dataclass
class ServeConfig:
    nfe: int = 10
    solver: str = "ddim"
    t_min: float = 0.002
    t_max: float = 80.0
    max_batch: int = 256
    use_pas: bool = True
    pas: PASConfig = dataclasses.field(default_factory=PASConfig)


class DiffusionServer:
    def __init__(self, eps_fn: Callable, dim: int, cfg: ServeConfig,
                 pas_params: Optional[PASParams] = None):
        from repro.core import polynomial_schedule
        self.cfg = cfg
        self.dim = dim
        self.eps_fn = eps_fn
        ts = polynomial_schedule(cfg.nfe, cfg.t_min, cfg.t_max)
        self.solver = solvers.make_solver(cfg.solver, ts)
        self.engine = engine_for_solver(self.solver)
        self.pas_params = pas_params
        self.stats = {"requests": 0, "samples": 0, "batches": 0,
                      "nfe_total": 0, "wall_s": 0.0}

    def set_pas(self, params: Optional[PASParams]) -> None:
        """Hot-swap the ~10 learned parameters (no model reload)."""
        self.pas_params = params

    def _run_batch(self, x_t: jnp.ndarray) -> jnp.ndarray:
        params = self.pas_params if self.cfg.use_pas else None
        return self.engine.sample(self.eps_fn, x_t, params=params,
                                  cfg=self.cfg.pas)

    def serve(self, requests: list[Request]) -> list[np.ndarray]:
        """Micro-batches requests; returns one array of samples per request."""
        outs: list[np.ndarray] = []
        pending: list[tuple[int, jnp.ndarray]] = []  # (request idx, x_T rows)
        sizes: list[int] = []
        t0 = time.time()

        def flush():
            if not pending:
                return
            x_t = jnp.concatenate([x for _, x in pending], axis=0)
            x0 = np.asarray(self._run_batch(x_t))
            off = 0
            for (i, x), n in zip(pending, sizes):
                outs.append(x0[off:off + n])
                off += n
            self.stats["batches"] += 1
            self.stats["nfe_total"] += self.solver.nfe
            pending.clear()
            sizes.clear()

        budget = self.cfg.max_batch
        for i, req in enumerate(requests):
            x_t = self.cfg.t_max * jax.random.normal(
                jax.random.key(req.seed), (req.n_samples, self.dim))
            if sum(sizes) + req.n_samples > budget:
                flush()
            pending.append((i, x_t))
            sizes.append(req.n_samples)
            self.stats["requests"] += 1
            self.stats["samples"] += req.n_samples
        flush()
        self.stats["wall_s"] += time.time() - t0
        return outs
