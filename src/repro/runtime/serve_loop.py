"""Batched diffusion serving with PAS correction (the paper's serving story).

Requests (each: a PRNG seed + sample count) are micro-batched up to
``max_batch``; a batch runs the PAS-corrected solver once for all requests.
Requests larger than ``max_batch`` are chunked across flushes (never run as
one oversized batch) and reassembled per request.

``DiffusionServer`` is a micro-batching shell around a ``repro.api.Pipeline``:
the pipeline owns the spec, the fused engine binding, and the PAS coordinate
table (~10 floats) — hot-swappable without touching model weights
(plug-and-play, paper §3.5).  Hot-swapping PAS params only re-specialises the
corrected prefix; the compiled plain path is untouched.

Mesh serving: ``ServeConfig.mesh`` (a ``repro.parallel.MeshSpec``) binds the
pipeline's engine to a (dp, state) device grid.  Flushes are padded to a
DP-divisible row count (pad rows are masked back out of every response), the
flush buffer is donated to the compiled scan, and ``stats["nfe_total"]``
counts the model evaluations *actually executed* — per padded row, chunked
flushes and pad waste included — so the counter is an honest cost meter.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import MeshSpec, Pipeline, SamplerSpec, ScheduleSpec
from repro.core import PASConfig, PASParams

__all__ = ["ServeConfig", "DiffusionServer", "Request"]


@dataclasses.dataclass
class Request:
    seed: int
    n_samples: int


@dataclasses.dataclass
class ServeConfig:
    nfe: int = 10
    solver: str = "ddim"
    t_min: float = 0.002
    t_max: float = 80.0
    max_batch: int = 256
    use_pas: bool = True
    pas: PASConfig = dataclasses.field(default_factory=PASConfig)
    mesh: MeshSpec = dataclasses.field(default_factory=MeshSpec)

    def to_spec(self) -> SamplerSpec:
        """The declarative sampler description this config serves."""
        return SamplerSpec(
            solver=self.solver, nfe=self.nfe,
            schedule=ScheduleSpec(t_min=self.t_min, t_max=self.t_max),
            pas=self.pas, mesh=self.mesh)


class DiffusionServer:
    def __init__(self, eps_fn: Callable, dim: int, cfg: ServeConfig,
                 pas_params: Optional[PASParams] = None,
                 pipeline: Optional[Pipeline] = None):
        self.cfg = cfg
        self.pipeline = (pipeline if pipeline is not None
                         else Pipeline.from_spec(cfg.to_spec(), eps_fn,
                                                 dim=dim))
        if pas_params is not None:
            self.pipeline.set_params(pas_params)
        # nfe_total = model evaluations actually executed, counted per padded
        # flush row: a flush of R rows on an engine whose trajectory costs E
        # evals (E = 2x steps for 2-eval teachers) adds R * E.  Chunked
        # flushes and DP pad rows are therefore included — the counter is the
        # true compute spent, not requests x nominal-NFE.
        self.stats = {"requests": 0, "samples": 0, "batches": 0,
                      "nfe_total": 0, "padded_samples": 0, "wall_s": 0.0}

    @classmethod
    def from_pipeline(cls, pipeline: Pipeline,
                      cfg: Optional[ServeConfig] = None) -> "DiffusionServer":
        """Serve an existing (typically calibrated/loaded) pipeline."""
        if cfg is None:
            spec = pipeline.spec
            ts = spec.ts()
            cfg = ServeConfig(nfe=spec.nfe, solver=spec.solver,
                              t_min=float(ts[-1]), t_max=float(ts[0]),
                              pas=spec.pas, mesh=spec.mesh)
        return cls(pipeline.eps_fn, pipeline.dim, cfg, pipeline=pipeline)

    # -- pipeline delegation ------------------------------------------------

    @property
    def eps_fn(self):
        return self.pipeline.eps_fn

    @property
    def dim(self):
        return self.pipeline.dim

    @property
    def solver(self):
        return self.pipeline.solver

    @property
    def engine(self):
        return self.pipeline.engine

    @property
    def pas_params(self) -> Optional[PASParams]:
        return self.pipeline.params

    def set_pas(self, params: Optional[PASParams]) -> None:
        """Hot-swap the ~10 learned parameters (no model reload)."""
        self.pipeline.set_params(params)

    def _run_batch(self, x_t: jnp.ndarray) -> jnp.ndarray:
        # the flush buffer is built fresh per flush and never reused, so it
        # is donated to the compiled scan (free initial-state buffer)
        return self.pipeline.sample(x_t, use_pas=self.cfg.use_pas,
                                    donate_x=True)

    # -- serving -------------------------------------------------------------

    def serve(self, requests: list[Request]) -> list[np.ndarray]:
        """Micro-batches requests; returns one array of samples per request.

        Oversized requests (n_samples > max_batch) are split into
        max_batch-sized chunks across flushes; the final partial chunk stays
        pending so later requests can pack into the same batch.

        Under a DP mesh every flush is padded to a DP-divisible row count
        (prior rows repeated as ballast — always in-distribution for the
        model) and the pad rows are masked back out of the responses; they
        still show up in ``nfe_total``/``padded_samples`` because the
        devices really did burn those evals.
        """
        parts: list[list[np.ndarray]] = [[] for _ in requests]
        pending: list[tuple[int, jnp.ndarray]] = []  # (request idx, x_T rows)
        sizes: list[int] = []
        t0 = time.time()
        mesh = self.pipeline.mesh_spec

        def flush():
            if not pending:
                return
            x_t = jnp.concatenate([x for _, x in pending], axis=0)
            n_rows = int(x_t.shape[0])
            pad = mesh.pad_batch(n_rows)
            if pad:                       # pad-and-mask to a DP-divisible batch
                filler = jnp.tile(x_t, (pad // n_rows + 1, 1))[:pad]
                x_t = jnp.concatenate([x_t, filler], axis=0)
            x0 = np.asarray(self._run_batch(x_t))
            off = 0
            for (i, _), n in zip(pending, sizes):
                parts[i].append(x0[off:off + n])
                off += n
            self.stats["batches"] += 1
            self.stats["nfe_total"] += (n_rows + pad) * self.engine.nfe
            self.stats["padded_samples"] += pad
            pending.clear()
            sizes.clear()

        budget = self.cfg.max_batch
        for i, req in enumerate(requests):
            x_t = self.pipeline.prior(jax.random.key(req.seed), req.n_samples)
            self.stats["requests"] += 1
            self.stats["samples"] += req.n_samples
            if req.n_samples <= budget:
                if sum(sizes) + req.n_samples > budget:
                    flush()
                pending.append((i, x_t))
                sizes.append(req.n_samples)
            else:
                flush()
                for off in range(0, req.n_samples, budget):
                    chunk = x_t[off:off + budget]
                    pending.append((i, chunk))
                    sizes.append(int(chunk.shape[0]))
                    if sum(sizes) >= budget:
                        flush()
        flush()
        self.stats["wall_s"] += time.time() - t0
        return [p[0] if len(p) == 1 else np.concatenate(p, axis=0)
                for p in parts]
