"""Batched diffusion serving with PAS correction (the paper's serving story).

Requests (each: a PRNG seed + sample count) are micro-batched up to
``max_batch``; a batch runs the PAS-corrected solver once for all requests.
Requests larger than ``max_batch`` are chunked across flushes (never run as
one oversized batch) and reassembled per request.

``DiffusionServer`` is a thin sync facade over the async
``runtime.scheduler.ServeScheduler`` (the default, ``ServeConfig.scheduler
== "async"``): ``serve(list)`` submits every request, drains, and returns
the assembled responses — bit-identical to the legacy synchronous flush
loop, which survives as ``scheduler="sync"`` (and as the parity oracle in
tests/test_serve_scheduler.py).  The async path additionally exposes
``submit()``/``drain()`` for deadline-aware serving and per-request chunk
streaming (see the scheduler module docstring).

The pipeline owns the spec, the fused engine binding, and the PAS
coordinate table (~10 floats) — hot-swappable without touching model
weights (plug-and-play, paper §3.5).  Hot-swapping PAS params only
re-specialises the corrected prefix; the compiled plain path is untouched.

Mesh serving: ``ServeConfig.mesh`` (a ``repro.parallel.MeshSpec``) binds the
pipeline's engine to a (dp, state) device grid.  Flushes are padded to a
DP-divisible row count (pad rows are masked back out of every response), the
flush buffer is donated to the compiled scan, and ``stats["nfe_total"]``
counts the model evaluations *actually executed* — per padded row, chunked
flushes and pad waste included — so the counter is an honest cost meter.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import MeshSpec, Pipeline, SamplerSpec, ScheduleSpec
from repro.core import PASConfig, PASParams

from .scheduler import ServeHandle, ServeScheduler

__all__ = ["ServeConfig", "DiffusionServer", "Request"]


@dataclasses.dataclass
class Request:
    seed: int
    n_samples: int
    deadline_ms: Optional[float] = None   # per-request batching slack bound
    priority: Optional[str] = None        # "interactive" | "batch" (None =
                                          # ServeConfig.default_priority)
    pipeline: Optional[str] = None        # explicit lane key for the router


@dataclasses.dataclass
class ServeConfig:
    """What to serve (a full ``SamplerSpec``) and how to batch it.

    ``spec`` pins the sampler exactly; when ``None`` it is assembled from
    the scalar shortcut fields below (``nfe``/``solver``/``t_min``/``t_max``
    describe a default-rho polynomial schedule).  ``from_pipeline`` stores
    the pipeline's spec verbatim, so a ``raw``-points or non-default-rho
    schedule round-trips: ``cfg.to_spec() == pipeline.spec`` always.
    """

    nfe: int = 10
    solver: str = "ddim"
    t_min: float = 0.002
    t_max: float = 80.0
    max_batch: int = 256
    use_pas: bool = True
    pas: PASConfig = dataclasses.field(default_factory=PASConfig)
    mesh: MeshSpec = dataclasses.field(default_factory=MeshSpec)
    spec: Optional[SamplerSpec] = None
    scheduler: str = "async"              # "async" (ServeScheduler) | "sync"
    deadline_ms: Optional[float] = None   # default batching slack, ms
    max_in_flight: int = 2                # double-buffered flush depth
    # routing fields (the multi-pipeline PipelineRouter reads these; the
    # single-pipeline scheduler only uses default_priority for packing)
    default_priority: str = "batch"       # class for Request.priority=None
    route_by: str = "slack"               # "slack" | "explicit" lane routing
    slack_ms_per_eval: float = 1.0        # deadline-slack cost model, ms/eval
    # diffusion-mode backbone geometry (``launch/serve --mode diffusion``;
    # ``repro.models.eps.build_eps`` consumes these — the oracle mode and
    # the sampler spec ignore them)
    seq: int = 32                         # backbone sequence length
    model_seed: int = 0                   # backbone init seed

    def __post_init__(self):
        if self.scheduler not in ("async", "sync"):
            raise ValueError(
                f"scheduler must be 'async' or 'sync', got {self.scheduler!r}")
        if self.max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {self.max_in_flight}")
        from .scheduler import PRIORITIES
        if self.default_priority not in PRIORITIES:
            raise ValueError(
                f"default_priority must be one of {PRIORITIES}, got "
                f"{self.default_priority!r}")
        if self.route_by not in ("slack", "explicit"):
            raise ValueError(
                f"route_by must be 'slack' or 'explicit', got "
                f"{self.route_by!r}")
        if self.slack_ms_per_eval <= 0:
            raise ValueError(
                f"slack_ms_per_eval must be > 0, got {self.slack_ms_per_eval}")
        if self.seq < 1:
            raise ValueError(f"seq must be >= 1, got {self.seq}")

    def to_spec(self) -> SamplerSpec:
        """The declarative sampler description this config serves."""
        if self.spec is not None:
            return self.spec
        return SamplerSpec(
            solver=self.solver, nfe=self.nfe,
            schedule=ScheduleSpec(t_min=self.t_min, t_max=self.t_max),
            pas=self.pas, mesh=self.mesh)

    @classmethod
    def for_spec(cls, spec: SamplerSpec, **kw) -> "ServeConfig":
        """A config serving ``spec`` exactly (scalar fields kept in sync)."""
        return cls(nfe=spec.nfe, solver=spec.solver,
                   t_min=spec.schedule.t_min, t_max=spec.schedule.t_max,
                   pas=spec.pas, mesh=spec.mesh, spec=spec, **kw)


class DiffusionServer:
    def __init__(self, eps_fn: Callable, dim: int, cfg: ServeConfig,
                 pas_params: Optional[PASParams] = None,
                 pipeline: Optional[Pipeline] = None):
        self.cfg = cfg
        self.pipeline = (pipeline if pipeline is not None
                         else Pipeline.from_spec(cfg.to_spec(), eps_fn,
                                                 dim=dim))
        if pas_params is not None:
            self.pipeline.set_params(pas_params)
        # nfe_total = model evaluations actually executed, counted per padded
        # flush row: a flush of R rows on an engine whose trajectory costs E
        # evals (E = 2x steps for 2-eval teachers) adds R * E.  Chunked
        # flushes and DP pad rows are therefore included — the counter is the
        # true compute spent, not requests x nominal-NFE.
        self.stats = {"requests": 0, "samples": 0, "batches": 0,
                      "nfe_total": 0, "padded_samples": 0, "wall_s": 0.0}
        self._scheduler: Optional[ServeScheduler] = None

    @classmethod
    def from_pipeline(cls, pipeline: Pipeline,
                      cfg: Optional[ServeConfig] = None) -> "DiffusionServer":
        """Serve an existing (typically calibrated/loaded) pipeline.

        The derived config stores ``pipeline.spec`` itself, so schedules the
        scalar fields can't express (``raw`` points, non-default rho, custom
        dtype/teacher) survive the round trip: ``cfg.to_spec()`` is always
        ``== pipeline.spec``.
        """
        if cfg is None:
            cfg = ServeConfig.for_spec(pipeline.spec)
        return cls(pipeline.eps_fn, pipeline.dim, cfg, pipeline=pipeline)

    # -- pipeline delegation ------------------------------------------------

    @property
    def eps_fn(self):
        return self.pipeline.eps_fn

    @property
    def dim(self):
        return self.pipeline.dim

    @property
    def solver(self):
        return self.pipeline.solver

    @property
    def engine(self):
        return self.pipeline.engine

    @property
    def pas_params(self) -> Optional[PASParams]:
        return self.pipeline.params

    def set_pas(self, params: Optional[PASParams]) -> None:
        """Hot-swap the ~10 learned parameters (no model reload)."""
        self.pipeline.set_params(params)

    def _run_batch(self, x_t: jnp.ndarray):
        # the flush buffer is staged fresh per flush and never reused, so it
        # is donated to the compiled scan (free initial-state buffer); the
        # return value is the device future (JAX async dispatch) — sync
        # callers block via np.asarray, the scheduler defers the read.
        # Adaptive pipelines return (y, per-row evals) so the scheduler can
        # account the data-dependent NFE at retire time.
        if self.pipeline.is_adaptive:
            y, _, evals = self.pipeline.sample_async(
                x_t, use_pas=self.cfg.use_pas, donate_x=True, want_evals=True)
            return y, evals
        y, _ = self.pipeline.sample_async(x_t, use_pas=self.cfg.use_pas,
                                          donate_x=True)
        return y

    # -- async serving -------------------------------------------------------

    @property
    def scheduler(self) -> ServeScheduler:
        """The lazily started ``ServeScheduler`` (async serving surface)."""
        if self.cfg.scheduler != "async":
            raise RuntimeError(
                "submit()/drain() need ServeConfig(scheduler='async'); the "
                "sync flush loop has no request queue — use serve(list), or "
                "switch the config to the async scheduler")
        if self._scheduler is None:
            self._scheduler = ServeScheduler(
                self.pipeline, max_batch=self.cfg.max_batch,
                use_pas=self.cfg.use_pas,
                deadline_ms=self.cfg.deadline_ms,
                max_in_flight=self.cfg.max_in_flight,
                run_batch=lambda x_t: self._run_batch(x_t),
                stats=self.stats,
                default_priority=self.cfg.default_priority)
        return self._scheduler

    def submit(self, request: Request, **kw) -> ServeHandle:
        """Enqueue one request; stream its chunks via the returned handle."""
        return self.scheduler.submit(request, **kw)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Flush pending batches and land every in-flight flush."""
        if self._scheduler is not None:
            self._scheduler.drain(timeout)

    def close(self) -> None:
        """Stop the scheduler thread (started lazily; idempotent)."""
        if self._scheduler is not None:
            self._scheduler.close()
            self._scheduler = None

    # -- serving -------------------------------------------------------------

    def serve(self, requests: list[Request]) -> list[np.ndarray]:
        """Micro-batches requests; returns one array of samples per request.

        The sync facade: every request is submitted to the async scheduler,
        the queue is drained, and the assembled per-request responses come
        back in order — bit-identical to the legacy synchronous flush loop
        (``cfg.scheduler == "sync"`` runs that loop verbatim instead).

        Oversized requests (n_samples > max_batch) are split into
        max_batch-sized chunks across flushes; the final partial chunk stays
        pending so later requests can pack into the same batch.  Zero-sample
        requests complete immediately with an empty (0, dim) response.

        Under a DP mesh every flush is padded to a DP-divisible row count
        (prior rows repeated as ballast — always in-distribution for the
        model) and the pad rows are masked back out of the responses; they
        still show up in ``nfe_total``/``padded_samples`` because the
        devices really did burn those evals.
        """
        if self.cfg.scheduler == "sync":
            return self._serve_sync(requests)
        t0 = time.time()
        handles = [self.submit(req) for req in requests]
        self.drain()
        outs = [h.result() for h in handles]
        self.stats["wall_s"] += time.time() - t0
        return outs

    def _serve_sync(self, requests: list[Request]) -> list[np.ndarray]:
        """The legacy synchronous flush loop (the scheduler's parity oracle)."""
        parts: list[list[np.ndarray]] = [[] for _ in requests]
        pending: list[tuple[int, jnp.ndarray]] = []  # (request idx, x_T rows)
        sizes: list[int] = []
        t0 = time.time()
        mesh = self.pipeline.mesh_spec

        def flush():
            if not pending:
                return
            x_t = jnp.concatenate([x for _, x in pending], axis=0)
            n_rows = int(x_t.shape[0])
            x_t, pad = mesh.pad_rows(x_t)   # pad-and-mask, DP-divisible
            out = self._run_batch(x_t)
            y, evals = out if isinstance(out, tuple) else (out, None)
            x0 = np.asarray(y)
            off = 0
            for (i, _), n in zip(pending, sizes):
                parts[i].append(x0[off:off + n])
                off += n
            self.stats["batches"] += 1
            if evals is None:
                self.stats["nfe_total"] += (n_rows + pad) * self.engine.nfe
            else:
                # adaptive: count the evals actually executed per padded row
                self.stats["nfe_total"] += int(np.asarray(evals).sum())
            self.stats["padded_samples"] += pad
            pending.clear()
            sizes.clear()

        budget = self.cfg.max_batch
        for i, req in enumerate(requests):
            self.stats["requests"] += 1
            self.stats["samples"] += req.n_samples
            if req.n_samples == 0:
                continue         # answered with an empty (0, dim) response
            x_t = self.pipeline.prior(jax.random.key(req.seed), req.n_samples)
            if req.n_samples <= budget:
                if sum(sizes) + req.n_samples > budget:
                    flush()
                pending.append((i, x_t))
                sizes.append(req.n_samples)
            else:
                flush()
                for off in range(0, req.n_samples, budget):
                    chunk = x_t[off:off + budget]
                    pending.append((i, chunk))
                    sizes.append(int(chunk.shape[0]))
                    if sum(sizes) >= budget:
                        flush()
        flush()
        self.stats["wall_s"] += time.time() - t0
        empty = np.zeros((0, self.dim), np.dtype(self.pipeline.spec.dtype))
        return [p[0] if len(p) == 1 else
                (np.concatenate(p, axis=0) if p else empty)
                for p in parts]
