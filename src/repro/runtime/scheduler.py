"""Async continuous-batching serve scheduler (deadline-aware, double-buffered).

The synchronous flush loop in ``runtime/serve_loop.py`` packs a *list* of
requests into micro-batches and hands every result back at the end — fine
for throughput, blind to latency.  ``ServeScheduler`` replaces the loop's
control flow with a request queue and a background scheduler thread while
keeping the loop's *batch composition rules* bit-for-bit (the sync facade
``DiffusionServer.serve`` produces identical flushes, hence identical
samples — tests/test_serve_scheduler.py):

* **deadline-aware batch formation** — requests carry an optional deadline
  (``Request.deadline_ms`` or ``ServeConfig.deadline_ms``); a flush fires
  when the ``max_batch`` budget fills *or* the oldest pending request's
  slack expires, so a lone small request is never held hostage by an empty
  queue;
* **double-buffered flushes** — a flush dispatches the compiled scan and
  returns immediately (JAX async dispatch: the result is a device future);
  up to ``max_in_flight`` flushes stay in flight while the scheduler stages
  the next batch on the host (prior draws, concatenation, DP padding), so
  host staging overlaps device compute.  Every flush buffer is freshly
  staged before donation — a donated buffer is never one a previous
  in-flight flush still owns (the engine additionally refuses to donate an
  already-deleted buffer);
* **per-request streaming** — each submitted request gets a ``ServeHandle``;
  oversized requests (``n_samples > max_batch``) are chunked across flushes
  and every finished chunk is pushed to the handle as its flush retires, so
  a large request yields rows *before* its last chunk lands
  (``handle.chunks()``), while ``handle.result()`` blocks for the full
  response;
* **priority classes** — each request is ``interactive`` or ``batch``
  (``Request.priority``, defaulting to ``ServeConfig.default_priority``).
  When a flush forms, interactive chunks pack first and batch chunks
  backfill the remaining budget; within a class, admit order is preserved.
  A stream where every request shares one class therefore packs exactly
  like the PR-5 FIFO scheduler — bit-identical flushes (asserted in
  tests/test_router.py);
* **lanes (multi-pipeline flush selection)** — the scheduler core runs any
  number of *lanes*, each one ``(pipeline, max_batch budget, flush
  executor)``, behind the single submit queue with shared device ownership
  (one ``max_in_flight`` back-pressure window across all lanes, one
  scheduler thread).  ``ServeScheduler`` itself is the single-lane facade;
  ``runtime.router.PipelineRouter`` routes requests across a zoo of lanes
  by explicit spec key or deadline slack.

Stats ride the same dict the sync loop uses (``requests``/``samples``/
``batches``/``nfe_total``/``padded_samples``) plus per-trigger flush
counters (``flushes_budget``/``flushes_deadline``/``flushes_drain``), a
per-request latency trace under ``latency_s``, per-priority traces under
``latency_by_priority`` and per-lane flush counts under ``lane_batches``/
``lane_rows``.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np

__all__ = ["PRIORITIES", "ServeHandle", "ServeScheduler"]

Array = jax.Array

_UNSET = object()

#: Priority classes, highest first: ``interactive`` requests pre-empt
#: ``batch`` backfill when a flush forms.
PRIORITIES = ("interactive", "batch")


def _priority_rank(name: str) -> int:
    try:
        return PRIORITIES.index(name)
    except ValueError:
        raise ValueError(
            f"priority must be one of {PRIORITIES}, got {name!r}") from None


class ServeHandle:
    """One submitted request's future: stream chunks, or block for all rows.

    Rows arrive in request order, chunk by chunk, as the flushes carrying
    them retire.  ``chunks()`` is a single-consumer iterator that yields
    each ``(rows, dim)`` ndarray as it lands; ``result()`` blocks until the
    last chunk and returns the concatenated ``(n_samples, dim)`` array.  A
    scheduler-side failure re-raises from either.
    """

    _DONE = object()

    def __init__(self, n_samples: int, dim: int, dtype, submit_t: float,
                 priority: str = "batch", lane: str = "default"):
        self.n_samples = int(n_samples)
        self.submit_t = submit_t
        self.priority = priority
        self.lane = lane                  # which pipeline served this request
        self.complete_t: Optional[float] = None
        self._dim = dim
        self._dtype = np.dtype(dtype)
        self._remaining = self.n_samples
        self._parts: list[np.ndarray] = []
        self._stream: queue.SimpleQueue = queue.SimpleQueue()
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        if self.n_samples == 0:
            # zero-sample requests complete immediately: they never join a
            # flush (nothing to compute) and never leave a consumer hanging
            self._finish()

    # -- scheduler side ----------------------------------------------------

    def _push(self, rows: np.ndarray) -> None:
        self._parts.append(rows)
        self._stream.put(rows)
        self._remaining -= rows.shape[0]
        if self._remaining <= 0:
            self._finish()

    def _finish(self) -> None:
        self.complete_t = time.perf_counter()
        self._stream.put(self._DONE)
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        if self._done.is_set():            # completed/failed already: keep
            return                         # the first outcome
        self._error = exc
        self._stream.put(self._DONE)
        self._done.set()

    # -- consumer side -----------------------------------------------------

    def done(self) -> bool:
        return self._done.is_set()

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-last-chunk latency (None while incomplete)."""
        if self.complete_t is None:
            return None
        return self.complete_t - self.submit_t

    def chunks(self, timeout: Optional[float] = None) -> Iterator[np.ndarray]:
        """Yield finished chunks in row order as their flushes retire.

        ``timeout`` bounds the wait for each *next* chunk; expiry raises
        ``TimeoutError`` (matching ``result()``), not an internal queue
        exception.
        """
        while True:
            try:
                item = self._stream.get(timeout=timeout)
            except queue.Empty:
                raise TimeoutError(
                    f"no chunk within {timeout}s "
                    f"({self._remaining}/{self.n_samples} rows outstanding)"
                ) from None
            if item is self._DONE:
                if self._error is not None:
                    raise self._error
                return
            yield item

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until every chunk landed; returns (n_samples, dim) rows."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request incomplete after {timeout}s "
                f"({self._remaining}/{self.n_samples} rows outstanding)")
        if self._error is not None:
            raise self._error
        if len(self._parts) == 1:
            return self._parts[0]
        if not self._parts:
            return np.zeros((0, self._dim), self._dtype)
        return np.concatenate(self._parts, axis=0)


@dataclasses.dataclass
class _Chunk:
    """One request's rows (or a slice of an oversized request) in a batch."""
    handle: ServeHandle
    rows: Array
    n: int
    deadline: Optional[float]        # absolute perf_counter time, None = never
    priority: int = 1                # rank into PRIORITIES; lower packs first


@dataclasses.dataclass
class _Lane:
    """One pipeline behind the shared queue: its budget and pending chunks.

    ``run_batch`` is the lane's flush executor: it receives the fully
    staged (concatenated, DP-padded) flush buffer and must return the
    device result *without blocking* (``Pipeline.sample_async``).
    """
    key: str
    pipeline: object
    max_batch: int
    run_batch: Callable[[Array], Array]
    use_pas: bool = True             # what the default flush executor passes
    pending: list[_Chunk] = dataclasses.field(default_factory=list)
    pending_rows: int = 0

    def min_deadline(self) -> Optional[float]:
        return min((c.deadline for c in self.pending
                    if c.deadline is not None), default=None)


@dataclasses.dataclass
class _Flight:
    """A dispatched flush whose device result has not been read back yet."""
    y: Array                          # device future (JAX async dispatch)
    chunks: list[_Chunk]
    n_rows: int                       # real rows (pad excluded)
    evals: Optional[Array] = None     # per-row model evals (adaptive lanes)


class ServeScheduler:
    """Request queue + scheduler thread over one ``repro.api.Pipeline``.

    ``run_batch`` is the flush executor: it receives the fully staged
    (concatenated, DP-padded) flush buffer and must return the device
    result *without blocking* (``Pipeline.sample_async`` / the server's
    ``_run_batch``).  ``DiffusionServer`` passes a late-bound hook so its
    existing ``_run_batch`` monkeypatch surface keeps working.

    The internals are lane-based (see the module docstring): this class is
    the single-lane facade, ``runtime.router.PipelineRouter`` the
    multi-lane one.  Both share the thread, the submit queue, the
    priority-aware flush selection, and the in-flight window.
    """

    def __init__(self, pipeline, *, max_batch: int, use_pas: bool = True,
                 deadline_ms: Optional[float] = None, max_in_flight: int = 2,
                 run_batch: Optional[Callable[[Array], Array]] = None,
                 stats: Optional[dict] = None,
                 default_priority: str = "batch"):
        self.pipeline = pipeline
        self.max_batch = int(max_batch)
        lane = _Lane(key="default", pipeline=pipeline,
                     max_batch=self.max_batch,
                     run_batch=(run_batch if run_batch is not None
                                else self._default_run_batch(pipeline,
                                                             use_pas)),
                     use_pas=use_pas)
        self._init_core([lane], deadline_ms=deadline_ms,
                        max_in_flight=max_in_flight, stats=stats,
                        default_priority=default_priority)

    def _init_core(self, lanes: list[_Lane], *, deadline_ms, max_in_flight,
                   stats, default_priority) -> None:
        """Shared constructor tail: stats, queue, and the scheduler thread."""
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got {max_in_flight}")
        if not lanes:
            raise ValueError("scheduler needs at least one lane")
        if len({ln.key for ln in lanes}) != len(lanes):
            raise ValueError(
                f"duplicate lane keys: {[ln.key for ln in lanes]}")
        _priority_rank(default_priority)     # validate early
        self._lanes: dict[str, _Lane] = {ln.key: ln for ln in lanes}
        self.default_deadline_ms = deadline_ms
        self.default_priority = default_priority
        self.max_in_flight = int(max_in_flight)
        self.stats = stats if stats is not None else {}
        for k in ("requests", "samples", "batches", "nfe_total",
                  "padded_samples", "flushes_budget", "flushes_deadline",
                  "flushes_drain"):
            self.stats.setdefault(k, 0)
        self.stats.setdefault("latency_s", [])
        self.stats.setdefault("latency_by_priority",
                              {p: [] for p in PRIORITIES})
        self.stats.setdefault("lane_batches", {ln.key: 0 for ln in lanes})
        self.stats.setdefault("lane_rows", {ln.key: 0 for ln in lanes})
        self._lock = threading.Lock()        # guards stats against readers
        self._queue: queue.SimpleQueue = queue.SimpleQueue()
        self._in_flight: collections.deque[_Flight] = collections.deque()
        self._closed = False
        self._thread = threading.Thread(
            target=self._run, name="serve-scheduler", daemon=True)
        self._thread.start()

    @staticmethod
    def _default_run_batch(pipeline, use_pas: bool) -> Callable[[Array], Array]:
        if getattr(pipeline, "is_adaptive", False):
            # adaptive lanes return (y, per-row evals): the scheduler defers
            # NFE accounting to retire time, when the actual counts are known
            def run(x_t: Array):
                y, _, evals = pipeline.sample_async(
                    x_t, use_pas=use_pas, donate_x=True, want_evals=True)
                return y, evals
            return run

        def run(x_t: Array) -> Array:
            y, _ = pipeline.sample_async(x_t, use_pas=use_pas, donate_x=True)
            return y
        return run

    # -- routing (overridden by PipelineRouter) ------------------------------

    def _route(self, request, pipeline_key: Optional[str],
               deadline_ms: Optional[float], priority: str) -> _Lane:
        """Pick the lane serving ``request``; the single-lane base accepts
        only its own key (or none)."""
        lane = next(iter(self._lanes.values()))
        if pipeline_key is not None and pipeline_key != lane.key:
            raise ValueError(
                f"unknown pipeline {pipeline_key!r}; this scheduler serves "
                f"only {lane.key!r} (use runtime.router.PipelineRouter for a "
                f"multi-pipeline zoo)")
        return lane

    # -- client API ----------------------------------------------------------

    def submit(self, request, deadline_ms=_UNSET, *,
               pipeline: Optional[str] = None,
               priority: Optional[str] = None) -> ServeHandle:
        """Enqueue one request; returns its ``ServeHandle`` immediately.

        ``deadline_ms`` bounds how long the request may wait for its batch
        to fill (per-call > ``request.deadline_ms`` > the scheduler
        default; ``None`` means it waits for the budget or a drain).
        ``priority`` resolves the same way (per-call > ``request.priority``
        > the scheduler default) and decides packing order when a flush
        forms; ``pipeline`` (per-call > ``request.pipeline``) pins the
        request to one lane by key instead of letting the router choose.
        """
        if self._closed:
            raise RuntimeError("scheduler is closed")
        if deadline_ms is _UNSET:
            deadline_ms = getattr(request, "deadline_ms", None)
            if deadline_ms is None:
                deadline_ms = self.default_deadline_ms
        if priority is None:
            priority = getattr(request, "priority", None)
            if priority is None:
                priority = self.default_priority
        rank = _priority_rank(priority)
        if pipeline is None:
            pipeline = getattr(request, "pipeline", None)
        lane = self._route(request, pipeline, deadline_ms, priority)
        now = time.perf_counter()
        handle = ServeHandle(request.n_samples, lane.pipeline.dim,
                             lane.pipeline.spec.dtype, submit_t=now,
                             priority=priority, lane=lane.key)
        with self._lock:
            self.stats["requests"] += 1
            self.stats["samples"] += handle.n_samples
        if handle.n_samples == 0:
            with self._lock:
                self.stats["latency_s"].append(0.0)
                self.stats["latency_by_priority"][priority].append(0.0)
            return handle                    # completed in the constructor
        deadline = None if deadline_ms is None else now + deadline_ms / 1e3
        self._queue.put(("req", lane, request, handle, deadline, rank))
        return handle

    def drain(self, timeout: Optional[float] = None) -> None:
        """Flush everything pending and retire every in-flight batch."""
        evt = threading.Event()
        self._queue.put(("drain", evt))
        if not evt.wait(timeout):
            raise TimeoutError(f"drain incomplete after {timeout}s")

    def close(self, timeout: Optional[float] = 60.0) -> None:
        """Drain, then stop the scheduler thread (idempotent)."""
        if self._closed:
            return
        self._closed = True
        self._queue.put(("stop", None))
        self._thread.join(timeout)

    # -- scheduler thread ----------------------------------------------------

    def _run(self) -> None:
        while True:
            try:
                item = self._poll()
            except BaseException as exc:                # noqa: BLE001
                self._abort(exc)
                continue
            if item is None:
                continue
            kind = item[0]
            try:
                if kind == "req":
                    self._admit(*item[1:])
                else:                                   # drain / stop
                    for lane in self._lanes.values():
                        self._flush(lane, "drain")
                    self._retire(block=True, drain=True)
            except BaseException as exc:                # noqa: BLE001
                self._abort(exc)
            finally:
                if kind == "drain":
                    # always release the waiter — a failed drain surfaces
                    # through the failed handles, never as a deadlock
                    item[1].set()
            if kind == "stop":
                return

    def _poll(self):
        """One queue read, sized to the most urgent thing we're waiting on."""
        self._retire(block=False)    # stream any flush the device finished
        try:
            # drain immediately available work first: requests that are
            # already queued must pack into the forming batch before an
            # expired deadline degrades it to a partial flush (matters after
            # a long first-flush compile, when every deadline looks expired)
            return self._queue.get_nowait()
        except queue.Empty:
            pass
        timeout = 0.05
        urgent: Optional[_Lane] = None
        urgent_d: Optional[float] = None
        any_pending = False
        for lane in self._lanes.values():
            if not lane.pending:
                continue
            any_pending = True
            d = lane.min_deadline()
            if d is not None and (urgent_d is None or d < urgent_d):
                urgent, urgent_d = lane, d
        if urgent is not None:
            wait = urgent_d - time.perf_counter()
            if wait <= 0:
                self._flush(urgent, "deadline")
                return None
            timeout = min(wait, timeout)
        elif not any_pending and self._in_flight:
            timeout = 0.005          # re-poll readiness of in-flight flushes
        try:
            return self._queue.get(timeout=timeout)
        except queue.Empty:
            return None

    def _admit(self, lane: _Lane, request, handle: ServeHandle,
               deadline: Optional[float], priority: int) -> None:
        """Stage a request's prior rows and pack them into pending chunks.

        Packing reproduces the sync loop's composition exactly when every
        request shares one priority class: a request within budget stays
        whole (the budget flush fires first when it would overflow); an
        oversized request is cut into budget-sized chunks, each flushing as
        it fills, with the final partial chunk left pending so later
        requests pack into the same batch.  Any failure fails this handle —
        a consumer blocked on it must never hang.
        """
        try:
            x_t = lane.pipeline.prior(jax.random.key(request.seed),
                                      handle.n_samples)
            budget = lane.max_batch
            for off in range(0, handle.n_samples, budget):
                rows = (x_t if handle.n_samples <= budget
                        else x_t[off:off + budget])
                lane.pending.append(_Chunk(handle, rows, int(rows.shape[0]),
                                           deadline, priority))
                lane.pending_rows += int(rows.shape[0])
                while lane.pending_rows >= budget:
                    self._flush(lane, "budget")
        except BaseException as exc:
            handle._fail(exc)              # no-op if a flush failed it first
            raise

    def _select(self, lane: _Lane) -> tuple[list[_Chunk], int]:
        """Pick the chunks forming this flush: interactive pre-empts batch.

        Chunks are ordered by (priority class, admit order) — interactive
        first, batch backfilling the remaining budget — and taken greedily
        until the first chunk that does not fit (never skipping past a
        blocked chunk, so composition is deterministic).  With a single
        priority class in play the order degenerates to admit order and the
        selection takes everything pending ≤ budget: exactly the PR-5 FIFO
        composition.
        """
        ordered = sorted(lane.pending, key=lambda c: c.priority)  # stable
        take: list[_Chunk] = []
        rows = 0
        for c in ordered:
            if rows + c.n > lane.max_batch:
                break
            take.append(c)
            rows += c.n
        return take, rows

    def _flush(self, lane: _Lane, reason: str) -> None:
        """Stage + dispatch one batch on ``lane``; never blocks on compute.

        A staging/dispatch failure fails every handle riding this flush
        (then re-raises for ``_abort``) — their consumers must never hang.
        """
        if not lane.pending:
            return
        chunks, n_rows = self._select(lane)
        taken = set(map(id, chunks))
        lane.pending = [c for c in lane.pending if id(c) not in taken]
        lane.pending_rows -= n_rows
        try:
            # host staging: concatenate + DP-pad into a fresh flush buffer
            # (the only buffer the executor may donate — in-flight flushes
            # each own their previously staged buffer, so donation never
            # aliases one).  Multi-chunk batches concatenate in numpy:
            # chunk compositions vary per flush, and an eager device
            # concatenate would XLA-compile every distinct composition on
            # this thread, stalling the queue for ~100ms apiece under mixed
            # load — host memcpy of staged rows costs microseconds and is
            # bit-identical
            x_t = (chunks[0].rows if len(chunks) == 1
                   else np.concatenate([np.asarray(c.rows) for c in chunks],
                                       axis=0))
            x_t, pad = lane.pipeline.mesh_spec.pad_rows(x_t)
            if len(self._in_flight) >= self.max_in_flight:
                self._retire(block=True)   # back-pressure: oldest flush lands
            out = lane.run_batch(x_t)      # async dispatch: returns the future
        except BaseException as exc:
            for c in chunks:
                c.handle._fail(exc)
            raise
        # adaptive lanes return (y, per-row evals); the per-row counts ride
        # the flight and land in nfe_total at retire time (the actual spend
        # is data-dependent and unknown at dispatch)
        y, evals = out if isinstance(out, tuple) else (out, None)
        self._in_flight.append(_Flight(y, chunks, n_rows, evals=evals))
        with self._lock:
            self.stats["batches"] += 1
            if evals is None:
                self.stats["nfe_total"] += ((n_rows + pad)
                                            * lane.pipeline.engine.nfe)
            self.stats["padded_samples"] += pad
            self.stats[f"flushes_{reason}"] += 1
            self.stats["lane_batches"][lane.key] += 1
            self.stats["lane_rows"][lane.key] += n_rows

    def _retire(self, block: bool, drain: bool = False) -> None:
        """Read back finished flushes and scatter rows to their handles."""
        while self._in_flight:
            fl = self._in_flight[0]
            # custom executors may return host arrays (no readiness probe):
            # anything without is_ready() is by definition already ready
            ready = getattr(fl.y, "is_ready", None)
            if not (block or ready is None or ready()):
                return
            self._in_flight.popleft()
            try:
                x0 = np.asarray(fl.y)                 # blocks on this flush
            except BaseException as exc:              # device-side failure
                for c in fl.chunks:
                    c.handle._fail(exc)
                raise
            if fl.evals is not None:
                # honest adaptive NFE: evals actually executed, pad rows
                # included (the device burned them regardless)
                with self._lock:
                    self.stats["nfe_total"] += int(np.asarray(fl.evals).sum())
            off = 0
            for c in fl.chunks:
                c.handle._push(x0[off:off + c.n])
                off += c.n
                if c.handle.done():
                    with self._lock:
                        self.stats["latency_s"].append(c.handle.latency_s)
                        self.stats["latency_by_priority"][
                            c.handle.priority].append(c.handle.latency_s)
            if not drain:                 # keep at most one blocking read
                block = False

    def _abort(self, exc: BaseException) -> None:
        """Fail every outstanding handle so no consumer blocks forever."""
        for lane in self._lanes.values():
            for c in lane.pending:
                c.handle._fail(exc)
            lane.pending = []
            lane.pending_rows = 0
        while self._in_flight:
            fl = self._in_flight.popleft()
            for c in fl.chunks:
                c.handle._fail(exc)
