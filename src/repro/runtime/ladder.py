"""NFELadder: one artifact family -> a deadline-graded rung of pipelines.

The adaptive-NFE serving story has two halves.  ``repro.engine.adaptive``
adapts the step count *inside* one sample via error control; this module
adapts it *across* requests: from ONE base ``SamplerSpec`` it derives a
ladder of fixed-grid rungs — several PAS-corrected low-NFE lanes plus an
uncorrected teacher-grade lane — and populates a ``PipelineRouter`` with
them, so deadline-slack routing picks the step count per request (tight
deadline -> few steps + PAS correction, slack -> teacher-grade NFE).

All rungs share the base spec's schedule family, dtype, teacher, PAS config
and mesh; only ``nfe`` (and, for the teacher rung, the solver) varies.  The
rungs therefore form a single *artifact family*: ``calibrate`` writes one
directory holding a per-rung ``PASArtifact`` plus a ``ladder.json``
manifest, and ``from_manifest`` rebuilds the identical ladder (and router)
from that directory alone.

    ladder = NFELadder(SamplerSpec(solver="ddim", nfe=10), nfes=(5, 8, 10))
    router = ladder.build_router(eps_fn, dim=D)
    ladder.calibrate(router, key=jax.random.key(0), artifact_dir=family_dir)
    router.submit(Request(seed=0, n_samples=4, deadline_ms=10))  # few steps
    router.submit(Request(seed=1, n_samples=64))                 # teacher
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Iterable, Optional

import jax

from repro.api.spec import SamplerSpec

__all__ = ["NFELadder"]

Array = jax.Array

#: Manifest filename inside the artifact-family directory.
MANIFEST = "ladder.json"
_MANIFEST_VERSION = 1

#: Router lane key for the uncorrected teacher-grade rung.
TEACHER_KEY = "teacher"


class NFELadder:
    """Derive (NFE, PAS-artifact) router lanes from one base spec.

    ``nfes`` lists the corrected rung step counts (ascending is
    conventional but not required — lane order follows the given order);
    each becomes a lane ``"nfe<n>"`` running ``base_spec.replace(nfe=n)``
    with PAS on.  ``teacher_rung=True`` appends a ``"teacher"`` lane
    running the base spec's own teacher solver/NFE with PAS off — the
    quality ceiling the cheap rungs were calibrated against.

    Any ``error_control`` on the base spec is stripped: ladder rungs are
    fixed grids by construction (the per-sample adaptive engine is the
    orthogonal half of adaptive NFE).
    """

    def __init__(self, base_spec: SamplerSpec, nfes: Iterable[int] = (5, 8, 10),
                 *, teacher_rung: bool = True):
        base = base_spec.replace(error_control=None)
        nfes = [int(n) for n in nfes]
        if not nfes:
            raise ValueError("NFELadder needs at least one rung NFE")
        if len(set(nfes)) != len(nfes):
            raise ValueError(f"duplicate rung NFEs: {nfes}")
        if any(n < 1 for n in nfes):
            raise ValueError(f"rung NFEs must be >= 1, got {nfes}")
        self.base_spec = base
        self.nfes = tuple(nfes)
        self.teacher_rung = bool(teacher_rung)
        self.specs: dict[str, SamplerSpec] = {
            f"nfe{n}": base.replace(nfe=n) for n in nfes}
        self.use_pas: dict[str, bool] = {k: True for k in self.specs}
        if teacher_rung:
            if TEACHER_KEY in self.specs:
                raise ValueError(f"rung key {TEACHER_KEY!r} is reserved")
            self.specs[TEACHER_KEY] = base.replace(
                solver=base.teacher.solver, nfe=base.teacher.nfe)
            self.use_pas[TEACHER_KEY] = False

    @property
    def keys(self) -> list[str]:
        return list(self.specs)

    # -- router construction -------------------------------------------------

    def build_router(self, eps_fn, dim: int, *, cfg=None,
                     artifact_dir=None, use_pas=None, **kw):
        """A ``PipelineRouter`` with one lane per rung.

        With ``artifact_dir``, rungs whose ``<dir>/<key>/`` holds a matching
        ``PASArtifact`` load their calibrated floats (``from_specs``
        semantics); others serve uncorrected until ``calibrate``.
        ``use_pas`` (bool or per-key mapping) overrides the ladder's own
        per-rung map — ``False`` serves every rung uncorrected.
        """
        from .router import PipelineRouter
        if use_pas is None:
            use_pas = dict(self.use_pas)
        return PipelineRouter.from_specs(
            self.specs, eps_fn, dim, artifact_dir=artifact_dir, cfg=cfg,
            use_pas=use_pas, **kw)

    def precompile(self, router, batches: Optional[Iterable[int]] = None, *,
                   calibration: bool = False, cache=None,
                   model_key: Optional[str] = None) -> dict:
        """Warm every rung lane of ``router`` before admitting traffic.

        Thin delegation to ``PipelineRouter.precompile`` — each rung's
        exact flush variant (its DP-padded ``max_batch`` bucket plus any
        extra ``batches``, the rung's ``use_pas`` setting) is AOT-compiled
        on the caller's thread; ``calibration=True`` also warms the PAS
        rungs' calibration programs for calibrate-on-launch fleets.
        """
        return router.precompile(batches, calibration=calibration,
                                 cache=cache, model_key=model_key)

    def calibrate(self, router, key: Array, batch: int = 256,
                  artifact_dir=None, *,
                  shared_teacher: bool = True) -> "NFELadder":
        """Calibrate every PAS rung lane of ``router`` (teacher rung skipped
        — it serves uncorrected) and persist the artifact family.

        ``shared_teacher=True`` (the default) routes all uncalibrated PAS
        rungs through ``repro.engine.zoo``: since every rung shares the base
        spec's schedule family, ONE teacher trajectory on the
        lcm-of-rung-NFEs grid serves the whole ladder and every rung's
        Algorithm 1 runs in one compiled program — a model drop recalibrates
        the full ladder for roughly the cost of one spec (the zoo ledger
        lands in each rung's ``diag["zoo"]``).  ``shared_teacher=False``
        (or a non-polynomial schedule) falls back to per-rung calibration.

        With ``artifact_dir``, each calibrated rung saves its
        ``PASArtifact`` under ``<dir>/<key>/`` and the ladder manifest is
        written alongside, making the directory a self-contained family:
        ``NFELadder.from_manifest(dir)`` + ``build_router(...,
        artifact_dir=dir)`` rebuilds the calibrated router.
        """
        todo = [name for name in self.keys
                if self.use_pas[name] and not router.pipelines[name].calibrated]
        zoo_keys = (todo if shared_teacher and len(todo) > 1
                    and self.base_spec.schedule.kind == "polynomial" else [])
        if zoo_keys:
            from repro.engine.zoo import ZooCalibrationEngine
            zoo = ZooCalibrationEngine(
                {name: router.pipelines[name].spec for name in zoo_keys})
            first = router.pipelines[zoo_keys[0]]
            results = zoo.calibrate(first.eps_fn, first.prior(key, batch))
            for name in zoo_keys:
                params, diag = results[name]
                router.pipelines[name].set_params(params, diag)
        for name in self.keys:
            if not self.use_pas[name]:
                continue
            pipe = router.pipelines[name]
            if not pipe.calibrated:
                pipe.calibrate(key=key, batch=batch)
            if artifact_dir is not None:
                pipe.save(Path(artifact_dir) / name)
        if artifact_dir is not None:
            self.save_manifest(artifact_dir)
        return self

    # -- persistence ---------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "version": _MANIFEST_VERSION,
            "base_spec": self.base_spec.to_dict(),
            "nfes": list(self.nfes),
            "teacher_rung": self.teacher_rung,
            "rungs": {k: {"use_pas": self.use_pas[k]} for k in self.keys},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "NFELadder":
        if d.get("version") != _MANIFEST_VERSION:
            raise ValueError(
                f"unsupported ladder manifest version {d.get('version')!r}")
        return cls(SamplerSpec.from_dict(d["base_spec"]), d["nfes"],
                   teacher_rung=d["teacher_rung"])

    def save_manifest(self, artifact_dir) -> Path:
        path = Path(artifact_dir)
        path.mkdir(parents=True, exist_ok=True)
        out = path / MANIFEST
        out.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True))
        return out

    @classmethod
    def from_manifest(cls, artifact_dir) -> "NFELadder":
        path = Path(artifact_dir) / MANIFEST
        return cls.from_dict(json.loads(path.read_text()))

    def __repr__(self) -> str:
        rungs = ", ".join(self.keys)
        return (f"NFELadder({self.base_spec.solver} family, rungs=[{rungs}])")
