"""Multi-pipeline SLA router: one queue, many samplers, shared devices.

PAS's product promise is a *zoo* of cheap calibrated samplers — each
``(solver, NFE)`` spec carries its own ~10-float artifact (paper §3.5), so
one deployment can hold a teacher-grade pipeline next to several corrected
low-NFE ones at near-zero marginal cost.  ``PipelineRouter`` turns that
into a serving feature (the USF "solver searching" framing as
infrastructure): a single submit queue routes every request onto one lane
of a pipeline zoo, the lanes share the device (one scheduler thread, one
``max_in_flight`` back-pressure window), and each lane keeps its own batch
budget so a cheap interactive sampler is never starved by a bulk lane's
backlog.

Routing, per request:

* **explicit** — ``Request.pipeline`` (or ``submit(pipeline=...)``) names a
  lane key directly;
* **deadline slack** (``route_by="slack"``, default) — a request with a
  tight deadline lands on the cheapest lane whose estimated cost fits the
  slack (tight deadline ⇒ low-NFE PAS pipeline); a request with no
  deadline, or slack enough for anything, gets the most expensive
  (teacher-grade) lane.  The cost model is deliberately simple and
  deterministic: ``pipeline.evals_per_sample * cfg.slack_ms_per_eval``,
  where ``evals_per_sample`` counts *total model evals per sample* — a
  two-eval solver at N steps prices as 2N, an adaptive lane as its compiled
  worst case ``2 * max_iters`` (the slack router must guarantee the
  deadline, so it prices the bound, not the optimistic mean).

Priorities ride the underlying scheduler: ``interactive`` chunks pack ahead
of ``batch`` backfill when any lane's flush forms (see
``runtime/scheduler.py``), and per-class latency traces land in
``stats["latency_by_priority"]`` — the curves ``benchmarks/serve_router.py``
records under Poisson/trace load.

    router = PipelineRouter({"fast": fast_pipe, "hq": hq_pipe},
                            budgets={"fast": 32, "hq": 256})
    h = router.submit(Request(seed=0, n_samples=4, deadline_ms=25,
                              priority="interactive"))   # -> "fast" lane
    router.submit(Request(seed=1, n_samples=256))        # -> "hq" lane
    router.drain()

A single-lane router with one priority class packs exactly like the PR-5
FIFO scheduler — bit-identical flushes (tests/test_router.py).
"""
from __future__ import annotations

from typing import Callable, Iterable, Mapping, Optional, Union

import jax

from .scheduler import ServeScheduler, _Lane
from .serve_loop import ServeConfig

__all__ = ["PipelineRouter"]

Array = jax.Array

PipelineZoo = Union[Mapping[str, object], Iterable[tuple[str, object]]]


class PipelineRouter(ServeScheduler):
    """One submit queue over a zoo of ``Pipeline`` lanes with shared devices.

    ``pipelines`` maps lane key -> ``repro.api.Pipeline`` (insertion order
    is the drain/flush order).  ``budgets`` overrides the per-lane
    ``max_batch`` (default ``cfg.max_batch`` for every lane); ``use_pas``
    may be a bool or a per-key mapping.  Everything else — deadlines,
    priorities, in-flight depth, routing policy — comes from the
    ``ServeConfig`` (its ``nfe``/``solver`` scalar fields are ignored here:
    each lane's pipeline already pins its own spec).
    """

    def __init__(self, pipelines: PipelineZoo, *,
                 cfg: Optional[ServeConfig] = None,
                 budgets: Optional[Mapping[str, int]] = None,
                 use_pas: Union[bool, Mapping[str, bool]] = True,
                 run_batch: Optional[Callable[[str, Array], Array]] = None,
                 stats: Optional[dict] = None):
        cfg = cfg if cfg is not None else ServeConfig()
        self.cfg = cfg
        items = (list(pipelines.items()) if isinstance(pipelines, Mapping)
                 else list(pipelines))
        if not items:
            raise ValueError("PipelineRouter needs at least one pipeline")
        budgets = dict(budgets or {})
        lanes = []
        for key, pipe in items:
            pas = use_pas if isinstance(use_pas, bool) else use_pas.get(key,
                                                                        True)
            budget = int(budgets.pop(key, cfg.max_batch))
            if budget < 1:
                raise ValueError(f"lane {key!r} budget must be >= 1, "
                                 f"got {budget}")
            runner = (self._default_run_batch(pipe, pas) if run_batch is None
                      else _bind_lane_runner(run_batch, key))
            lanes.append(_Lane(key=str(key), pipeline=pipe, max_batch=budget,
                               run_batch=runner, use_pas=pas))
        if budgets:
            raise ValueError(
                f"budgets for unknown lanes: {sorted(budgets)} "
                f"(zoo: {[ln.key for ln in lanes]})")
        # slack routing ranks lanes by compute cost (total model evals per
        # row); ties keep zoo order so routing stays deterministic
        self._by_cost = sorted(
            lanes, key=lambda ln: (_lane_evals(ln), ln.key))
        self.pipeline = lanes[0].pipeline    # base-class compat: "a" pipeline
        self.max_batch = lanes[0].max_batch
        self._init_core(lanes, deadline_ms=cfg.deadline_ms,
                        max_in_flight=cfg.max_in_flight, stats=stats,
                        default_priority=cfg.default_priority)

    # -- construction helpers ------------------------------------------------

    @classmethod
    def from_specs(cls, specs, eps_fn, dim: int, *,
                   keys: Optional[Iterable[str]] = None,
                   artifact_dir=None, **kw) -> "PipelineRouter":
        """Build the zoo from ``SamplerSpec``s against one eps model.

        ``specs`` is a list of specs (lane keys default to
        ``"{solver}@{nfe}"``) or a mapping key -> spec.  With
        ``artifact_dir``, each lane whose ``<artifact_dir>/<key>/``
        contains a matching ``PASArtifact`` loads its calibrated ~10
        floats (specs are compared modulo placement, like
        ``Pipeline.load``); lanes without one serve uncorrected until
        ``.calibrate_all`` or a later ``set_params``.
        """
        from pathlib import Path

        from repro.api.artifact import PASArtifact
        from repro.api.pipeline import Pipeline

        if isinstance(specs, Mapping):
            items = list(specs.items())
        else:
            specs = list(specs)
            if keys is None:
                keys = [f"{s.solver}@{s.nfe}" for s in specs]
            items = list(zip(keys, specs))
        if len({k for k, _ in items}) != len(items):
            raise ValueError(f"duplicate lane keys: {[k for k, _ in items]}")
        zoo = {}
        for key, spec in items:
            lane_dir = Path(artifact_dir) / key if artifact_dir else None
            if lane_dir is not None and PASArtifact.exists(lane_dir):
                zoo[key] = Pipeline.load(lane_dir, eps_fn, dim=dim,
                                         expected_spec=spec, mesh=spec.mesh)
            else:
                zoo[key] = Pipeline.from_spec(spec, eps_fn, dim=dim)
        return cls(zoo, **kw)

    def calibrate_all(self, key: Array, batch: int = 256,
                      artifact_dir=None) -> "PipelineRouter":
        """Calibrate every uncalibrated lane (and persist per-lane artifacts
        under ``<artifact_dir>/<lane_key>/`` when a directory is given)."""
        from pathlib import Path
        for name, pipe in self.pipelines.items():
            if not pipe.calibrated:
                pipe.calibrate(key=key, batch=batch)
            if artifact_dir is not None:
                pipe.save(Path(artifact_dir) / name)
        return self

    # -- fleet pre-warming ---------------------------------------------------

    def precompile(self, batches: Optional[Iterable[int]] = None, *,
                   calibration: bool = False, cache=None,
                   model_key: Optional[str] = None) -> dict:
        """Warm every lane's flush program before the queue admits traffic.

        For each lane this AOT-compiles the exact (batch-bucket, dtype,
        mesh) variant its flush executor dispatches — ``donate_x=True``,
        the lane's ``use_pas`` setting, the adaptive engine for adaptive
        lanes — at the lane's DP-padded ``max_batch`` budget, plus any
        extra ``batches`` buckets (for deployments whose deadline flushes
        routinely fire below budget).  Runs on the *caller's* thread: the
        scheduler thread keeps servicing its (empty) queue, and once this
        returns the first real flush dispatches a warm program instead of
        stalling the lane on an ~8s first-flush compile.

        ``calibration=True`` also warms each lane's calibration programs
        (for fleets that calibrate on launch); ``cache``/``model_key``
        feed the persistent compile cache so later processes skip the
        compile entirely.  Returns {lane: {batch: report}}.
        """
        extra = [int(b) for b in (batches or [])]
        report: dict = {}
        for key, lane in self._lanes.items():
            lane_rep = {}
            for b in dict.fromkeys([lane.max_batch, *extra]):
                lane_rep[b] = lane.pipeline.precompile(
                    b, use_pas=lane.use_pas, donate_x=True,
                    calibration=calibration, cache=cache,
                    model_key=model_key)
            report[key] = lane_rep
        return report

    # -- introspection -------------------------------------------------------

    @property
    def pipelines(self) -> dict[str, object]:
        """Lane key -> ``Pipeline``, in zoo order."""
        return {k: ln.pipeline for k, ln in self._lanes.items()}

    @property
    def lane_keys(self) -> list[str]:
        return list(self._lanes)

    def lane_cost_ms(self, key: str) -> float:
        """The slack router's estimated per-row cost for one lane.

        Priced in total model evals per sample (``Pipeline.evals_per_sample``
        — 2N for a two-eval solver at N steps, the compiled ``2 * max_iters``
        worst case for an adaptive lane), times the config's ms/eval.
        """
        return _lane_evals(self._lanes[key]) * self.cfg.slack_ms_per_eval

    # -- routing -------------------------------------------------------------

    def _route(self, request, pipeline_key: Optional[str],
               deadline_ms: Optional[float], priority: str) -> _Lane:
        if pipeline_key is not None:
            try:
                return self._lanes[pipeline_key]
            except KeyError:
                raise ValueError(
                    f"unknown pipeline {pipeline_key!r}; zoo: "
                    f"{self.lane_keys}") from None
        if self.cfg.route_by == "explicit":
            raise ValueError(
                "route_by='explicit' requires Request.pipeline (or "
                f"submit(pipeline=...)); zoo: {self.lane_keys}")
        # deadline-slack routing: the most expensive lane whose estimated
        # cost fits the request's slack; no deadline means teacher-grade
        if deadline_ms is None:
            return self._by_cost[-1]
        for lane in reversed(self._by_cost):
            if (_lane_evals(lane) * self.cfg.slack_ms_per_eval
                    <= deadline_ms):
                return lane
        return self._by_cost[0]              # nothing fits: cheapest lane

    def serve(self, requests: list) -> list:
        """Sync convenience: submit everything, drain, results in order."""
        handles = [self.submit(r) for r in requests]
        self.drain()
        return [h.result() for h in handles]


def _lane_evals(lane: _Lane) -> int:
    """Total model evals one sample costs on this lane (the routing unit).

    ``Pipeline.evals_per_sample`` when available; bare-engine fallbacks
    (tests passing minimal pipeline doubles) use ``engine.nfe``, which
    already counts evals rather than steps.
    """
    pipe = lane.pipeline
    evals = getattr(pipe, "evals_per_sample", None)
    return int(evals if evals is not None else pipe.engine.nfe)


def _bind_lane_runner(run_batch: Callable[[str, Array], Array],
                      key: str) -> Callable[[Array], Array]:
    return lambda x_t: run_batch(key, x_t)
