"""Trace-driven load: arrival processes for the serving stack.

The continuous-batching payoff (PR 5) and the router's SLA story (PR 6)
only show under *staggered* arrivals — a benchmark that submits its whole
stream up-front measures throughput, never latency-under-load.  This module
generates reproducible request arrival schedules:

* ``poisson_arrivals`` — a seeded Poisson process at a given offered load
  (requests/s), with a configurable interactive/batch priority mix: the
  interactive class draws small sizes and a tight deadline, the batch class
  large sizes and a loose one — the deadline is what the router's slack
  policy routes on;
* ``load_trace``/``save_trace`` — the same schedule as a CSV
  (``t_ms,seed,n_samples,priority,deadline_ms,pipeline``) so recorded
  production traces replay byte-for-byte;
* ``replay`` — walls-clock playback: sleeps to each arrival instant and
  submits through any ``submit(request)`` callable (``DiffusionServer`` or
  ``PipelineRouter``), returning ``(arrival, handle)`` pairs for latency
  accounting.

Everything is host-side and jax-free; determinism comes from
``numpy.random.default_rng(seed)``.
"""
from __future__ import annotations

import csv
import dataclasses
import time
from pathlib import Path
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

__all__ = ["Arrival", "load_trace", "poisson_arrivals", "replay",
           "save_trace"]


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: when it arrives and what it asks for."""

    t_s: float                            # offset from stream start, seconds
    seed: int
    n_samples: int
    priority: str = "batch"
    deadline_ms: Optional[float] = None
    pipeline: Optional[str] = None        # explicit lane key (router only)

    def request(self):
        """The ``repro.api.Request`` this arrival submits."""
        from .serve_loop import Request
        return Request(seed=self.seed, n_samples=self.n_samples,
                       deadline_ms=self.deadline_ms, priority=self.priority,
                       pipeline=self.pipeline)


def poisson_arrivals(rate_rps: float, duration_s: float, *, seed: int = 0,
                     interactive_fraction: float = 0.5,
                     interactive_sizes: Sequence[int] = (1, 2, 4, 8),
                     batch_sizes: Sequence[int] = (16, 32, 64),
                     interactive_deadline_ms: Optional[float] = 25.0,
                     batch_deadline_ms: Optional[float] = 250.0,
                     ) -> list[Arrival]:
    """A seeded Poisson arrival schedule at ``rate_rps`` offered load.

    Inter-arrival gaps are exponential(1/rate); each arrival flips a
    (seeded) coin for its priority class and draws a size from that class's
    palette.  Request seeds are the arrival index, so the *sample values*
    of a schedule are stable across rates — only timing and mix change.
    """
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    if not 0.0 <= interactive_fraction <= 1.0:
        raise ValueError(f"interactive_fraction must be in [0, 1], got "
                         f"{interactive_fraction}")
    rng = np.random.default_rng(seed)
    out: list[Arrival] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate_rps))
        if t >= duration_s:
            return out
        if rng.random() < interactive_fraction:
            prio, sizes, ddl = ("interactive", interactive_sizes,
                                interactive_deadline_ms)
        else:
            prio, sizes, ddl = "batch", batch_sizes, batch_deadline_ms
        out.append(Arrival(t_s=t, seed=len(out),
                           n_samples=int(sizes[rng.integers(len(sizes))]),
                           priority=prio, deadline_ms=ddl))


_FIELDS = ("t_ms", "seed", "n_samples", "priority", "deadline_ms", "pipeline")


def save_trace(path, arrivals: Iterable[Arrival]) -> Path:
    """Write a schedule as CSV (the format ``load_trace`` reads back)."""
    path = Path(path)
    with path.open("w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(_FIELDS)
        for a in arrivals:
            w.writerow([f"{1e3 * a.t_s:.3f}", a.seed, a.n_samples,
                        a.priority,
                        "" if a.deadline_ms is None else a.deadline_ms,
                        a.pipeline or ""])
    return path


def load_trace(path) -> list[Arrival]:
    """Parse a CSV trace (header optional; '#' lines are comments)."""
    out: list[Arrival] = []
    with Path(path).open(newline="") as fh:
        for row in csv.reader(fh):
            if not row or row[0].lstrip().startswith("#"):
                continue
            if row[0].strip() == "t_ms":            # header
                continue
            row = [c.strip() for c in row] + [""] * (len(_FIELDS) - len(row))
            out.append(Arrival(
                t_s=float(row[0]) / 1e3, seed=int(row[1]),
                n_samples=int(row[2]), priority=row[3] or "batch",
                deadline_ms=float(row[4]) if row[4] else None,
                pipeline=row[5] or None))
    return sorted(out, key=lambda a: a.t_s)


def replay(arrivals: Iterable[Arrival], submit: Callable, *,
           speed: float = 1.0) -> list[tuple[Arrival, object]]:
    """Play a schedule against a submit callable in (scaled) wall time.

    Sleeps to each arrival instant (``speed > 1`` compresses the clock) and
    calls ``submit(arrival.request())``; returns ``(arrival, handle)``
    pairs in arrival order.  The caller drains afterwards — handles carry
    their own submit-to-completion latency.
    """
    if speed <= 0:
        raise ValueError(f"speed must be > 0, got {speed}")
    schedule = sorted(arrivals, key=lambda a: a.t_s)
    out = []
    t0 = time.perf_counter()
    for a in schedule:
        wait = a.t_s / speed - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(wait)
        out.append((a, submit(a.request())))
    return out
