"""Fault-tolerant training loop: resume-from-latest, periodic async
checkpoints, straggler monitoring, graceful shutdown, JSONL metrics.

Designed for 1000+-node operation (DESIGN.md §5): every mechanism below is
the single-process analogue of the multi-host behaviour — checkpoint/restore
is mesh-elastic, data order is (seed, step)-deterministic so restarts replay
identically, and the straggler monitor is the per-host step-deadline watchdog
that a real deployment wires to its control plane.
"""
from __future__ import annotations

import dataclasses
import json
import signal
import time
from pathlib import Path
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro import checkpoint as ckpt

__all__ = ["TrainLoopConfig", "StragglerMonitor", "run_train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep_ckpts: int = 3
    log_every: int = 10
    metrics_path: Optional[str] = None
    straggler_factor: float = 3.0     # deadline = factor * EMA(step time)
    straggler_warmup: int = 5


class StragglerMonitor:
    """Step-time EMA + deadline watchdog.

    On real fleets this triggers the control-plane action (re-shard the data
    of the slow host, or preemptively restart it); here it records the event
    and the loop re-seeds its iterator — the recovery path is exercised, the
    hardware alert is a log line.
    """

    def __init__(self, factor: float, warmup: int):
        self.factor = factor
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.events: list[dict] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        tripped = False
        if self.ema is not None and self.n > self.warmup \
                and dt > self.factor * self.ema:
            tripped = True
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        alpha = 0.2
        self.ema = dt if self.ema is None else (1 - alpha) * self.ema + alpha * dt
        return tripped


def run_train_loop(
    step_fn: Callable,                   # (params, opt_state, batch) -> (p, o, metrics)
    params: Any,
    opt_state: Any,
    batches: Iterator[dict],
    cfg: TrainLoopConfig,
    shardings: Optional[tuple] = None,   # (param_shardings, opt_shardings)
) -> tuple[Any, Any, dict]:
    """Returns (params, opt_state, summary).  Resumes from cfg.ckpt_dir."""
    ckpt_dir = Path(cfg.ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    start_step = 0
    state = {"params": params, "opt": opt_state}
    latest = ckpt.latest_step(ckpt_dir)
    if latest is not None:
        sh = None
        if shardings is not None:
            sh = {"params": shardings[0], "opt": shardings[1]}
        state, extra = ckpt.restore(ckpt_dir, state, shardings=sh)
        start_step = int(extra.get("next_step", latest))

    stop = {"flag": False}

    def _sigterm(signum, frame):   # graceful preemption: final checkpoint
        stop["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _sigterm)
        except ValueError:         # non-main thread (tests)
            pass

    monitor = StragglerMonitor(cfg.straggler_factor, cfg.straggler_warmup)
    metrics_f = open(cfg.metrics_path, "a") if cfg.metrics_path else None
    pending_save = None
    history: list[dict] = []

    params, opt_state = state["params"], state["opt"]
    it = iter(batches)
    # deterministic replay: skip the stream to the resume point
    for _ in range(start_step):
        next(it)

    step = start_step
    try:
        for step in range(start_step, cfg.total_steps):
            if stop["flag"]:
                break
            batch = next(it)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics.get("ce_loss", metrics))
            dt = time.time() - t0
            straggled = monitor.observe(step, dt)

            if step % cfg.log_every == 0 or straggled:
                row = {"step": step, "dt": round(dt, 4),
                       "straggler": straggled,
                       **{k: float(np.asarray(v)) for k, v in metrics.items()
                          if np.ndim(v) == 0}}
                history.append(row)
                if metrics_f:
                    metrics_f.write(json.dumps(row) + "\n")
                    metrics_f.flush()

            if (step + 1) % cfg.ckpt_every == 0:
                if pending_save is not None:
                    pending_save.result()          # backpressure
                pending_save = ckpt.save_async(
                    ckpt_dir, step + 1,
                    {"params": params, "opt": opt_state},
                    extra={"next_step": step + 1})
    finally:
        if pending_save is not None:
            pending_save.result()
        # final (or preemption) checkpoint
        ckpt.save(ckpt_dir, step + 1, {"params": params, "opt": opt_state},
                  extra={"next_step": step + 1})
        ckpt.cleanup(ckpt_dir, keep=cfg.keep_ckpts)
        if metrics_f:
            metrics_f.close()
        for sig, h in old_handlers.items():
            signal.signal(sig, h)

    summary = {"final_step": step + 1, "resumed_from": start_step,
               "straggler_events": monitor.events, "history": history,
               "preempted": stop["flag"]}
    return params, opt_state, summary
