from .scheduler import ServeHandle, ServeScheduler
from .serve_loop import DiffusionServer, Request, ServeConfig
from .train_loop import StragglerMonitor, TrainLoopConfig, run_train_loop

__all__ = ["DiffusionServer", "Request", "ServeConfig", "ServeHandle",
           "ServeScheduler", "StragglerMonitor", "TrainLoopConfig",
           "run_train_loop"]
