from .ladder import NFELadder
from .router import PipelineRouter
from .scheduler import PRIORITIES, ServeHandle, ServeScheduler
from .serve_loop import DiffusionServer, Request, ServeConfig
from .traffic import (Arrival, load_trace, poisson_arrivals, replay,
                      save_trace)
from .train_loop import StragglerMonitor, TrainLoopConfig, run_train_loop

__all__ = ["Arrival", "DiffusionServer", "NFELadder", "PRIORITIES",
           "PipelineRouter",
           "Request", "ServeConfig", "ServeHandle", "ServeScheduler",
           "StragglerMonitor", "TrainLoopConfig", "load_trace",
           "poisson_arrivals", "replay", "run_train_loop", "save_trace"]
