"""Fused PAS-corrected linear-multistep update as a Pallas TPU kernel.

Every 1-NFE solver the paper corrects reduces (core/solvers.py) to

    x_{j+1} = alpha[j] * x_j + beta[j, 0] * native_0 + sum_m beta[j, m] * hist_m

and PAS (core/pas.py) replaces the current direction with d~ = U^T (C * s)
before the native-space mapping.  The seed path materialised d~, the native
direction, and each multiply-add as separate XLA ops with an HBM round-trip
between the projection and the update; these kernels do the whole step in one
pass over VMEM-resident tiles of the flattened state.

Three kernels, one coefficient layout:

* ``fused_step``      — the plain multistep update (inactive PAS steps, and
  every step of an uncorrected sampler).
* ``fused_pas_step``  — folds the PAS coordinate application (d~ = sum_k
  cs[b, k] * u[b, k, :]) and the native-space mapping into the same tile pass,
  emitting (x_next, d~, native) so the history/Q pushes reuse the tile.
* ``fused_pas_project_step`` — the weight-space variant: instead of a
  materialised (B, n_basis, D) basis it takes the projected coordinates
  pw = cs @ W (B, R+1) (``pca.basis_weights``) and contracts them directly
  against the Q-buffer rows + current direction in the same tile pass, so a
  corrected step streams the state exactly once and the basis never exists
  in HBM.

Coefficient rows are packed ``[alpha, beta_0 .. beta_{K-1}, t]`` (length K+2,
see engine/engine.py) so one (N, K+2) table drives the whole trajectory scan.
The D axis is tiled into ``block_d`` lanes; batch rides whole in each block
(B is the microbatch, D the flattened sample dim — the huge axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

__all__ = ["fused_step", "fused_pas_step", "fused_pas_project_step"]

_DEF_BLOCK_D = 1024


def _step_kernel(coef_ref, x_ref, nat_ref, hist_ref, o_ref, *, k: int):
    a = coef_ref[0, 0]
    out = a * x_ref[...] + coef_ref[0, 1] * nat_ref[...]
    for m in range(1, k):
        out = out + coef_ref[0, 1 + m] * hist_ref[m - 1]
    o_ref[...] = out


def _pas_step_kernel(coef_ref, x_ref, u_ref, cs_ref, hist_ref,
                     x_out, d_out, nat_out, *, k: int, native_x0: bool):
    x = x_ref[...]
    cs = cs_ref[...]                                   # (B, n_basis)
    u = u_ref[...]                                     # (B, n_basis, blk)
    d = jnp.sum(cs[:, :, None] * u, axis=1)            # d~ tile
    if native_x0:
        nat = x - coef_ref[0, k + 1] * d               # t is the last slot
    else:
        nat = d
    out = coef_ref[0, 0] * x + coef_ref[0, 1] * nat
    for m in range(1, k):
        out = out + coef_ref[0, 1 + m] * hist_ref[m - 1]
    x_out[...] = out
    d_out[...] = d
    nat_out[...] = nat


def _pas_project_step_kernel(coef_ref, x_ref, q_ref, d_ref, pw_ref, hist_ref,
                             x_out, d_out, nat_out, *, k: int,
                             native_x0: bool):
    x = x_ref[...]                                     # (B, blk)
    d = d_ref[...]                                     # (B, blk)
    pw = pw_ref[...]                                   # (B, R+1)
    q = q_ref[...]                                     # (R, B, blk)
    # d~ tile = sum_r pw[:, r] * q[r] + pw[:, -1] * d — contraction over the
    # R+1 buffer rows, batched over B, elementwise along the tile
    d_tilde = jax.lax.dot_general(
        pw[:, :-1], q, (((1,), (0,)), ((0,), (1,))),
        preferred_element_type=x.dtype) + pw[:, -1:] * d
    if native_x0:
        nat = x - coef_ref[0, k + 1] * d_tilde         # t is the last slot
    else:
        nat = d_tilde
    out = coef_ref[0, 0] * x + coef_ref[0, 1] * nat
    for m in range(1, k):
        out = out + coef_ref[0, 1 + m] * hist_ref[m - 1]
    x_out[...] = out
    d_out[...] = d_tilde
    nat_out[...] = nat


def _pad_d(x: Array, block_d: int) -> tuple[Array, int]:
    d = x.shape[-1]
    pad = (-d) % block_d
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)
    return x, d


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_step(x: Array, nat: Array, hist: Array, coef: Array, *,
               block_d: int = _DEF_BLOCK_D, interpret: bool = False) -> Array:
    """x, nat (B, D); hist (H, B, D); coef (K+2,) -> x_next (B, D)."""
    k = coef.shape[0] - 2
    b = x.shape[0]
    h = hist.shape[0]
    x_p, d = _pad_d(x, block_d)
    nat_p, _ = _pad_d(nat, block_d)
    hist_p, _ = _pad_d(hist, block_d)
    n_blocks = x_p.shape[-1] // block_d

    out = pl.pallas_call(
        functools.partial(_step_kernel, k=k),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, k + 2), lambda i: (0, 0)),
            pl.BlockSpec((b, block_d), lambda i: (0, i)),
            pl.BlockSpec((b, block_d), lambda i: (0, i)),
            pl.BlockSpec((h, b, block_d), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((b, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct(x_p.shape, x.dtype),
        interpret=interpret,
    )(coef.astype(x.dtype)[None], x_p, nat_p, hist_p)
    return out[..., :d]


@functools.partial(jax.jit,
                   static_argnames=("native_x0", "block_d", "interpret"))
def fused_pas_step(x: Array, u: Array, cs: Array, hist: Array, coef: Array, *,
                   native_x0: bool = False, block_d: int = _DEF_BLOCK_D,
                   interpret: bool = False) -> tuple[Array, Array, Array]:
    """PAS-corrected step in one pass.

    x (B, D); u (B, n_basis, D) orthonormal basis; cs (B, n_basis) coordinates
    pre-scaled by the per-sample norm (coord_mode folding happens upstream);
    hist (H, B, D); coef (K+2,).  Returns (x_next, d_tilde, native).
    """
    k = coef.shape[0] - 2
    b, n_basis, _ = u.shape
    h = hist.shape[0]
    x_p, d = _pad_d(x, block_d)
    u_p, _ = _pad_d(u, block_d)
    hist_p, _ = _pad_d(hist, block_d)
    n_blocks = x_p.shape[-1] // block_d

    shape = jax.ShapeDtypeStruct(x_p.shape, x.dtype)
    x_next, d_tilde, nat = pl.pallas_call(
        functools.partial(_pas_step_kernel, k=k, native_x0=native_x0),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, k + 2), lambda i: (0, 0)),
            pl.BlockSpec((b, block_d), lambda i: (0, i)),
            pl.BlockSpec((b, n_basis, block_d), lambda i: (0, 0, i)),
            pl.BlockSpec((b, n_basis), lambda i: (0, 0)),
            pl.BlockSpec((h, b, block_d), lambda i: (0, 0, i)),
        ],
        out_specs=[pl.BlockSpec((b, block_d), lambda i: (0, i))] * 3,
        out_shape=[shape, shape, shape],
        interpret=interpret,
    )(coef.astype(x.dtype)[None], x_p, u_p, cs.astype(x.dtype), hist_p)
    return x_next[..., :d], d_tilde[..., :d], nat[..., :d]


@functools.partial(jax.jit,
                   static_argnames=("native_x0", "block_d", "interpret"))
def fused_pas_project_step(x: Array, q_rows: Array, d: Array, pw: Array,
                           hist: Array, coef: Array, *,
                           native_x0: bool = False,
                           block_d: int = _DEF_BLOCK_D,
                           interpret: bool = False
                           ) -> tuple[Array, Array, Array]:
    """Weight-space PAS step: projection against the raw Q rows, fused.

    x, d (B, D); q_rows (R, B, D) the engine's Q-buffer carry (unmasked —
    ``pw`` columns of invalid rows are zero by ``basis_weights`` contract);
    pw (B, R+1) = cs @ W projected coordinates; hist (H, B, D); coef (K+2,).
    Returns (x_next, d_tilde, native).  Compared to ``fused_pas_step`` this
    drops the (B, n_basis, D) materialised-basis input entirely: the tile
    pass reads x, q_rows, d, hist once and writes the three outputs once.
    """
    k = coef.shape[0] - 2
    b = x.shape[0]
    r = q_rows.shape[0]
    h = hist.shape[0]
    x_p, dim = _pad_d(x, block_d)
    q_p, _ = _pad_d(q_rows, block_d)
    d_p, _ = _pad_d(d, block_d)
    hist_p, _ = _pad_d(hist, block_d)
    n_blocks = x_p.shape[-1] // block_d

    shape = jax.ShapeDtypeStruct(x_p.shape, x.dtype)
    x_next, d_tilde, nat = pl.pallas_call(
        functools.partial(_pas_project_step_kernel, k=k, native_x0=native_x0),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, k + 2), lambda i: (0, 0)),
            pl.BlockSpec((b, block_d), lambda i: (0, i)),
            pl.BlockSpec((r, b, block_d), lambda i: (0, 0, i)),
            pl.BlockSpec((b, block_d), lambda i: (0, i)),
            pl.BlockSpec((b, r + 1), lambda i: (0, 0)),
            pl.BlockSpec((h, b, block_d), lambda i: (0, 0, i)),
        ],
        out_specs=[pl.BlockSpec((b, block_d), lambda i: (0, i))] * 3,
        out_shape=[shape, shape, shape],
        interpret=interpret,
    )(coef.astype(x.dtype)[None], x_p, q_p, d_p, pw.astype(x.dtype), hist_p)
    return x_next[..., :dim], d_tilde[..., :dim], nat[..., :dim]
