"""PAS Gram kernels (X X^T over a huge feature axis) as Pallas TPU kernels.

The PAS buffer is (n, D) with n ~ 12 and D huge (the flattened, possibly
device-local sample dimension).  Both kernels tile D into VMEM-sized chunks
and accumulate the tiny f32 Gram product across the sequential grid axis —
one pass over the rows, no transposed re-read (vs. the naive X @ X.T which
reads X twice with a transposed layout).  Masked rows are zeroed on the fly.

Tail handling: a D that does not divide ``block_d`` is *not* padded host-side
(the seed version materialised a full padded copy of the buffer per call) —
the final grid block masks its out-of-range lanes in-kernel, so any
``block_d`` is legal for any D and the input is never copied.

* ``gram``     — single-buffer Gram (n, D) -> (n, n); the ``psum_gram``
  building block of the sharded PAS path.
* ``gram_qd``  — the corrected-step Gram: per-sample Xp = [Q * mask; d] from
  the engine's (R, B, D) Q-buffer carry + (B, D) direction, -> (B, R+1, R+1).
  This is the only reduction over D a corrected step performs; on a mesh the
  caller psums its ~1 KB output and every downstream stage stays local.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_DEF_BLOCK_D = 2048


def _masked_tile(x: Array, i, block_d: int, d_total: int) -> Array:
    """Zero the lanes of tile ``i`` that fall past the true D extent.

    Out-of-range lanes of a partial final block hold unspecified values
    (Pallas does not zero-fill), so ``where`` — not multiplication, which
    would keep a NaN a NaN — is required.
    """
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    return jnp.where(col + i * block_d < d_total, x, 0.0)


def _gram_kernel(x_ref, mask_ref, o_ref, *, block_d: int, d_total: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)           # (n, block_d)
    x = x * mask_ref[...].astype(jnp.float32)[:, None]
    x = _masked_tile(x, i, block_d, d_total)
    partial = jax.lax.dot_general(x, x, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _first():
        o_ref[...] = partial

    @pl.when(i > 0)
    def _rest():
        o_ref[...] = o_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gram(x: Array, mask: Array | None = None, *, block_d: int = _DEF_BLOCK_D,
         interpret: bool = False) -> Array:
    """x (n, D) [+ mask (n,)] -> X X^T (n, n) in float32."""
    n, d = x.shape
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    n_blocks = pl.cdiv(d, block_d)

    return pl.pallas_call(
        functools.partial(_gram_kernel, block_d=block_d, d_total=d),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x, mask.astype(jnp.float32))


def _gram_qd_kernel(q_ref, mask_ref, d_ref, o_ref, *,
                    block_d: int, d_total: int):
    i = pl.program_id(1)
    q = q_ref[...][:, 0, :].astype(jnp.float32)       # (R, block_d)
    q = q * mask_ref[...].astype(jnp.float32)[:, None]
    dv = d_ref[...].astype(jnp.float32)               # (1, block_d)
    xp = jnp.concatenate([q, dv], axis=0)             # (R+1, block_d)
    xp = _masked_tile(xp, i, block_d, d_total)
    partial = jax.lax.dot_general(xp, xp, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _first():
        o_ref[0] = partial

    @pl.when(i > 0)
    def _rest():
        o_ref[0] = o_ref[0] + partial


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gram_qd(q_rows: Array, q_mask: Array, d: Array, *,
            block_d: int = _DEF_BLOCK_D, interpret: bool = False) -> Array:
    """Corrected-step Gram: (R, B, D) rows + (B, D) direction -> (B, R+1, R+1).

    Grid is (B, D-blocks) with the block axis minor, so each sample's tiles
    accumulate sequentially into its (R+1, R+1) output while the row/d tiles
    stream through VMEM exactly once.
    """
    r, b, dim = q_rows.shape
    n_blocks = pl.cdiv(dim, block_d)

    return pl.pallas_call(
        functools.partial(_gram_qd_kernel, block_d=block_d, d_total=dim),
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((r, 1, block_d), lambda j, i: (0, j, i)),
            pl.BlockSpec((r,), lambda j, i: (0,)),
            pl.BlockSpec((1, block_d), lambda j, i: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, r + 1, r + 1), lambda j, i: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r + 1, r + 1), jnp.float32),
        interpret=interpret,
    )(q_rows, q_mask.astype(jnp.float32), d)
