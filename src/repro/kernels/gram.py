"""PAS Gram matrix (X X^T) as a Pallas TPU kernel.

The PAS buffer is (n, D) with n ~ 12 and D huge (the flattened, possibly
device-local sample dimension).  The kernel tiles D into VMEM-sized chunks
and accumulates the (n x n) f32 product across the sequential grid axis —
one pass over X, no transposed re-read (vs. the naive X @ X.T which reads X
twice with a transposed layout).  Masked rows are zeroed on the fly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _gram_kernel(x_ref, mask_ref, o_ref, *, n_blocks: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)           # (n, block_d)
    x = x * mask_ref[...].astype(jnp.float32)[:, None]
    partial = jax.lax.dot_general(x, x, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _first():
        o_ref[...] = partial

    @pl.when(i > 0)
    def _rest():
        o_ref[...] = o_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gram(x: Array, mask: Array | None = None, *, block_d: int = 2048,
         interpret: bool = False) -> Array:
    """x (n, D) [+ mask (n,)] -> X X^T (n, n) in float32."""
    n, d = x.shape
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    pad = (-d) % block_d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    n_blocks = x.shape[1] // block_d

    return pl.pallas_call(
        functools.partial(_gram_kernel, n_blocks=n_blocks),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x, mask.astype(jnp.float32))
