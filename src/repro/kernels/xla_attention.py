"""Chunked online-softmax attention in pure XLA (the non-TPU ops path).

Statically chunks queries (python loop — chunk indices are compile-time) and
scans KV chunks with an online-softmax carry, so:
  * peak memory is O(B * H * q_chunk * kv_chunk) instead of O(S * T);
  * causal / sliding-window chunks OUTSIDE the reachable KV range are never
    emitted at all — compiled FLOPs reflect the real sub-quadratic structure
    (mixtral SWA, gemma3 local layers), keeping the roofline honest;
  * GQA is an einsum reshape, never a materialised repeat.

Numerically identical (up to fp assoc.) to kernels/ref.attention — tested.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array
_NEG_INF = -1e30


def _chunk_attend(q_blk, k_all, v_all, *, q_pos0, kv_lo, n_kv, kv_chunk,
                  causal, window, cap, t_real):
    """q_blk (B,KV,G,cq,Dh); scan n_kv chunks starting at kv_lo."""
    b, kv, g, cq, dh = q_blk.shape
    acc0 = jnp.zeros((b, kv, g, cq, dh), jnp.float32)
    m0 = jnp.full((b, kv, g, cq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, cq), jnp.float32)

    def body(carry, ki):
        acc, m, l = carry
        start = kv_lo + ki * kv_chunk
        k_blk = jax.lax.dynamic_slice_in_dim(k_all, start, kv_chunk, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v_all, start, kv_chunk, axis=1)
        s = jnp.einsum("bkgqd,btkd->bkgqt", q_blk, k_blk,
                       preferred_element_type=jnp.float32)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        q_pos = q_pos0 + jax.lax.broadcasted_iota(jnp.int32, (cq, kv_chunk), 0)
        k_pos = start + jax.lax.broadcasted_iota(jnp.int32, (cq, kv_chunk), 1)
        mask = k_pos < t_real
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l), None

    (acc, _, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(n_kv))
    return acc / jnp.where(l > 0, l, 1.0)[..., None]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "logits_soft_cap", "scale",
                              "q_chunk", "kv_chunk"))
def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: int | None = None, logits_soft_cap: float | None = None,
              scale: float | None = None, q_chunk: int = 2048,
              kv_chunk: int = 2048) -> Array:
    """Same contract as kernels/ref.attention."""
    b, s, h, dh = q.shape
    _, t, kv, _ = k.shape
    assert h % kv == 0
    g = h // kv
    scale = scale if scale is not None else dh ** -0.5
    offset = t - s  # right-aligned query positions

    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    pad_q = (-s) % q_chunk
    pad_t = (-t) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0))) if pad_t else k
    vp = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0))) if pad_t else v
    s_pad, t_pad = qp.shape[1], kp.shape[1]

    # (B, S, H, Dh) -> (B, KV, G, S, Dh) grouped query layout
    qg = (qp.reshape(b, s_pad, kv, g, dh).transpose(0, 2, 3, 1, 4)
          * jnp.asarray(scale, q.dtype))

    outs = []
    for qi in range(s_pad // q_chunk):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=3)
        q_lo_pos = offset + qi * q_chunk
        q_hi_pos = q_lo_pos + q_chunk - 1
        # statically reachable KV range for this q chunk
        hi = min(q_hi_pos + 1, t_pad) if causal else t_pad
        lo = 0
        if window is not None:
            lo = max(0, q_lo_pos - window + 1)
        lo = (lo // kv_chunk) * kv_chunk
        hi = -(-max(hi, lo + 1) // kv_chunk) * kv_chunk
        hi = min(hi, t_pad)
        n_kv = max((hi - lo) // kv_chunk, 1)
        out = _chunk_attend(q_blk, kp, vp, q_pos0=q_lo_pos, kv_lo=lo,
                            n_kv=n_kv, kv_chunk=kv_chunk, causal=causal,
                            window=window, cap=logits_soft_cap, t_real=t)
        outs.append(out)

    og = jnp.concatenate(outs, axis=3)[:, :, :, :s]       # (B,KV,G,S,Dh) f32
    return og.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh).astype(q.dtype)
