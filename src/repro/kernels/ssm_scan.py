"""Mamba selective scan as a Pallas TPU kernel.

Grid: (B, Di/block_d, L/block_t) with time innermost/sequential; the
(block_d, N) f32 state is carried in VMEM scratch across time blocks, so HBM
traffic is exactly one read of (u, delta, B, C) and one write of y — the
recurrence itself never touches HBM (the property that makes Mamba fast on
real hardware; XLA's associative_scan materialises O(L log L) intermediates).

Channel blocks are parallel: the state is diagonal in Di (A is (Di, N)), so
each block owns its slice of the recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _ssm_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_out_ref,
                h_ref, *, block_t: int, n_t_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                     # (bd, N)
    d = d_ref[...].astype(jnp.float32)                     # (bd,)

    def step(tt, h):
        dt_row = dt_ref[0, tt].astype(jnp.float32)         # (bd,)
        u_row = u_ref[0, tt].astype(jnp.float32)           # (bd,)
        b_row = b_ref[0, tt].astype(jnp.float32)           # (N,)
        c_row = c_ref[0, tt].astype(jnp.float32)           # (N,)
        decay = jnp.exp(dt_row[:, None] * a)               # (bd, N)
        h = decay * h + (dt_row * u_row)[:, None] * b_row[None, :]
        y_row = jnp.sum(h * c_row[None, :], axis=-1) + d * u_row
        y_ref[0, tt] = y_row.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[...])
    h_ref[...] = h

    @pl.when(it == n_t_blocks - 1)
    def _emit_state():
        h_out_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "block_t", "interpret"))
def ssm_scan(u: Array, delta: Array, a: Array, b: Array, c: Array,
             d: Array | None = None, h0: Array | None = None, *,
             block_d: int = 128, block_t: int = 256,
             interpret: bool = False) -> tuple[Array, Array]:
    """See kernels/ref.ssm_scan for the contract. h0 must be None (TPU path
    integrates prefill-from-scratch; decode steps don't use the kernel)."""
    if h0 is not None:
        raise NotImplementedError("kernel path covers prefill (h0=None)")
    bsz, ell, di = u.shape
    n = a.shape[-1]
    block_d = min(block_d, di)
    block_t = min(block_t, ell)
    assert di % block_d == 0, (di, block_d)
    pad_t = (-ell) % block_t
    if pad_t:
        # zero delta on padding -> decay=1, drive=0: state passes through
        u = jnp.pad(u, ((0, 0), (0, pad_t), (0, 0)))
        delta = jnp.pad(delta, ((0, 0), (0, pad_t), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_t), (0, 0)))
    ell_p = u.shape[1]
    nd, nt = di // block_d, ell_p // block_t
    if d is None:
        d = jnp.zeros((di,), jnp.float32)

    y, h_last = pl.pallas_call(
        functools.partial(_ssm_kernel, block_t=block_t, n_t_blocks=nt),
        grid=(bsz, nd, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda ib, id_, it: (ib, it, id_)),
            pl.BlockSpec((1, block_t, block_d), lambda ib, id_, it: (ib, it, id_)),
            pl.BlockSpec((block_d, n), lambda ib, id_, it: (id_, 0)),
            pl.BlockSpec((1, block_t, n), lambda ib, id_, it: (ib, it, 0)),
            pl.BlockSpec((1, block_t, n), lambda ib, id_, it: (ib, it, 0)),
            pl.BlockSpec((block_d,), lambda ib, id_, it: (id_,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda ib, id_, it: (ib, it, id_)),
            pl.BlockSpec((1, block_d, n), lambda ib, id_, it: (ib, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, ell_p, di), u.dtype),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(u, delta, a, b, c, d)
    return y[:, :ell], h_last
