"""Pure-jnp oracles for every Pallas kernel (the correctness reference).

These are also the implementations the CPU-hosted dry-run lowers (pallas TPU
custom-calls cannot compile for the host platform); XLA fuses them well enough
that the roofline FLOPs/bytes are representative.  Shapes follow the ops.py
contracts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = ["attention", "fused_pas_project_step", "fused_pas_step",
           "fused_step", "gram", "gram_qd", "rmsnorm", "ssm_scan"]

_NEG_INF = -1e30


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: int | None = None, logits_soft_cap: float | None = None,
              scale: float | None = None) -> Array:
    """Multi-head attention with GQA broadcast and optional sliding window.

    q: (B, S, H, Dh); k, v: (B, T, KV, Dh) with H % KV == 0.  Returns
    (B, S, H, Dh).  ``window=w`` keeps keys with q_pos - w < k_pos <= q_pos
    (sliding window, causal implied within the window when causal=True).
    Softmax is computed in float32 regardless of input dtype.
    """
    b, s, h, dh = q.shape
    _, t, kv, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = scale if scale is not None else dh ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # fold GQA: (B, T, KV, Dh) -> broadcast to (B, T, H, Dh) without copy cost
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)

    logits = jnp.einsum("bshd,bthd->bhst", qf, kf)
    if logits_soft_cap is not None:
        logits = logits_soft_cap * jnp.tanh(logits / logits_soft_cap)

    q_pos = jnp.arange(s)[:, None] + (t - s)  # right-aligned (prefill: t == s)
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, _NEG_INF)

    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vf)
    return out.astype(q.dtype)


def fused_step(x: Array, nat: Array, hist: Array, coef: Array) -> Array:
    """Linear-multistep update x_next = a*x + b0*nat + sum_m b_m*hist[m-1].

    x, nat: (B, D); hist: (H, B, D); coef: (K+2,) packed
    [alpha, beta_0..beta_{K-1}, t].  Accumulation order matches
    ``LinearMultistepSolver.phi`` so the engine is bit-compatible with the
    seed sampling path in float32.
    """
    k = coef.shape[0] - 2
    out = coef[0] * x + coef[1] * nat
    for m in range(1, k):
        out = out + coef[1 + m] * hist[m - 1]
    return out


def fused_pas_step(x: Array, u: Array, cs: Array, hist: Array, coef: Array,
                   *, native_x0: bool = False) -> tuple[Array, Array, Array]:
    """PAS projection + native mapping + multistep update in one fused graph.

    u: (B, n_basis, D) orthonormal basis rows; cs: (B, n_basis) coordinates
    already scaled by the per-sample norm.  Returns (x_next, d_tilde, native).
    """
    d_tilde = jnp.einsum("bk,bkd->bd", cs, u)
    nat = x - coef[-1] * d_tilde if native_x0 else d_tilde
    return fused_step(x, nat, hist, coef), d_tilde, nat


def gram(x: Array, mask: Array | None = None) -> Array:
    """G = X X^T in float32. x: (n, D); mask: (n,) row validity."""
    xf = x.astype(jnp.float32)
    if mask is not None:
        xf = xf * mask[:, None].astype(jnp.float32)
    return xf @ xf.T


def gram_qd(q_rows: Array, q_mask: Array, d: Array) -> Array:
    """Per-sample Gram of the PAS projection rows Xp = [Q * mask; d].

    q_rows: (R, B, D) Q-buffer row storage (batch axis second — the engine
    carry layout); q_mask: (R,) row validity; d: (B, D) current direction.
    Returns (B, R+1, R+1) float32 — the one reduction over D a corrected
    step performs (on a state-sharded mesh the caller psums this tiny
    output; everything downstream of it is local).
    """
    qf = q_rows.astype(jnp.float32) * q_mask.astype(jnp.float32)[:, None, None]
    xp = jnp.concatenate([qf, d.astype(jnp.float32)[None]], axis=0)
    return jnp.einsum("rbd,sbd->brs", xp, xp)


def fused_pas_project_step(x: Array, q_rows: Array, d: Array, pw: Array,
                           hist: Array, coef: Array, *,
                           native_x0: bool = False
                           ) -> tuple[Array, Array, Array]:
    """Weight-space PAS projection + native mapping + multistep update, fused.

    ``pw`` (B, R+1) are the projected coordinates cs @ W (``pca.basis_weights``
    folded against the learned coordinates), so the corrected direction is
    d~_b = sum_r pw[b, r] * Xp_r — one contraction over the R+1 buffer rows,
    elementwise along D (shardable with zero collectives).  ``pw`` columns of
    invalid buffer rows must be zero (basis_weights' mask folding guarantees
    it), so q_rows is consumed *unmasked*.  Returns (x_next, d_tilde, native).
    """
    pwx = pw.astype(x.dtype)
    d_tilde = jnp.einsum("br,rbd->bd", pwx[:, :-1], q_rows) + pwx[:, -1:] * d
    nat = x - coef[-1] * d_tilde if native_x0 else d_tilde
    return fused_step(x, nat, hist, coef), d_tilde, nat


def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    """RMS normalisation over the last axis, computed in float32."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def ssm_scan(u: Array, delta: Array, a: Array, b: Array, c: Array,
             d: Array | None = None, h0: Array | None = None
             ) -> tuple[Array, Array]:
    """Mamba-1 selective scan (the SSM recurrence), float32 state.

    u, delta: (B, L, Di); a: (Di, N) (A = -exp(a) convention handled by
    caller — this oracle takes the *continuous* A directly); b, c: (B, L, N);
    d: (Di,) skip weight; h0: (B, Di, N) initial state.

      h_t = exp(delta_t * A) * h_{t-1} + delta_t * B_t * u_t
      y_t = (C_t . h_t) + D * u_t

    Returns (y (B, L, Di), h_last (B, Di, N)).  Implemented with an
    associative scan over L (parallel-friendly oracle).
    """
    bsz, ell, di = u.shape
    uf = u.astype(jnp.float32)
    dt = delta.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    # decay (B, L, Di, N) and input drive
    decay = jnp.exp(dt[..., None] * af[None, None])
    drive = dt[..., None] * bf[:, :, None, :] * uf[..., None]

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by

    if h0 is not None:
        drive = drive.at[:, 0].add(decay[:, 0] * h0.astype(jnp.float32))
    _, h = jax.lax.associative_scan(combine, (decay, drive), axis=1)
    y = jnp.einsum("bldn,bln->bld", h, cf)
    if d is not None:
        y = y + d.astype(jnp.float32)[None, None] * uf
    return y.astype(u.dtype), h[:, -1]
