"""Fused RMSNorm Pallas kernel: one VMEM pass (mean-square + scale) per row
block instead of XLA's separate reduce + broadcast-multiply HBM round-trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (block_r, E)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    y = y * (1.0 + s_ref[...].astype(jnp.float32))[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: Array, scale: Array, eps: float = 1e-6, *,
            block_rows: int = 256, interpret: bool = False) -> Array:
    """x (..., E), scale (E,) -> rmsnorm(x) * (1 + scale), dtype-preserving."""
    orig_shape = x.shape
    e = orig_shape[-1]
    xr = x.reshape(-1, e)
    r = xr.shape[0]
    block_rows = min(block_rows, r)
    pad = (-r) % block_rows
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    nb = xr.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, e), lambda i: (i, 0)),
            pl.BlockSpec((e,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, scale)
    return out[:r].reshape(orig_shape)
