"""Public kernel entry points: dispatch between Pallas TPU kernels and oracles.

Models call these, never the kernels directly.  Dispatch policy:
  * TPU backend -> Pallas kernel (pl.pallas_call with VMEM BlockSpecs);
  * CPU/GPU (this container, and the 512-virtual-device dry-run) -> ref.py;
  * ``interpret=True`` forces the Pallas kernel body in interpret mode
    (how the kernel tests run on CPU);
  * env ``REPRO_FORCE_PALLAS=1`` / ``REPRO_DISABLE_PALLAS=1`` override.

shard_map contract (mesh-native sampling, ``core.distributed`` /
``engine``): every op here may be called from inside a ``shard_map`` body,
where it sees *per-device shard* shapes instead of global ones.  That is
safe because dispatch is backend-keyed (host-side, trace-time — never on
array values) and every kernel treats its tiled axes independently: callers
shard only axes the kernels never reduce over (batch, and the D tiling
axis), so a shard is just a smaller instance of the same shape contract.
Kernels that DO reduce (``gram`` / ``gram_qd`` over D) are composed with an
explicit ``lax.psum`` by the caller (``distributed.psum_gram`` /
``batched_pas_weights_sharded``) — the kernel itself stays local.  On TPU
the per-device shard must still satisfy the kernel's tile minimums; size
meshes so D_local keeps the lane dim >= 128.

Differentiation contract: these ops are *forward-only* — the Pallas kernels
carry no custom VJPs.  Callers that differentiate (the CalibrationEngine's
SGD inner scan) must build their loss from the pure-jnp formulation
(``solvers.LinearMultistepSolver.phi`` / ``kernels.ref``) and reserve these
entry points for forward rollouts; that is how ``engine/calibration.py``
composes them.
"""
from __future__ import annotations

import os

import jax

from . import ref

Array = jax.Array

__all__ = ["flash_attention", "fused_pas_project_step", "fused_pas_step",
           "fused_step", "gram", "gram_qd", "rmsnorm", "ssm_scan",
           "use_pallas"]


def use_pallas() -> bool:
    if os.environ.get("REPRO_DISABLE_PALLAS"):
        return False
    if os.environ.get("REPRO_FORCE_PALLAS"):
        return True
    return jax.default_backend() == "tpu"


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None,
                    logits_soft_cap: float | None = None,
                    scale: float | None = None,
                    interpret: bool = False) -> Array:
    """Tiled online-softmax attention (see kernels/flash_attention.py)."""
    if interpret or use_pallas():
        from . import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  logits_soft_cap=logits_soft_cap, scale=scale,
                                  interpret=interpret or not use_pallas())
    if q.shape[1] > 2048 or k.shape[1] > 2048:
        # chunked online-softmax path: O(chunk^2) memory, static block
        # skipping for causal/window — keeps dry-run memory + FLOPs honest
        from . import xla_attention
        return xla_attention.attention(q, k, v, causal=causal, window=window,
                                       logits_soft_cap=logits_soft_cap,
                                       scale=scale)
    return ref.attention(q, k, v, causal=causal, window=window,
                         logits_soft_cap=logits_soft_cap, scale=scale)


def fused_step(x: Array, nat: Array, hist: Array, coef: Array, *,
               interpret: bool = False) -> Array:
    """Fused multistep update (kernels/fused_step.py); the engine hot path."""
    if interpret or use_pallas():
        from . import fused_step as fs
        return fs.fused_step(x, nat, hist, coef,
                             interpret=interpret or not use_pallas())
    return ref.fused_step(x, nat, hist, coef)


def fused_pas_step(x: Array, u: Array, cs: Array, hist: Array, coef: Array, *,
                   native_x0: bool = False, interpret: bool = False
                   ) -> tuple[Array, Array, Array]:
    """PAS projection folded into the multistep update (kernels/fused_step.py)."""
    if interpret or use_pallas():
        from . import fused_step as fs
        return fs.fused_pas_step(x, u, cs, hist, coef, native_x0=native_x0,
                                 interpret=interpret or not use_pallas())
    return ref.fused_pas_step(x, u, cs, hist, coef, native_x0=native_x0)


def fused_pas_project_step(x: Array, q_rows: Array, d: Array, pw: Array,
                           hist: Array, coef: Array, *,
                           native_x0: bool = False, interpret: bool = False
                           ) -> tuple[Array, Array, Array]:
    """Weight-space PAS projection + update in one tile pass
    (kernels/fused_step.py); the corrected-step hot path — the basis is
    never materialised, ``pw = cs @ basis_weights(gram_qd(...))``."""
    if interpret or use_pallas():
        from . import fused_step as fs
        return fs.fused_pas_project_step(
            x, q_rows, d, pw, hist, coef, native_x0=native_x0,
            interpret=interpret or not use_pallas())
    return ref.fused_pas_project_step(x, q_rows, d, pw, hist, coef,
                                      native_x0=native_x0)


def gram(x: Array, mask: Array | None = None, *, block_d: int | None = None,
         interpret: bool = False) -> Array:
    """PAS Gram matrix X X^T over a huge feature axis (kernels/gram.py).

    ``block_d`` sizes the VMEM tile of the Pallas path (any value is legal
    for any D — the tail block is masked in-kernel); ``None`` keeps the
    kernel default.  The XLA oracle ignores it (no tiling to size).
    """
    if interpret or use_pallas():
        from . import gram as gk
        kw = {} if block_d is None else {"block_d": block_d}
        return gk.gram(x, mask=mask, interpret=interpret or not use_pallas(),
                       **kw)
    return ref.gram(x, mask=mask)


def gram_qd(q_rows: Array, q_mask: Array, d: Array, *,
            block_d: int | None = None, interpret: bool = False) -> Array:
    """Per-sample Gram of the PAS rows [Q * mask; d] (kernels/gram.py):
    (R, B, D) + (R,) + (B, D) -> (B, R+1, R+1) f32.  The one D reduction a
    corrected step performs; on a mesh the caller psums this tiny output."""
    if interpret or use_pallas():
        from . import gram as gk
        kw = {} if block_d is None else {"block_d": block_d}
        return gk.gram_qd(q_rows, q_mask, d,
                          interpret=interpret or not use_pallas(), **kw)
    return ref.gram_qd(q_rows, q_mask, d)


def rmsnorm(x: Array, scale: Array, eps: float = 1e-6, *,
            interpret: bool = False) -> Array:
    """Fused RMSNorm (kernels/rmsnorm.py)."""
    if interpret or use_pallas():
        from . import rmsnorm as rk
        return rk.rmsnorm(x, scale, eps=eps, interpret=interpret or not use_pallas())
    return ref.rmsnorm(x, scale, eps=eps)


def ssm_scan(u: Array, delta: Array, a: Array, b: Array, c: Array,
             d: Array | None = None, h0: Array | None = None, *,
             interpret: bool = False) -> tuple[Array, Array]:
    """Mamba selective scan (kernels/ssm_scan.py)."""
    if interpret or use_pallas():
        from . import ssm_scan as sk
        return sk.ssm_scan(u, delta, a, b, c, d=d, h0=h0,
                           interpret=interpret or not use_pallas())
    return ref.ssm_scan(u, delta, a, b, c, d=d, h0=h0)
