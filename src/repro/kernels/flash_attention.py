"""Flash attention as a Pallas TPU kernel (online softmax, VMEM tiling).

Grid: (B*H, S/block_q, T/block_kv) with the KV axis innermost/sequential
("arbitrary" semantics) so the (block_q, head_dim) f32 accumulator + running
max/denominator live in VMEM scratch across KV steps.  GQA is handled in the
K/V BlockSpec index maps (query head -> kv head), so no materialised
jnp.repeat.  Causal and sliding-window masks skip fully-masked KV blocks via
pl.when (the compute never runs, only the O(1) scratch bookkeeping).

Block sizes default to 128x128 (MXU-aligned); the wrapper pads S/T and masks
the padding.  Validated against kernels/ref.attention in interpret mode
(tests/test_kernels.py sweeps shapes, dtypes, masks, GQA ratios).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window: int | None,
               soft_cap: float | None, block_q: int, block_kv: int,
               n_kv_blocks: int, t_real: int, pos_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions of this tile
    q_start = iq * block_q + pos_offset          # query positions (key-space)
    k_start = ik * block_kv

    # --- block-level skip: is any (q, k) pair in this tile unmasked? ---
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None:
        live = jnp.logical_and(
            live, k_start + block_kv - 1 > q_start - window)
    live = jnp.logical_and(live, k_start < t_real)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if soft_cap is not None:
            s = soft_cap * jnp.tanh(s / soft_cap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = k_pos < t_real
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)                        # exp(-inf-... ) guard
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def _pad_axis(x: Array, axis: int, multiple: int) -> Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logits_soft_cap", "scale",
                     "block_q", "block_kv", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None,
                    logits_soft_cap: float | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = False) -> Array:
    """q (B,S,H,Dh); k,v (B,T,KV,Dh) -> (B,S,H,Dh). See kernels/ref.attention."""
    b, s, h, dh = q.shape
    _, t, kv, _ = k.shape
    assert h % kv == 0
    group = h // kv
    scale = scale if scale is not None else dh ** -0.5

    block_q = min(block_q, max(s, 8))
    block_kv = min(block_kv, max(t, 8))

    # (B*H, S, Dh) query layout; (B*KV, T, Dh) for K/V
    qr = _pad_axis(q.transpose(0, 2, 1, 3).reshape(b * h, s, dh), 1, block_q)
    kr = _pad_axis(k.transpose(0, 2, 1, 3).reshape(b * kv, t, dh), 1, block_kv)
    vr = _pad_axis(v.transpose(0, 2, 1, 3).reshape(b * kv, t, dh), 1, block_kv)
    s_pad, t_pad = qr.shape[1], kr.shape[1]
    nq, nk = s_pad // block_q, t_pad // block_kv

    def kv_row(i):  # query-head row -> kv-head row (GQA)
        return (i // h) * kv + (i % h) // group

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        soft_cap=logits_soft_cap, block_q=block_q, block_kv=block_kv,
        n_kv_blocks=nk, t_real=t, pos_offset=t - s)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda i, j, kk: (i, j, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda i, j, kk: (kv_row(i), kk, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda i, j, kk: (kv_row(i), kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda i, j, kk: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denom
        ],
        interpret=interpret,
    )(qr, kr, vr)

    out = out[:, :s].reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    return out
