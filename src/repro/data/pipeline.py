"""Deterministic, shardable synthetic data pipelines.

Production-shaped: every batch is derived from (seed, step, shard) — restart
at step k regenerates the identical stream (checkpoint/restore correctness),
and each data-parallel host pulls only its shard.  A background prefetch
thread hides host latency.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

__all__ = ["TokenStream", "ImageStream", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """Synthetic LM batches: a fixed-order Markov-ish stream (learnable, so
    train-loss decreasing is a meaningful smoke signal)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0
    n_shards: int = 1

    def batch(self, step: int) -> dict[str, np.ndarray]:
        local = self.global_batch // self.n_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 131 + self.shard)
        # order-1 structure: next token = (a*tok + b) % V with noise
        a = 31 + 2 * (step % 3)
        start = rng.integers(0, self.vocab_size, size=(local, 1))
        idx = np.arange(self.seq_len)[None, :]
        toks = (start + a * idx) % self.vocab_size
        noise = rng.integers(0, self.vocab_size, size=toks.shape)
        flip = rng.random(toks.shape) < 0.05
        toks = np.where(flip, noise, toks).astype(np.int32)
        labels = np.roll(toks, -1, axis=1).astype(np.int32)
        labels[:, -1] = -1
        return {"tokens": toks, "labels": labels}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class ImageStream:
    """Synthetic image batches from a Gaussian-mixture (matches the analytic
    oracle in core/analytic.py, so learned-denoiser tests have ground truth)."""

    dim: int
    global_batch: int
    n_modes: int = 4
    seed: int = 0

    def batch(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 7919 + step)
        modes = rng.integers(0, self.n_modes, size=(self.global_batch,))
        centers = np.linspace(-2.0, 2.0, self.n_modes)
        x = centers[modes][:, None] + 0.3 * rng.standard_normal(
            (self.global_batch, self.dim))
        return x.astype(np.float32)


class Prefetcher:
    """Background-thread prefetch with bounded queue + error propagation."""

    def __init__(self, it: Iterator, depth: int = 2,
                 to_device: Optional[Callable] = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._to_device = to_device or (lambda x: x)
        self._done = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._done:
                    return
                self._q.put(self._to_device(item))
        except Exception as e:  # surface loader failures to the training loop
            self._q.put(e)
        self._q.put(StopIteration())

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, StopIteration):
            raise item
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._done = True
