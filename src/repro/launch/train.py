"""Training launcher: any zoo arch, synthetic token stream, fault-tolerant
runtime (checkpoint/resume, straggler monitor).

On this CPU container the default is the reduced config (--full lowers the
real config; use dryrun.py for full-scale lowering-only validation).

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --steps 30
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.data.pipeline import Prefetcher, TokenStream
from repro.optim import AdamW, warmup_cosine
from repro.api import TrainLoopConfig, run_train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"== train {args.arch} (reduced: {cfg.n_layers}L d{cfg.d_model}) ==")
    params = models.init_params(jax.random.key(0), cfg)
    opt = AdamW(lr=warmup_cosine(args.lr, 10, args.steps), weight_decay=0.01)
    opt_state = opt.init(params)

    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch)

    def to_batch(raw):
        b = {"tokens": jnp.asarray(raw["tokens"]),
             "labels": jnp.asarray(raw["labels"])}
        if cfg.frontend == "vision_patches":
            b["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model))
        if cfg.is_encoder_decoder:
            b["enc_states"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model))
        return b

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            return models.lm_loss(p, batch, cfg)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, {**metrics, **om}

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix=f"{args.arch}_ckpt_")
    loop_cfg = TrainLoopConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                               ckpt_every=args.ckpt_every, log_every=5)
    batches = Prefetcher(iter(stream), depth=2, to_device=to_batch)
    _, _, summary = run_train_loop(step_fn, params, opt_state, batches,
                                   loop_cfg)
    first, last = summary["history"][0], summary["history"][-1]
    print(f"steps {summary['resumed_from']}->{summary['final_step']}  "
          f"loss {first['ce_loss']:.3f} -> {last['ce_loss']:.3f}  "
          f"ckpts: {ckpt_dir}")
    assert last["ce_loss"] < first["ce_loss"], "loss must decrease"
    print("OK")


if __name__ == "__main__":
    main()
