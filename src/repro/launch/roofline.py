"""Roofline term derivation from compiled dry-run artifacts.

Terms (per DESIGN/EXPERIMENTS):
  compute    = HLO_FLOPs_per_device / 197e12  (bf16 peak, v5e)
  memory     = HLO_bytes_per_device / 819e9   (HBM bw)
  collective = per-device collective operand bytes / 50e9 (per-link ICI,
               single-link conservative model)

``compiled.cost_analysis()`` reports post-SPMD *per-device* numbers (verified
empirically: a 512-way-sharded matmul reports total/512).  Collective bytes
are parsed from the post-SPMD HLO text — operand shapes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from typing import Any

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link (conservative single-link model)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_DEF_RE = re.compile(r"^\s*(?:ROOT )?(%[\w.\-]+) = ((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))")
_OPERAND_RE = re.compile(r"%[\w.\-]+")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string: 'bf16[8,16]' or a tuple '(f32[2], ...)'."""
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(type_str))


def parse_collectives(hlo_text: str) -> dict[str, dict[str, Any]]:
    """Sum operand bytes per collective kind from (post-SPMD) HLO text.

    Post-optimization HLO references operands by name only, so a first pass
    builds the name -> result-bytes table; collective operand bytes are then
    resolved through it (falling back to the collective's own result bytes).
    """
    sizes: dict[str, int] = {}
    coll_lines: list[tuple[str, str, int]] = []  # (kind, rhs, result_bytes)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str = m.groups()
        nbytes = _type_bytes(type_str)
        sizes[name] = nbytes
        rhs = line.split(" = ", 1)[1]
        for kind in _COLL_KINDS:
            # call sites (incl. async -start); -done consumes the start token
            mm = re.search(rf"\b{kind}(?:-start)?\(", rhs)
            if mm and f"{kind}-done" not in rhs:
                coll_lines.append((kind, rhs[mm.end():], nbytes))
                break

    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}
    for kind, operand_str, result_bytes in coll_lines:
        operand_str = operand_str.split(")", 1)[0]
        nbytes = sum(sizes.get(op, 0) for op in _OPERAND_RE.findall(operand_str))
        if nbytes == 0:
            nbytes = result_bytes
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


# ---------------------------------------------------------------------------
# loop-aware HLO collective accounting
# ---------------------------------------------------------------------------

# computation headers: "%name (params...) -> type {" — params may contain
# nested parens (tuple types) and the entry is prefixed with "ENTRY "
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?(%[\w.\-]+)\s*\(")
_WHILE_RE = re.compile(r"while\(.*?condition=(%[\w.\-]+), body=(%[\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        is_header = (line.rstrip().endswith("{") and "->" in line
                     and " = " not in line)
        m = _COMP_RE.match(line) if is_header else None
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def parse_collectives_loop_aware(hlo_text: str) -> dict[str, dict[str, Any]]:
    """Like parse_collectives, but collectives inside while bodies count
    trip_count times (jax.lax.scan layers — XLA HLO text lists the body once).

    Trip counts are estimated as the largest integer constant in the loop's
    condition computation (scan conditions compare the counter to N).
    """
    comps = _split_computations(hlo_text)
    if not comps:
        return parse_collectives(hlo_text)

    # name -> result bytes across the whole module
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = _type_bytes(m.group(2))

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for ln in comps.get(cond_name, ())
                  for c in _CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}

    def walk(comp_name: str, multiplier: int, seen: frozenset):
        if comp_name in seen:
            return
        seen = seen | {comp_name}
        for line in comps.get(comp_name, ()):
            if " = " not in line:
                continue
            rhs = line.split(" = ", 1)[1]
            wm = _WHILE_RE.search(rhs)
            if wm:
                cond, body = wm.groups()
                walk(body, multiplier * trip_count(cond), seen)
                continue
            # nested calls (fusion bodies don't contain collectives; calls may)
            cm = re.search(r"(?:call|conditional)\(.*?to_apply=(%[\w.\-]+)", rhs)
            if cm:
                walk(cm.group(1), multiplier, seen)
            for kind in _COLL_KINDS:
                mm = re.search(rf"\b{kind}(?:-start)?\(", rhs)
                if mm and f"{kind}-done" not in rhs:
                    operand_str = rhs[mm.end():].split(")", 1)[0]
                    nbytes = sum(sizes.get(op, 0)
                                 for op in _OPERAND_RE.findall(operand_str))
                    if nbytes == 0:
                        dm = _DEF_RE.match(line)
                        nbytes = _type_bytes(dm.group(2)) if dm else 0
                    out[kind]["count"] += multiplier
                    out[kind]["bytes"] += multiplier * nbytes
                    break

    entries = [n for n in comps if "entry" in n.lower()]
    roots = entries or [next(iter(comps))]
    # fall back: walk every computation not referenced as a body/cond/fusion
    referenced = set()
    for lines in comps.values():
        for ln in lines:
            for nm in re.findall(r"(?:condition|body|to_apply|calls)=(%[\w.\-]+)", ln):
                referenced.add(nm)
    roots = [n for n in comps if n not in referenced] or roots
    for r in roots:
        walk(r, 1, frozenset())
    return out


def roofline_terms(cost: dict, collectives: dict) -> dict[str, Any]:
    flops = float(cost.get("flops", 0.0) or 0.0)
    byts = float(cost.get("bytes accessed", 0.0) or 0.0)
    cbytes = float(sum(v["bytes"] for v in collectives.values()))
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": cbytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values()) if any(terms.values()) else 0.0
    return {
        **terms,
        "dominant": dominant.removesuffix("_s"),
        "bound_step_s": step_s,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": byts,
        "collective_bytes_per_device": cbytes,
    }


def _embed_params(cfg) -> int:
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n *= 2
    if cfg.rope_theta is None and cfg.pattern[0].kind == "attn":
        n += cfg.max_position * cfg.d_model
    return n


def _attn_layers(cfg) -> list:
    """(count, window) pairs per pattern spec scaled to n_layers."""
    per = cfg.n_layers / len(cfg.pattern)
    return [(per, s.window) for s in cfg.pattern if s.kind == "attn"]


def analytic_cost(cfg, shape, mesh_shape: dict, kind: str,
                  serve_weight_layout: str = "fsdp_tp",
                  ce_dtype: str = "float32", remat: str = "full",
                  cache_dtype: str = "native") -> dict[str, Any]:
    """Analytic per-device FLOPs / HBM bytes / collective bytes for one step.

    This is the PRIMARY roofline source: XLA-CPU's cost_analysis counts
    while-loop (layer-scan) bodies ONCE, undercounting by ~n_layers (verified:
    measured useful_flops_ratio ~= n_layers across the zoo).  The model below
    is explicit about every term; HLO-parsed numbers are kept as cross-checks.

    serve_weight_layout: "fsdp_tp" (weights 2D-sharded, all-gathered per
    layer — collective-heavy) | "tp2d" (weights stationary, sharded over
    data x model as pure TP; activation collectives only).
    """
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    chips = dp * tp
    dt = 2 if cfg.dtype == "bfloat16" else 4
    e, v = cfg.d_model, cfg.vocab_size
    b, s = shape.global_batch, shape.seq_len
    h, dh = cfg.n_heads, cfg.head_dim_

    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    n_embed = _embed_params(cfg)
    n_block_active = max(n_active - n_embed, 0)
    # MoE expert matmuls run at capacity (cf x routed tokens)
    moe_cf = cfg.capacity_factor if cfg.n_experts else 1.0

    # ----- FLOPs (global) -----
    if kind == "decode":
        tokens = b
        ctx = s
    else:
        tokens = b * s
        ctx = None
    matmul_fwd = 2.0 * n_block_active * tokens * (moe_cf if cfg.n_experts else 1.0)
    attn_fwd = 0.0
    for count, window in _attn_layers(cfg):
        if kind == "decode":
            pairs = min(window or ctx, ctx)          # one query vs its context
        else:
            w = min(window, s) if window else None
            pairs = (w * s - w * w / 2) if w else s * s / 2.0
        attn_fwd += count * 4.0 * b * h * dh * pairs  # QK^T + AV, 2 flop/MAC
    ssm_fwd = 0.0
    n_mamba = sum(1 for sp in cfg.pattern if sp.kind == "mamba") \
        / len(cfg.pattern) * cfg.n_layers
    if n_mamba:
        ssm_fwd = n_mamba * tokens * cfg.d_inner * (6 * cfg.ssm_state
                                                    + 2 * cfg.d_conv)
    n_rg = sum(1 for sp in cfg.pattern if sp.kind == "rglru") \
        / len(cfg.pattern) * cfg.n_layers
    rg_fwd = n_rg * tokens * cfg.lru_width_ * 10
    logits_fwd = 2.0 * tokens * e * v if kind != "prefill" else 2.0 * b * e * v
    fwd = matmul_fwd + attn_fwd + ssm_fwd + rg_fwd + logits_fwd

    if kind == "train":
        # fwd + bwd(2x) + full-remat recompute (1x) + optimizer elementwise;
        # remat="dots" saves matmul outputs -> no matmul recompute (3x)
        passes = 4.0 if remat == "full" else 3.0
        flops_global = passes * fwd + 20.0 * n_total
    else:
        flops_global = fwd
    flops_dev = flops_global / chips

    # ----- HBM bytes (per device) -----
    byts: dict[str, float] = {}
    tokens_dev = tokens / dp
    if kind == "train":
        weights_pass = n_total * dt / tp               # gathered-shard reads
        n_passes = 3.0 if remat == "full" else 2.0
        byts["weights"] = n_passes * weights_pass      # fwd + bwd (+ remat)
        byts["grads"] = 2.0 * n_total * dt / (dp * tp)
        byts["optimizer"] = n_total * 20.0 / (dp * tp)  # m,v r/w f32 + p r/w
        byts["activations"] = cfg.n_layers * 14.0 * tokens_dev * e * dt / \
            max(tp if kind == "train" else 1, 1)       # SP-sharded streams
        ce_b = 2.0 if ce_dtype == "bfloat16" else 4.0
        byts["logits_ce"] = 3.0 * tokens_dev * (v / tp) * ce_b
    elif kind == "prefill":
        byts["weights"] = n_total * dt / tp
        byts["activations"] = cfg.n_layers * 8.0 * tokens_dev * e * dt
        kv_layers = sum(c for c, _ in _attn_layers(cfg))
        byts["kv_write"] = kv_layers * 2 * tokens_dev * cfg.n_kv_heads * dh * dt
    else:  # decode
        byts["weights"] = n_active * dt / tp
        # int8 KV: 1 byte + f32/Dh per-slot scale overhead
        kv_elt = (1.0 + 4.0 / max(dh, 1)) if cache_dtype == "int8" else dt
        kv_bytes = 0.0
        for count, window in _attn_layers(cfg):
            kv_len = min(window or s, s)
            kv_bytes += count * 2 * (b / dp) * kv_len * cfg.n_kv_heads * dh \
                * kv_elt
        kv_shard = tp if cfg.n_kv_heads % tp == 0 or s % tp == 0 else 1
        byts["kv_read"] = kv_bytes / kv_shard
        byts["state"] = (n_mamba * (b / dp) * cfg.d_inner * cfg.ssm_state * 4
                         + n_rg * (b / dp) * cfg.lru_width_ * 4) * 2 / tp
        byts["activations"] = cfg.n_layers * 8.0 * (b / dp) * e * dt
    bytes_dev = float(sum(byts.values()))

    # ----- collective bytes (per device) -----
    colls: dict[str, float] = {}
    if kind == "train":
        # FSDP weight all-gather per pass (x3: fwd/bwd/remat) + grad RS/AG
        colls["weight_allgather"] = 3.0 * n_total * dt / tp
        colls["grad_reduce"] = 2.0 * n_total * dt / tp
        if mesh_shape.get("pod", 1) > 1:
            colls["pod_gradient_allreduce"] = 2.0 * n_total * dt / (tp * 16)
        # SP boundary gathers: attention needs full seq per head shard
        colls["sp_activation"] = cfg.n_layers * 2.0 * tokens_dev * e * dt
        if cfg.n_experts:
            colls["moe_all_to_all"] = 2.0 * tokens_dev * e * dt * moe_cf * 4
    elif kind == "prefill":
        if serve_weight_layout == "fsdp_tp":
            colls["weight_allgather"] = n_total * dt / tp
        colls["tp_activation_allreduce"] = cfg.n_layers * 2.0 * tokens_dev * e * dt
        if cfg.n_experts:
            colls["moe_all_to_all"] = 2.0 * tokens_dev * e * dt * moe_cf
    else:
        if serve_weight_layout == "fsdp_tp":
            colls["weight_allgather"] = n_active * dt / tp
        colls["tp_activation_allreduce"] = cfg.n_layers * 2.0 * (b / dp) * e * dt
        if cfg.n_experts:
            colls["moe_all_to_all"] = 2.0 * (b / dp) * e * dt * moe_cf
    coll_dev = float(sum(colls.values()))

    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    useful = (2.0 if kind != "train" else 6.0) * n_active * tokens / flops_global
    return {
        **terms,
        "dominant": dominant.removesuffix("_s"),
        "bound_step_s": max(terms.values()),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "flops_breakdown_global": {
            "matmul": matmul_fwd, "attention": attn_fwd, "ssm": ssm_fwd,
            "rglru": rg_fwd, "logits": logits_fwd},
        "bytes_breakdown": byts,
        "collective_breakdown": colls,
        "model_flops_ratio": useful,
    }


def analytic_memory(cfg, shape, mesh_shape: dict, kind: str) -> dict[str, float]:
    """TPU-target per-device live-set model (bytes).

    The CPU-host measurement inflates temps: XLA-CPU's float-normalization
    pass upconverts bf16 loop-carried buffers (e.g. the layer-scan saved-
    activation stack) to f32 — native-bf16 TPUs never materialise those.
    arguments/outputs from memory_analysis() are exact; this model estimates
    the true TPU temp live-set for the §Dry-run "fits" verdict.
    """
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    dtype_b = 2 if cfg.dtype == "bfloat16" else 4
    e = cfg.d_model
    n_params = cfg.param_count()

    params_dev = n_params * dtype_b / (dp * tp)          # FSDP(data) x TP
    out = {"params": params_dev}

    if kind == "train":
        tokens_dev = shape.global_batch * shape.seq_len / dp
        out["opt_state"] = n_params * 8 / (dp * tp)       # m+v f32
        # remat-full saves one residual per layer, seq SP-sharded over TP
        out["saved_activations"] = cfg.n_layers * tokens_dev * e * dtype_b / tp
        out["logits_chunk"] = (shape.global_batch / dp) * 1024 \
            * cfg.vocab_size / tp * 4 * 2                 # fwd+bwd chunk
        out["gathered_layer_weights"] = \
            (n_params / max(cfg.n_layers, 1)) * dtype_b / tp * 2
        out["transients"] = 4 * tokens_dev / tp * e * 4   # few f32 act copies
    elif kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / dp
        kv_pairs = sum(1 for s in cfg.pattern if s.kind == "attn") \
            / max(len(cfg.pattern), 1) * cfg.n_layers
        kv_len = shape.seq_len
        out["kv_cache_out"] = (shape.global_batch / dp) * kv_pairs * 2 \
            * min(kv_len, max((s.window or kv_len) for s in cfg.pattern)) \
            * cfg.n_kv_heads * cfg.head_dim_ * dtype_b / min(
                tp if cfg.n_kv_heads % tp == 0 else 1, tp)
        out["transients"] = 6 * tokens_dev * e * dtype_b
    else:  # decode
        out["transients"] = 64 * 2**20  # GEMV-bound: O(100MB) workspace
    out["total"] = float(sum(out.values()))
    return out


def model_flops(cfg, shape, chips: int) -> dict[str, float]:
    """MODEL_FLOPS = 6 N D (train) / 2 N_active D (serve), N = active params."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        mf = 2.0 * n_active * shape.global_batch
    return {"model_flops_global": mf, "model_flops_per_device": mf / chips}
