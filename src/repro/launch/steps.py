"""Step builders for the dry-run and the real launchers: train / prefill /
decode / denoise, each with its in/out shardings for a given mesh + cell.

Everything here works on ShapeDtypeStructs (no allocation): the dry-run
lowers jax.jit(step, in_shardings=..., donate...).lower(**specs).compile().
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.rglru import RGLRUState
from repro.models.ssm import MambaState
from repro.optim import AdamW
from repro.parallel import AxisRules, param_partition_specs, spec_for
from repro.launch.shapes import ShapeCase, batch_specs

__all__ = ["CellPlan", "make_rules", "plan_cell"]


@dataclasses.dataclass
class CellPlan:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    fn: Any                      # python callable (to be jit'ed)
    arg_specs: tuple             # ShapeDtypeStructs (positional)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple[int, ...]
    static_descr: dict           # for the report


def make_rules(mesh, cfg: ModelConfig, kind: str, sp: bool = False,
               serve_layout: str = "fsdp_tp") -> AxisRules:
    """Default production rules per cell kind (overridable by perf configs).

    train: DP over (pod,data), FSDP weight shard over data, TP over model;
           SP (activation seq sharding over model) for the big train cells.
    serve: "fsdp_tp" — weights 2D-sharded, re-gathered every layer (min
           memory, collective-heavy); "tp_stationary" — weights sharded over
           the model axis only and never moved (the §Perf serving layout).
    """
    has_pod = "pod" in mesh.shape
    batch = ("pod", "data") if has_pod else ("data",)
    fsdp: tuple[str, ...] = ("data",)
    if kind in ("prefill", "decode") and serve_layout == "tp_stationary":
        fsdp = ()
    return AxisRules(
        mesh=mesh,
        batch=batch,
        model=("model",),
        fsdp=fsdp,
        seq=("model",) if sp else (),
        expert=("model",),
    )


def _shardings(tree_specs, rules: AxisRules):
    return jax.tree.map(
        lambda spec: NamedSharding(rules.mesh, spec), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def _batch_shardings(bspecs: dict, rules: AxisRules) -> dict:
    out = {}
    for k, v in bspecs.items():
        if k in ("tokens", "labels"):
            out[k] = spec_for(v.shape, ("batch", None), rules)
        elif k == "token":
            out[k] = spec_for(v.shape, ("batch",), rules)
        else:  # prefix_embeds / enc_states (B, F, E)
            out[k] = spec_for(v.shape, ("batch", None, None), rules)
    return out


# ---------------------------------------------------------------------------
# cache partition specs (decode)
# ---------------------------------------------------------------------------

def _scale_spec(shape, rules: AxisRules) -> P:
    """(..., B, L, KV, 1) quant scales: mirror the KV sharding sans head dim."""
    kv_like = _kv_spec(shape[:-1] + (shape[-2],), rules)
    return P(*kv_like[:-1], None)


def _kv_spec(shape, rules: AxisRules) -> P:
    """(..., B, L, KV, Dh): prefer head-TP; fall back to seq-TP; replicate."""
    *lead, b, l, kv, dh = shape
    model_n = rules.axes_size(rules.model)
    bspec = spec_for((b,), ("batch",), rules)[0]
    head_ok = model_n > 1 and kv % model_n == 0
    seq_ok = model_n > 1 and l % model_n == 0
    model_ax = rules.model if len(rules.model) > 1 else rules.model[0]
    head_ax = model_ax if head_ok else None
    seq_ax = model_ax if (not head_ok and seq_ok) else None
    return P(*(None,) * len(lead), bspec, seq_ax, head_ax, None)


def _cache_specs(cache_sds: models.Cache, rules: AxisRules):
    def layer_spec(c):
        if isinstance(c, attn_mod.QuantKVCache):
            return attn_mod.QuantKVCache(
                k=_kv_spec(c.k.shape, rules), v=_kv_spec(c.v.shape, rules),
                k_scale=_scale_spec(c.k_scale.shape, rules),
                v_scale=_scale_spec(c.v_scale.shape, rules))
        if isinstance(c, attn_mod.KVCache):
            return attn_mod.KVCache(k=_kv_spec(c.k.shape, rules),
                                    v=_kv_spec(c.v.shape, rules))
        if isinstance(c, MambaState):
            return MambaState(
                h=spec_for(c.h.shape, ("batch", "model", None), rules),
                conv=spec_for(c.conv.shape, ("batch", None, "model"), rules))
        if isinstance(c, RGLRUState):
            return RGLRUState(
                h=spec_for(c.h.shape, ("batch", "model"), rules),
                conv=spec_for(c.conv.shape, ("batch", None, "model"), rules))
        raise TypeError(type(c))

    def maybe(c):
        return None if c is None else layer_spec(c)

    return models.Cache(
        blocks=tuple(maybe(c) for c in cache_sds.blocks),
        tail=tuple(maybe(c) for c in cache_sds.tail),
        cross=None if cache_sds.cross is None else tuple(
            maybe(c) for c in cache_sds.cross),
        cross_tail=None if cache_sds.cross_tail is None else tuple(
            maybe(c) for c in cache_sds.cross_tail),
        pos=P(),
    )


# ---------------------------------------------------------------------------
# cell planners
# ---------------------------------------------------------------------------

def _train_plan(cfg: ModelConfig, rules: AxisRules, shape: ShapeCase,
                remat: str, seq_chunk: int = 1024,
                ce_dtype: str = "float32") -> CellPlan:
    opt = AdamW(lr=3e-4)
    pspecs_sds = models.param_specs(cfg)
    ospecs_sds = jax.eval_shape(opt.init, pspecs_sds)
    bspecs = batch_specs(cfg, shape)

    p_part = param_partition_specs(pspecs_sds, rules)
    o_part = type(ospecs_sds)(
        step=P(),
        m=param_partition_specs(ospecs_sds.m, rules),
        v=param_partition_specs(ospecs_sds.v, rules))
    b_part = _batch_shardings(bspecs, rules)

    def step(params, opt_state, batch):
        def loss_fn(p):
            return models.lm_loss(p, batch, cfg, remat=remat, remat_group=1,
                                  seq_chunk=seq_chunk, ce_dtype=ce_dtype)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_opt, om = opt.update(grads, opt_state, params)
        return new_params, new_opt, {**metrics, **om}

    return CellPlan(
        fn=step,
        arg_specs=(pspecs_sds, ospecs_sds, bspecs),
        in_shardings=(_shardings(p_part, rules), _shardings(o_part, rules),
                      _shardings(b_part, rules)),
        out_shardings=(_shardings(p_part, rules), _shardings(o_part, rules),
                       None),
        donate_argnums=(0, 1),
        static_descr={"kind": "train", "remat": remat,
                      "seq_chunk": seq_chunk, "ce_dtype": ce_dtype},
    )


def _prefill_plan(cfg: ModelConfig, rules: AxisRules,
                  shape: ShapeCase) -> CellPlan:
    pspecs_sds = models.param_specs(cfg)
    bspecs = batch_specs(cfg, shape)
    p_part = param_partition_specs(pspecs_sds, rules)
    b_part = _batch_shardings(bspecs, rules)

    def step(params, batch):
        logits, cache = models.prefill(
            params, batch["tokens"], cfg, max_len=shape.seq_len,
            prefix_embeds=batch.get("prefix_embeds"),
            enc_states=batch.get("enc_states"))
        return logits, cache

    cache_sds = jax.eval_shape(step, pspecs_sds, bspecs)[1]
    cache_part = _cache_specs(cache_sds, rules)

    return CellPlan(
        fn=step,
        arg_specs=(pspecs_sds, bspecs),
        in_shardings=(_shardings(p_part, rules), _shardings(b_part, rules)),
        out_shardings=(NamedSharding(rules.mesh, spec_for(
            (shape.global_batch, cfg.vocab_size), ("batch", "model"), rules)),
            _shardings(cache_part, rules)),
        donate_argnums=(),
        static_descr={"kind": "prefill"},
    )


def _decode_plan(cfg: ModelConfig, rules: AxisRules, shape: ShapeCase,
                 cache_dtype: str = "native") -> CellPlan:
    pspecs_sds = models.param_specs(cfg)
    bspecs = batch_specs(cfg, shape)
    p_part = param_partition_specs(pspecs_sds, rules)

    # cache specs via an abstract prefill at full cache length
    prefill_tokens = jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len), jnp.int32)
    prefill_batch = dict(bspecs)
    prefill_batch.pop("token")
    cache_sds = jax.eval_shape(
        lambda p, t, b: models.prefill(
            p, t, cfg, max_len=shape.seq_len,
            prefix_embeds=b.get("prefix_embeds"),
            enc_states=b.get("enc_states"), cache_dtype=cache_dtype)[1],
        pspecs_sds, prefill_tokens, prefill_batch)
    cache_part = _cache_specs(cache_sds, rules)

    def step(params, cache, token):
        return models.decode_step(params, cache, token, cfg)

    logits_part = spec_for((shape.global_batch, cfg.vocab_size),
                           ("batch", "model"), rules)
    return CellPlan(
        fn=step,
        arg_specs=(pspecs_sds, cache_sds, bspecs["token"]),
        in_shardings=(_shardings(p_part, rules), _shardings(cache_part, rules),
                      NamedSharding(rules.mesh, _batch_shardings(
                          {"token": bspecs["token"]}, rules)["token"])),
        out_shardings=(NamedSharding(rules.mesh, logits_part),
                       _shardings(cache_part, rules)),
        donate_argnums=(1,),
        static_descr={"kind": "decode", "cache_len": shape.seq_len,
                      "cache_dtype": cache_dtype},
    )


def _denoise_plan(cfg: ModelConfig, rules: AxisRules,
                  shape: ShapeCase) -> CellPlan:
    """Diffusion-LM serve step (the paper's technique at LM scale)."""
    pspecs_sds = models.param_specs(cfg, with_diffusion_head=True)
    p_part = param_partition_specs(pspecs_sds, rules)
    b, s = shape.global_batch, shape.seq_len
    x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    sig_sds = jax.ShapeDtypeStruct((b,), jnp.float32)
    x_part = spec_for(x_sds.shape, ("batch", "seq", None), rules)

    def step(params, x_t, sigma):
        return models.denoise(params, x_t, sigma, cfg)

    return CellPlan(
        fn=step,
        arg_specs=(pspecs_sds, x_sds, sig_sds),
        in_shardings=(_shardings(p_part, rules),
                      NamedSharding(rules.mesh, x_part),
                      NamedSharding(rules.mesh, P())),
        out_shardings=NamedSharding(rules.mesh, x_part),
        donate_argnums=(),
        static_descr={"kind": "denoise"},
    )


def plan_cell(cfg: ModelConfig, shape: ShapeCase, mesh,
              kind_override: Optional[str] = None, sp: Optional[bool] = None,
              remat: str = "full", serve_layout: str = "fsdp_tp",
              seq_chunk: int = 1024, ce_dtype: str = "float32",
              cache_dtype: str = "native") -> CellPlan:
    kind = kind_override or shape.kind
    if sp is None:
        # SP on for big-activation train cells (see DESIGN.md §5)
        sp = kind in ("train", "denoise") and \
            shape.global_batch * shape.seq_len >= 2 ** 20
    rules = make_rules(mesh, cfg, kind, sp=sp, serve_layout=serve_layout)
    with jax.set_mesh(mesh):
        if kind == "train":
            return _train_plan(cfg, rules, shape, remat, seq_chunk,
                               ce_dtype), rules
        if kind == "prefill":
            return _prefill_plan(cfg, rules, shape), rules
        if kind == "decode":
            return _decode_plan(cfg, rules, shape, cache_dtype), rules
        if kind == "denoise":
            return _denoise_plan(cfg, rules, shape), rules
    raise ValueError(kind)
