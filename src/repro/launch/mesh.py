"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    # jax < 0.5 has no explicit-sharding axis types; Auto is the only mode
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False,
                         tp: int = 1) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips (v5e-256).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips.

    ``tp`` carves backbone tensor parallelism out of the state ("model")
    axis — the chip count stays fixed, the state axis shrinks to ``16/tp``
    and a trailing "tensor" axis of size ``tp`` appears (the axis
    ``repro.models.eps`` places attention-head / ff / expert shards on).
    ``tp`` must divide 16.

    Validated against the local device table up front: ``jax.make_mesh``'s
    own failure on a small host is an opaque reshape error, so mismatches
    raise here with the fix spelled out (mirroring
    ``repro.parallel.MeshSpec.build``).
    """
    if tp < 1 or 16 % tp:
        raise ValueError(
            f"tp must be a positive divisor of the 16-wide state axis, "
            f"got {tp}")
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if tp > 1:
        shape = shape[:-1] + (16 // tp, tp)
        axes = axes[:-1] + ("model", "tensor")
    need = math.prod(shape)
    have = jax.device_count()
    if have < need:
        raise ValueError(
            f"make_production_mesh(multi_pod={multi_pod}) needs {need} "
            f"devices for mesh {dict(zip(axes, shape))} but this process "
            f"sees {have}. Run on a "
            f"{'2-pod v5e-256' if multi_pod else 'v5e-256'} slice, or "
            f"simulate one on CPU with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}; for "
            f"small hosts build a right-sized mesh via "
            f"repro.parallel.MeshSpec(dp=..., state=...).build() instead.")
    return make_mesh(shape, axes)
