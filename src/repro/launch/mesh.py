"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    # jax < 0.5 has no explicit-sharding axis types; Auto is the only mode
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips (v5e-256).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
