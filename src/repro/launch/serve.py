"""Serving launcher: PAS-corrected batched diffusion sampling.

Modes:
  --mode oracle     analytic GMM eps (default; instant)
  --mode diffusion  reduced zoo backbone in diffusion-LM mode (--arch ...)

The sampler is built through ``repro.api``: one ``SamplerSpec``, one
``Pipeline``.  With ``--artifact-dir`` the calibrated ~10 parameters are
persisted as a ``PASArtifact`` and reloaded on the next launch (calibration
is skipped when a matching artifact exists).

  PYTHONPATH=src python -m repro.launch.serve --nfe 10 --solver ddim \
      [--t-min 0.002] [--t-max 80.0] [--max-batch 256] [--artifact-dir DIR]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.api import PASArtifact, Pipeline
from repro.core import PASConfig, two_mode_gmm
from repro.engine import engine_cache_stats
from repro.runtime import DiffusionServer, Request, ServeConfig


def _oracle_eps(dim: int):
    gmm = two_mode_gmm(dim, sep=6.0, var=0.25)
    return gmm.eps, dim


def _diffusion_lm_eps(arch: str, seq: int = 32):
    from repro import models
    from repro.configs import get_config
    from repro.diffusion import EDMConfig, eps_from_denoiser, precondition
    cfg = get_config(arch).reduced()
    params = models.init_params(jax.random.key(0), cfg,
                                with_diffusion_head=True)
    d_state = seq * cfg.d_model

    def raw_fn(x_flat, c_noise):
        x = x_flat.reshape(-1, seq, cfg.d_model)
        out = models.denoise(params, x, jnp.exp(4.0 * c_noise), cfg)
        return out.reshape(x_flat.shape)

    return jax.jit(eps_from_denoiser(
        precondition(raw_fn, EDMConfig(sigma_data=1.0)))), d_state


def _calibrated_pipeline(cfg: ServeConfig, eps_fn, dim: int,
                         artifact_dir: str | None) -> Pipeline:
    """Load the PAS artifact if a matching one exists, else calibrate (and
    persist when --artifact-dir is given)."""
    spec = cfg.to_spec()
    if artifact_dir and PASArtifact.exists(artifact_dir):
        pipe = Pipeline.load(artifact_dir, eps_fn, dim=dim,
                             expected_spec=spec)
        print(f"PAS artifact loaded from {artifact_dir!r}: steps "
              f"{pipe.params.corrected_paper_steps()} "
              f"({pipe.params.n_stored_params} params)")
        return pipe
    pipe = Pipeline.from_spec(spec, eps_fn, dim=dim)
    pipe.calibrate(key=jax.random.key(0), batch=128)
    print(f"PAS calibrated: steps {pipe.params.corrected_paper_steps()} "
          f"({pipe.params.n_stored_params} params)")
    if artifact_dir:
        path = pipe.save(artifact_dir)
        print(f"PAS artifact saved to {path}")
    return pipe


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="oracle", choices=["oracle", "diffusion"])
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--solver", default="ddim")
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--no-pas", action="store_true")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--t-min", type=float, default=0.002,
                    help="schedule endpoint eps (ServeConfig.t_min)")
    ap.add_argument("--t-max", type=float, default=80.0,
                    help="schedule endpoint T (ServeConfig.t_max)")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="micro-batch budget; larger requests are chunked")
    ap.add_argument("--artifact-dir", default=None,
                    help="save/load the calibrated PASArtifact here")
    args = ap.parse_args()

    if args.mode == "oracle":
        eps_fn, dim = _oracle_eps(args.dim)
    else:
        eps_fn, dim = _diffusion_lm_eps(args.arch)

    cfg = ServeConfig(nfe=args.nfe, solver=args.solver,
                      t_min=args.t_min, t_max=args.t_max,
                      max_batch=args.max_batch,
                      use_pas=not args.no_pas,
                      pas=PASConfig(val_fraction=0.25, n_sgd_iters=150))

    if args.no_pas:
        server = DiffusionServer(eps_fn, dim, cfg)
    else:
        pipe = _calibrated_pipeline(cfg, eps_fn, dim, args.artifact_dir)
        server = DiffusionServer.from_pipeline(pipe, cfg)

    outs = server.serve([Request(seed=i, n_samples=16)
                         for i in range(args.requests)])
    print(f"served {server.stats['samples']} samples / "
          f"{server.stats['requests']} requests in "
          f"{server.stats['batches']} batches, {server.stats['wall_s']:.2f}s")
    print(f"engine: {server.engine.name} @ {server.engine.nfe} NFE, "
          f"{server.engine.compiled_variants()} compiled variant(s), "
          f"cache {engine_cache_stats()}")
    assert len(outs) == args.requests
    print("OK")


if __name__ == "__main__":
    main()
