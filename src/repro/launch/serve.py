"""Serving launcher: PAS-corrected batched diffusion sampling.

Modes:
  --mode oracle     analytic GMM eps (default; instant)
  --mode diffusion  reduced zoo backbone in diffusion-LM mode (--arch ...)

  PYTHONPATH=src python -m repro.launch.serve --nfe 10 --solver ddim
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import (PASConfig, calibrate, ground_truth_trajectory,
                        nested_teacher_schedule, two_mode_gmm)
from repro.engine import engine_cache_stats
from repro.runtime import DiffusionServer, Request, ServeConfig


def _oracle_eps(dim: int):
    gmm = two_mode_gmm(dim, sep=6.0, var=0.25)
    return gmm.eps, dim


def _diffusion_lm_eps(arch: str, seq: int = 32):
    from repro import models
    from repro.configs import get_config
    from repro.diffusion import EDMConfig, eps_from_denoiser, precondition
    cfg = get_config(arch).reduced()
    params = models.init_params(jax.random.key(0), cfg,
                                with_diffusion_head=True)
    d_state = seq * cfg.d_model

    def raw_fn(x_flat, c_noise):
        x = x_flat.reshape(-1, seq, cfg.d_model)
        out = models.denoise(params, x, jnp.exp(4.0 * c_noise), cfg)
        return out.reshape(x_flat.shape)

    return jax.jit(eps_from_denoiser(
        precondition(raw_fn, EDMConfig(sigma_data=1.0)))), d_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="oracle", choices=["oracle", "diffusion"])
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--solver", default="ddim")
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--no-pas", action="store_true")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    if args.mode == "oracle":
        eps_fn, dim = _oracle_eps(args.dim)
    else:
        eps_fn, dim = _diffusion_lm_eps(args.arch)

    cfg = ServeConfig(nfe=args.nfe, solver=args.solver,
                      use_pas=not args.no_pas,
                      pas=PASConfig(val_fraction=0.25, n_sgd_iters=150))
    server = DiffusionServer(eps_fn, dim, cfg)

    if not args.no_pas:
        s_ts, t_ts, m = nested_teacher_schedule(args.nfe, 100, cfg.t_min,
                                                cfg.t_max)
        x_c = cfg.t_max * jax.random.normal(jax.random.key(0), (128, dim))
        gt = ground_truth_trajectory(eps_fn, s_ts, t_ts, m, x_c)
        pas_params, _ = calibrate(server.solver, eps_fn, x_c, gt, cfg.pas)
        server.set_pas(pas_params)
        print(f"PAS: steps {pas_params.corrected_paper_steps()} "
              f"({pas_params.n_stored_params} params)")

    outs = server.serve([Request(seed=i, n_samples=16)
                         for i in range(args.requests)])
    print(f"served {server.stats['samples']} samples / "
          f"{server.stats['requests']} requests in "
          f"{server.stats['batches']} batches, {server.stats['wall_s']:.2f}s")
    print(f"engine: {server.engine.name} @ {server.engine.nfe} NFE, "
          f"{server.engine.compiled_variants()} compiled variant(s), "
          f"cache {engine_cache_stats()}")
    assert len(outs) == args.requests
    print("OK")


if __name__ == "__main__":
    main()
