"""Serving launcher: PAS-corrected batched diffusion sampling.

Modes:
  --mode oracle     analytic GMM eps (default; instant)
  --mode diffusion  reduced zoo backbone in diffusion-LM mode (--arch ...,
                    --seq/--model-seed set geometry + weight seed; the
                    backbone comes from ``repro.models.get_eps_model`` —
                    one shared param tree across every lane of the launch)

The sampler is built through ``repro.api``: one ``SamplerSpec``, one
``Pipeline``.  With ``--artifact-dir`` the calibrated ~10 parameters are
persisted as a ``PASArtifact`` and reloaded on the next launch (calibration
is skipped when a matching artifact exists).  Artifacts are placement-free:
an artifact calibrated under one ``--mesh`` reloads onto any other.

Sharded serving: ``--dp N`` shards the flush batch over N data-parallel
devices, ``--state-shard M`` shards the flattened state dim over M devices
(PAS reductions go through the ``core.distributed`` collectives), ``--tp T``
tensor-shards the diffusion backbone's weights (attention heads / ff dims /
experts; requires ``--mode diffusion``), and ``--mesh DPxSTATE[xTP]`` sets
all at once.  ``--lower-only`` AOT-lowers and compiles
the partitioned sampling program and reports placement/collectives without
executing — run it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (or more) to exercise
the production program on a virtual host mesh.

Scheduling: ``--scheduler async`` (the default) serves through the
deadline-aware continuous-batching ``runtime.scheduler.ServeScheduler``
(``--deadline-ms`` bounds how long a request may wait for its batch to
fill; ``--stream`` submits requests individually and reports per-request
chunk arrival + latency percentiles).  ``--scheduler sync`` runs the legacy
synchronous flush loop (bit-identical responses on the same seeds).

Adaptive NFE: ``--adaptive`` swaps the fixed grid for the error-controlled
embedded-pair sampler (``--rtol``/``--atol`` set the tolerances); the PAS
artifact is still calibrated/loaded on the spec's fixed grid and its
coordinates transfer to the adaptive grid, so one artifact family serves
both.  ``--nfe-ladder N1,N2,...`` instead serves a ``runtime.ladder``
ladder: PAS-corrected fixed rungs at those step counts plus a teacher-grade
lane, auto-populating the ``PipelineRouter`` so deadline slack picks the
step count per request.  Uncalibrated rungs are calibrated zoo-wide
(``repro.engine.zoo``): one shared teacher trajectory on the
lcm-of-rung-NFEs grid, every rung's Algorithm 1 in one compiled run.

Routing: any repeatable ``--pipeline KEY=SOLVER@NFE`` switches the launch
onto the multi-lane ``PipelineRouter`` — one submit queue over a zoo of
samplers sharing the launch schedule/mesh, requests routed by explicit lane
key or deadline slack, ``interactive`` packing ahead of ``batch``.
``--priority`` sets the generated request class (``mixed`` interleaves) and
``--arrival`` staggers submissions: ``poisson`` generates a seeded stream
at ``--rate``/``--duration``, ``trace`` replays a ``--trace-file`` CSV
(``t_ms,seed,n_samples,priority,deadline_ms,pipeline``).  The report adds
per-priority latency percentiles and per-lane flush counts.

  PYTHONPATH=src python -m repro.launch.serve --nfe 10 --solver ddim \
      [--t-min 0.002] [--t-max 80.0] [--max-batch 256] [--artifact-dir DIR] \
      [--calibrate-batch B] [--dp N] [--state-shard M] [--tp T] \
      [--mesh DPxSTATE[xTP]] [--seq L] [--model-seed S] \
      [--scheduler {async,sync}] [--deadline-ms MS] [--stream] \
      [--pipeline KEY=SOLVER@NFE ...] [--priority CLASS] \
      [--arrival {upfront,poisson,trace}] [--rate R] [--duration S] \
      [--trace-file CSV] [--slack-ms-per-eval MS] [--lower-only] \
      [--adaptive] [--rtol R] [--atol A] [--nfe-ladder N1,N2,...]
"""
from __future__ import annotations

import argparse
import json
import re
import time

import jax

# the serving types resolve through repro.api too (lazily, PEP 562): the
# public surface is the only import boundary launchers use
from repro.api import (DiffusionServer, ErrorControlConfig, MeshSpec,
                       NFELadder, PASArtifact, Pipeline, PipelineRouter,
                       Request, ServeConfig, load_trace, poisson_arrivals,
                       replay)
from repro.core import PASConfig, two_mode_gmm
from repro.engine import compile_cache, engine_cache_stats


def parse_mesh(value: str) -> tuple[int, int, int]:
    """Parse a ``--mesh DPxSTATE[xTP]`` grid, rejecting malformed values.

    The old ``str.partition("x")`` parsing silently accepted ``8`` (as
    dp=8, state defaulted) and ``x4`` (empty dp -> crash later); both now
    fail at the argparse boundary with the expected format spelled out.
    The optional third component is backbone tensor parallelism
    (``MeshSpec.tp``; ``--mesh 2x1x4`` = dp=2, state=1, tp=4).
    """
    m = re.fullmatch(r"(\d+)x(\d+)(?:x(\d+))?", value.strip())
    if not m:
        raise argparse.ArgumentTypeError(
            f"expected DPxSTATE or DPxSTATExTP (positive integers joined by "
            f"'x', e.g. 8x1, 2x4 or 2x1x4), got {value!r}")
    dp, state = int(m.group(1)), int(m.group(2))
    tp = int(m.group(3)) if m.group(3) else 1
    if dp < 1 or state < 1 or tp < 1:
        raise argparse.ArgumentTypeError(
            f"mesh axes must be >= 1, got dp={dp} state={state} tp={tp}")
    return dp, state, tp


def parse_nfe_list(value: str) -> tuple[int, ...]:
    """Parse a ``--nfe-ladder N1,N2,...`` rung list."""
    try:
        nfes = tuple(int(v) for v in value.split(","))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected comma-separated integers (e.g. 5,8,10), "
            f"got {value!r}") from None
    if not nfes or any(n < 1 for n in nfes):
        raise argparse.ArgumentTypeError(
            f"ladder NFEs must be positive integers, got {value!r}")
    if len(set(nfes)) != len(nfes):
        raise argparse.ArgumentTypeError(f"duplicate ladder NFEs: {value!r}")
    return nfes


def parse_pipeline(value: str) -> tuple[str, str, int]:
    """Parse one ``--pipeline KEY=SOLVER@NFE`` lane spec."""
    m = re.fullmatch(r"([\w.-]+)=([\w.-]+)@(\d+)", value.strip())
    if not m:
        raise argparse.ArgumentTypeError(
            f"expected KEY=SOLVER@NFE (e.g. fast=ddim@5), got {value!r}")
    key, solver, nfe = m.group(1), m.group(2), int(m.group(3))
    if nfe < 1:
        raise argparse.ArgumentTypeError(f"NFE must be >= 1, got {nfe}")
    return key, solver, nfe


def _oracle_eps(dim: int):
    gmm = two_mode_gmm(dim, sep=6.0, var=0.25)
    return gmm.eps, dim


def _diffusion_lm_eps(arch: str, seq: int = 32):
    """Deprecated shim — use ``repro.models.eps.build_eps`` instead.

    The private helper this launcher used to carry (replicated params,
    hardcoded ``seq=32`` / ``jax.random.key(0)``) was promoted to the
    first-class ``repro.models.eps`` module, which additionally places
    params and per-layer activations on the launch mesh (``MeshSpec.tp``
    backbone tensor parallelism).  This wrapper reproduces the exact old
    (eps_fn, dim) contract — bit-identical outputs — and will be removed;
    see README "Real backbones on the mesh".
    """
    import warnings
    warnings.warn(
        "launch.serve._diffusion_lm_eps is deprecated; use "
        "repro.models.eps.build_eps(arch, seq=..., seed=..., mesh=...)",
        DeprecationWarning, stacklevel=2)
    from repro.models import build_eps
    model = build_eps(arch, seq=seq, seed=0)
    return model.fn, model.dim


def _calibrated_pipeline(cfg: ServeConfig, eps_fn, dim: int,
                         artifact_dir: str | None,
                         calibrate_batch: int = 128) -> Pipeline:
    """Load the PAS artifact if a matching one exists, else calibrate (and
    persist when --artifact-dir is given).  The artifact spec is compared
    modulo placement and re-placed onto this launch's mesh, so the same
    artifact serves any --mesh shape.

    Calibration-on-launch runs through the fused ``CalibrationEngine`` on
    the launch mesh: the batch is padded to a DP-divisible row count so a
    large ``--calibrate-batch`` shards across the data-parallel axis exactly
    like a serve flush (pad rows are prior draws — always in-distribution)."""
    spec = cfg.to_spec()
    if artifact_dir and PASArtifact.exists(artifact_dir):
        pipe = Pipeline.load(artifact_dir, eps_fn, dim=dim,
                             expected_spec=spec, mesh=spec.mesh)
        print(f"PAS artifact loaded from {artifact_dir!r}: steps "
              f"{pipe.params.corrected_paper_steps()} "
              f"({pipe.params.n_stored_params} params, re-placed onto "
              f"dp={spec.mesh.dp} state={spec.mesh.state})")
        return pipe
    pipe = Pipeline.from_spec(spec, eps_fn, dim=dim)
    batch = calibrate_batch + spec.mesh.pad_batch(calibrate_batch)
    pipe.calibrate(key=jax.random.key(0), batch=batch)
    print(f"PAS calibrated on batch {batch} "
          f"(dp={spec.mesh.dp} state={spec.mesh.state}): steps "
          f"{pipe.params.corrected_paper_steps()} "
          f"({pipe.params.n_stored_params} params)")
    if artifact_dir:
        path = pipe.save(artifact_dir)
        print(f"PAS artifact saved to {path}")
    return pipe


def _precompile_router(args, router: PipelineRouter) -> None:
    """Warm every router lane's flush variant when --precompile is set."""
    if not args.precompile:
        return
    t0 = time.perf_counter()
    rep = router.precompile(model_key=args.model_key)
    sources = {lane: {b: r["sample"].get("source") for b, r in by_b.items()}
               for lane, by_b in rep.items()}
    print(f"precompiled {len(rep)} lane(s) in "
          f"{time.perf_counter() - t0:.2f}s: {sources}")


# traffic-module class deadlines: what upfront router requests default to
# when --deadline-ms is not given (the slack router routes on these)
_CLASS_DEADLINE_MS = {"interactive": 25.0, "batch": 250.0}


def _router_requests(args) -> list[Request]:
    """The upfront request list for router mode (--arrival upfront)."""
    prios = (["interactive", "batch"] if args.priority == "mixed"
             else [args.priority])
    reqs = []
    for i in range(args.requests):
        prio = prios[i % len(prios)]
        ddl = (args.deadline_ms if args.deadline_ms is not None
               else _CLASS_DEADLINE_MS[prio])
        reqs.append(Request(seed=i, n_samples=16, priority=prio,
                            deadline_ms=ddl))
    return reqs


def _serve_router(args, cfg: ServeConfig, eps_fn, dim: int) -> None:
    """Serve through a multi-lane ``PipelineRouter`` (any ``--pipeline``).

    Every lane shares the launch schedule/mesh/PAS config; only
    (solver, NFE) varies per ``KEY=SOLVER@NFE``.  Artifacts live per lane
    under ``<artifact-dir>/<key>/`` — ``from_specs`` reloads the ones that
    exist, ``calibrate_all`` fills in and persists the rest.
    """
    import dataclasses

    base = cfg.to_spec()
    specs = {key: dataclasses.replace(base, solver=solver, nfe=nfe)
             for key, solver, nfe in args.pipelines}
    router = PipelineRouter.from_specs(
        specs, eps_fn, dim, artifact_dir=args.artifact_dir,
        use_pas=not args.no_pas, cfg=cfg)
    if not args.no_pas:
        router.calibrate_all(jax.random.key(0), batch=args.calibrate_batch,
                             artifact_dir=args.artifact_dir)
    _precompile_router(args, router)
    _drive_router(args, router)


def _serve_ladder(args, cfg: ServeConfig, eps_fn, dim: int) -> None:
    """Serve an ``NFELadder`` router (``--nfe-ladder N1,N2,...``).

    The ladder derives PAS-corrected fixed rungs at the given step counts
    plus an uncorrected teacher-grade lane from the launch spec, all sharing
    one artifact family under ``--artifact-dir`` (per-rung artifacts + the
    ``ladder.json`` manifest).
    """
    ladder = NFELadder(cfg.to_spec(), nfes=args.nfe_ladder)
    router = ladder.build_router(
        eps_fn, dim, cfg=cfg, artifact_dir=args.artifact_dir,
        use_pas=(False if args.no_pas else None))
    if not args.no_pas:
        ladder.calibrate(router, jax.random.key(0),
                         batch=args.calibrate_batch,
                         artifact_dir=args.artifact_dir)
    if args.precompile:
        t0 = time.perf_counter()
        rep = ladder.precompile(router, model_key=args.model_key)
        print(f"precompiled {len(rep)} ladder lane(s) in "
              f"{time.perf_counter() - t0:.2f}s")
    _drive_router(args, router)


def _drive_router(args, router: PipelineRouter) -> None:
    """Shared router driver: submit per ``--arrival``, drain, report."""
    print("router lanes: " + ", ".join(
        f"{k}={p.spec.solver}@{p.spec.nfe} "
        f"(est {router.lane_cost_ms(k):.0f}ms/row)"
        for k, p in router.pipelines.items()))
    try:
        if args.arrival == "upfront":
            handles = [router.submit(r) for r in _router_requests(args)]
        else:
            if args.arrival == "poisson":
                frac = {"interactive": 1.0, "batch": 0.0,
                        "mixed": 0.5}[args.priority]
                arrivals = poisson_arrivals(args.rate, args.duration, seed=0,
                                            interactive_fraction=frac)
            else:
                arrivals = load_trace(args.trace_file)
            handles = [h for _, h in replay(arrivals, router.submit)]
        router.drain(timeout=600)
        stats = router.stats
        for prio, lats in stats["latency_by_priority"].items():
            if not lats:
                continue
            lat = sorted(1e3 * v for v in lats)
            print(f"{prio}: {len(lat)} request(s) "
                  f"p50={lat[len(lat) // 2]:.1f}ms "
                  f"p95={lat[int(0.95 * (len(lat) - 1))]:.1f}ms")
        print("lane flushes: " + ", ".join(
            f"{k}={v} ({stats['lane_rows'][k]} rows)"
            for k, v in stats["lane_batches"].items()))
        print(f"served {stats['samples']} samples / {stats['requests']} "
              f"requests in {stats['batches']} batches "
              f"({stats['nfe_total']} evals), "
              f"engine cache {engine_cache_stats()}")
        assert all(h.done() for h in handles)
    finally:
        router.close()
    print("OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="oracle", choices=["oracle", "diffusion"])
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--seq", type=int, default=32,
                    help="backbone sequence length for --mode diffusion "
                         "(state dim = seq * d_model)")
    ap.add_argument("--model-seed", type=int, default=0,
                    help="backbone init seed for --mode diffusion")
    ap.add_argument("--solver", default="ddim")
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--no-pas", action="store_true")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--t-min", type=float, default=0.002,
                    help="schedule endpoint eps (ServeConfig.t_min)")
    ap.add_argument("--t-max", type=float, default=80.0,
                    help="schedule endpoint T (ServeConfig.t_max)")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="micro-batch budget; larger requests are chunked")
    ap.add_argument("--artifact-dir", default=None,
                    help="save/load the calibrated PASArtifact here")
    ap.add_argument("--calibrate-batch", type=int, default=128,
                    help="calibration trajectories for --calibrate-on-launch "
                         "(padded to a DP-divisible count under a mesh)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel mesh axis (batch sharding)")
    ap.add_argument("--state-shard", type=int, default=1,
                    help="state-dim mesh axis (D sharding; PAS reductions "
                         "run through core.distributed collectives)")
    ap.add_argument("--tp", type=int, default=1,
                    help="backbone tensor-parallel mesh axis (--mode "
                         "diffusion: shards eps-model weights/activations "
                         "via repro.models.eps; composes with --dp)")
    ap.add_argument("--mesh", default=None, metavar="DPxSTATE[xTP]",
                    type=parse_mesh,
                    help="shorthand setting all axes, e.g. --mesh 8x1 or "
                         "--mesh 2x1x4")
    ap.add_argument("--pipeline", action="append", dest="pipelines",
                    metavar="KEY=SOLVER@NFE", type=parse_pipeline,
                    help="add one router lane (repeatable); any --pipeline "
                         "serves through the multi-lane PipelineRouter "
                         "instead of the single-pipeline server")
    ap.add_argument("--priority", default="batch",
                    choices=["interactive", "batch", "mixed"],
                    help="priority class for generated requests (mixed: "
                         "Poisson coin per request / alternating upfront)")
    ap.add_argument("--arrival", default="upfront",
                    choices=["upfront", "poisson", "trace"],
                    help="upfront: submit --requests at once; poisson: "
                         "seeded Poisson stream (--rate/--duration); trace: "
                         "replay a CSV schedule (--trace-file)")
    ap.add_argument("--rate", type=float, default=60.0,
                    help="offered load for --arrival poisson, requests/s")
    ap.add_argument("--duration", type=float, default=1.0,
                    help="stream length for --arrival poisson, seconds")
    ap.add_argument("--trace-file", default=None,
                    help="CSV schedule for --arrival trace "
                         "(t_ms,seed,n_samples,priority,deadline_ms,pipeline)")
    ap.add_argument("--slack-ms-per-eval", type=float, default=1.0,
                    help="router cost model: ms of deadline slack one model "
                         "eval is worth (deadline-slack lane routing)")
    ap.add_argument("--adaptive", action="store_true",
                    help="error-controlled sampling: the embedded-pair PID "
                         "solver picks the step count per sample; --nfe only "
                         "names the PAS calibration grid")
    ap.add_argument("--rtol", type=float, default=0.05,
                    help="relative tolerance for --adaptive error control")
    ap.add_argument("--atol", type=float, default=0.0078,
                    help="absolute tolerance for --adaptive error control")
    ap.add_argument("--nfe-ladder", default=None, metavar="N1,N2,...",
                    type=parse_nfe_list,
                    help="serve an NFELadder router: PAS rungs at these step "
                         "counts + a teacher-grade lane, deadline slack "
                         "picking the rung per request")
    ap.add_argument("--scheduler", default="async",
                    choices=["async", "sync"],
                    help="async: deadline-aware continuous-batching "
                         "scheduler; sync: legacy flush loop (bit-identical "
                         "responses)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="max time a request waits for its batch to fill "
                         "before a partial flush (async scheduler only)")
    ap.add_argument("--stream", action="store_true",
                    help="submit requests individually and report streamed "
                         "chunk arrival + latency percentiles")
    ap.add_argument("--lower-only", action="store_true",
                    help="AOT-lower + compile the partitioned programs "
                         "(sampling, calibration, adaptive with --adaptive) "
                         "and report placement/collectives; no sampling")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent compile cache directory (the XLA disk "
                         "cache + serialized AOT executables); a warm cache "
                         "removes the per-process compile tax")
    ap.add_argument("--precompile", action="store_true",
                    help="warm every lane's flush program (and the "
                         "calibration programs when calibration runs on "
                         "launch) before admitting traffic")
    args = ap.parse_args()

    if args.stream and args.scheduler != "async":
        ap.error("--stream serves through the request queue; it requires "
                 "--scheduler async")
    if args.pipelines and args.scheduler != "async":
        ap.error("--pipeline routes through the async scheduler; it cannot "
                 "combine with --scheduler sync")
    if args.arrival == "trace" and not args.trace_file:
        ap.error("--arrival trace requires --trace-file")
    if args.nfe_ladder and args.pipelines:
        ap.error("--nfe-ladder builds its own router lanes; it cannot "
                 "combine with --pipeline")
    if args.nfe_ladder and args.scheduler != "async":
        ap.error("--nfe-ladder routes through the async scheduler; it "
                 "cannot combine with --scheduler sync")
    if args.adaptive and (args.pipelines or args.nfe_ladder):
        ap.error("--adaptive is per-sample step-count adaptation on the "
                 "single-pipeline server; router lanes are fixed rungs "
                 "(use --nfe-ladder for per-request adaptation)")
    if args.pipelines is not None:
        keys = [k for k, _, _ in args.pipelines]
        if len(set(keys)) != len(keys):
            ap.error(f"duplicate --pipeline keys: {keys}")
    if args.mesh is not None:
        args.dp, args.state_shard, args.tp = args.mesh
    if args.tp > 1 and args.mode != "diffusion":
        ap.error("--tp shards the eps backbone; it requires --mode diffusion "
                 "(the oracle eps has no weights to shard)")
    mesh = MeshSpec(dp=args.dp, state=args.state_shard, tp=args.tp)

    if args.cache_dir:
        # wire the persistent compile cache before anything compiles: the
        # XLA disk cache covers every jit/AOT compile from here on, and the
        # AOT paths below additionally serialize/restore whole executables
        compile_cache.configure(args.cache_dir)
        print(f"compile cache: {args.cache_dir} (xla + executables)")

    if args.mode == "oracle":
        eps_fn, dim = _oracle_eps(args.dim)
        model_key = f"oracle:gmm:{dim}"
    else:
        # the first-class eps module: ONE shared param tree (every router /
        # ladder lane reuses it), placed on the launch mesh with --tp
        # backbone tensor parallelism composing with sampling DP
        from repro.models import get_eps_model
        eps_model = get_eps_model(args.arch, seq=args.seq,
                                  seed=args.model_seed, mesh=mesh)
        eps_fn, dim = eps_model.fn, eps_model.dim
        # the eps model's identity in the executable-serialization key
        # (placement excluded — engine fingerprints hash the mesh)
        model_key = eps_model.model_key
    args.model_key = model_key

    cfg = ServeConfig(nfe=args.nfe, solver=args.solver,
                      t_min=args.t_min, t_max=args.t_max,
                      max_batch=args.max_batch,
                      use_pas=not args.no_pas,
                      pas=PASConfig(val_fraction=0.25, n_sgd_iters=150),
                      mesh=mesh,
                      scheduler=args.scheduler,
                      deadline_ms=args.deadline_ms,
                      slack_ms_per_eval=args.slack_ms_per_eval,
                      seq=args.seq, model_seed=args.model_seed)

    if args.lower_only:
        # the serve dry-run: compile (never run) the partitioned programs —
        # under XLA_FLAGS=--xla_force_host_platform_device_count=N these are
        # the exact lowered programs a real N-device mesh executes.  The
        # sampling scan, the calibration-side programs (teacher, Algorithm
        # 1, final gate), and — with --adaptive — the masked adaptive scan
        # are all covered, so the dry-run predicts the whole launch, not
        # just the serve flush
        pipe = Pipeline.from_spec(cfg.to_spec(), eps_fn, dim=dim)
        batch = args.max_batch + mesh.pad_batch(args.max_batch)
        info = {"sampling": pipe.engine.aot_compile(
            eps_fn, batch=batch, dim=dim, model_key=model_key)}
        cal_batch = (args.calibrate_batch
                     + mesh.pad_batch(args.calibrate_batch))
        info["calibration"] = pipe.calibration_engine.aot_compile(
            eps_fn, cal_batch, dim, model_key=model_key)
        if args.adaptive:
            ec = ErrorControlConfig(rtol=args.rtol, atol=args.atol)
            adaptive = Pipeline.from_spec(
                cfg.to_spec().replace(error_control=ec), eps_fn, dim=dim)
            info["adaptive"] = adaptive.adaptive_engine.aot_compile(
                eps_fn, batch, dim, model_key=model_key)
        print(json.dumps(info, indent=1))
        print("LOWER_OK")
        return

    if args.pipelines:
        _serve_router(args, cfg, eps_fn, dim)
        return
    if args.nfe_ladder:
        _serve_ladder(args, cfg, eps_fn, dim)
        return

    if args.no_pas:
        pipe = Pipeline.from_spec(cfg.to_spec(), eps_fn, dim=dim)
    else:
        # calibration runs on the fixed grid either way: with --adaptive the
        # learned coordinates transfer to the adaptive grid, so the same
        # artifact family serves both samplers
        pipe = _calibrated_pipeline(cfg, eps_fn, dim, args.artifact_dir,
                                    calibrate_batch=args.calibrate_batch)
    if args.adaptive:
        import dataclasses
        ec = ErrorControlConfig(rtol=args.rtol, atol=args.atol)
        adaptive = Pipeline.from_spec(pipe.spec.replace(error_control=ec),
                                      eps_fn, dim=dim)
        adaptive.set_params(pipe.params, pipe.diag)
        pipe = adaptive
        cfg = dataclasses.replace(cfg, spec=pipe.spec)
        print(f"adaptive sampling: rtol={ec.rtol} atol={ec.atol} "
              f"(worst case {pipe.evals_per_sample} evals/sample)")
    if args.precompile:
        t0 = time.perf_counter()
        rep = pipe.precompile(args.max_batch, use_pas=not args.no_pas,
                              model_key=model_key)
        print(f"precompiled flush program in {time.perf_counter() - t0:.2f}s "
              f"(source: {rep['sample'].get('source')})")
    server = DiffusionServer.from_pipeline(pipe, cfg)

    reqs = [Request(seed=i, n_samples=16) for i in range(args.requests)]
    if args.stream:
        # per-request streaming: chunks land as their flushes retire; the
        # drain only forces out whatever a deadline hasn't already flushed
        handles = [server.submit(r) for r in reqs]
        server.drain(timeout=600)
        outs = []
        for i, h in enumerate(handles):
            shapes = [c.shape[0] for c in h.chunks(timeout=60)]
            outs.append(h.result())
            print(f"request {i}: {shapes} rows streamed, "
                  f"latency {1e3 * h.latency_s:.1f}ms")
        lat = sorted(1e3 * v for v in server.stats["latency_s"])
        print(f"latency p50={lat[len(lat) // 2]:.1f}ms "
              f"p95={lat[int(0.95 * (len(lat) - 1))]:.1f}ms "
              f"(deadline {args.deadline_ms}ms, "
              f"{server.stats.get('flushes_deadline', 0)} deadline / "
              f"{server.stats.get('flushes_budget', 0)} budget / "
              f"{server.stats.get('flushes_drain', 0)} drain flushes)")
    else:
        outs = server.serve(reqs)
    if getattr(pipe, "is_adaptive", False) and server.stats["samples"]:
        mean_nfe = server.stats["nfe_total"] / (server.stats["samples"]
                                                + server.stats["padded_samples"])
        print(f"adaptive NFE: {mean_nfe:.1f} evals/sample mean "
              f"(bound {pipe.evals_per_sample})")
    print(f"served {server.stats['samples']} samples / "
          f"{server.stats['requests']} requests in "
          f"{server.stats['batches']} batches "
          f"(mesh dp={mesh.dp} state={mesh.state} tp={mesh.tp}, "
          f"{server.stats['padded_samples']} pad rows, "
          f"{server.stats['nfe_total']} evals), "
          f"{server.stats['wall_s']:.2f}s")
    print(f"engine: {server.engine.name} @ {server.engine.nfe} NFE, "
          f"{server.engine.compiled_variants()} compiled variant(s), "
          f"cache {engine_cache_stats()}")
    assert len(outs) == args.requests
    print("OK")


if __name__ == "__main__":
    main()
