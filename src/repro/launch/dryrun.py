import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# CPU-host artifact suppression (TPU never has it): XLA-CPU converts bf16 dot
# operands to f32 and LICM hoists those converts out of the layer scan,
# materialising f32 copies of whole scanned weight/cache stacks.  Disabling
# the hoist keeps converts per-iteration, matching TPU's true live-set.
os.environ["XLA_FLAGS"] += " --xla_disable_hlo_passes=while-loop-invariant-code-motion"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes; record memory/cost/collective analysis (EXPERIMENTS.md
§Dry-run).  MUST keep the two lines above first — jax locks the device count
on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
  ... [--out benchmarks/artifacts/dryrun] [--force] [--step denoise]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_applicability
from repro.launch.steps import plan_cell
from repro.parallel import axis_rules


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: Path,
             force: bool = False, step_kind: str | None = None,
             sp: bool | None = None, remat: str = "full",
             serve_layout: str = "fsdp_tp", seq_chunk: int = 1024,
             ce_dtype: str = "float32", cache_dtype: str = "native",
             tag: str = "") -> dict:
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": step_kind or shape.kind, "tag": tag,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }

    ok, reason = cell_applicability(cfg, shape)
    if not ok:
        record.update(status="skip", reason=reason)
        out_path.write_text(json.dumps(record, indent=1))
        return record

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size
    try:
        t0 = time.time()
        plan, rules = plan_cell(cfg, shape, mesh, kind_override=step_kind,
                                sp=sp, remat=remat, serve_layout=serve_layout,
                                seq_chunk=seq_chunk, ce_dtype=ce_dtype,
                                cache_dtype=cache_dtype)
        t_plan = time.time() - t0

        t0 = time.time()
        with jax.set_mesh(mesh), axis_rules(rules):
            jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                             out_shardings=plan.out_shardings,
                             donate_argnums=plan.donate_argnums)
            lowered = jitted.lower(*plan.arg_specs)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        cost = dict(compiled.cost_analysis() or {})
        hlo = compiled.as_text()
        colls = rl.parse_collectives_loop_aware(hlo)
        kind = step_kind or shape.kind
        analytic = rl.analytic_cost(cfg, shape, dict(mesh.shape), kind,
                                    serve_weight_layout=serve_layout,
                                    ce_dtype=ce_dtype, remat=remat,
                                    cache_dtype=cache_dtype)
        terms = rl.roofline_terms(cost, colls)
        mflops = rl.model_flops(cfg, shape, chips)
        amem = rl.analytic_memory(cfg, shape, dict(mesh.shape), kind)
        hlo_per_dev = terms["hlo_flops_per_device"]
        useful = (mflops["model_flops_per_device"] / hlo_per_dev
                  if hlo_per_dev else 0.0)

        record.update(
            status="ok",
            chips=chips,
            seconds={"plan": round(t_plan, 2), "lower": round(t_lower, 2),
                     "compile": round(t_compile, 2)},
            memory_per_device_bytes={
                "arguments": ma.argument_size_in_bytes,
                "outputs": ma.output_size_in_bytes,
                "temps": ma.temp_size_in_bytes,
                "aliased": ma.alias_size_in_bytes,
                "total_live": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            cost={k: cost.get(k) for k in ("flops", "bytes accessed",
                                           "transcendentals")},
            collectives=colls,
            roofline_hlo=terms,          # cross-check (loop bodies ~once)
            roofline=analytic,           # PRIMARY terms (see roofline.py)
            model_flops=mflops,
            useful_flops_ratio=useful,
            analytic_memory_tpu_bytes=amem,
            # exact (dtype-true) args/outputs + analytic TPU temps; the
            # params/opt components of `amem` are already inside `arguments`
            fits_16g_tpu=bool(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes
                + (amem["total"] - amem.get("params", 0.0)
                   - amem.get("opt_state", 0.0)) < 16 * 2**30),
            static=plan.static_descr,
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # a failure here is a bug in the system — record it
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(record, indent=1))
    return record


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", default=None,
                    help="arch id (repeatable); default: all assigned")
    ap.add_argument("--shape", action="append", default=None,
                    choices=list(SHAPES), help="shape (repeatable)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--step", default=None, choices=["denoise"],
                    help="override the step kind (paper-mode diffusion serve)")
    ap.add_argument("--sp", default=None, type=int,
                    help="force sequence-parallel on (1) / off (0)")
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--layout", default="fsdp_tp",
                    choices=["fsdp_tp", "tp_stationary"],
                    help="serving weight layout (prefill/decode cells)")
    ap.add_argument("--seq-chunk", type=int, default=1024,
                    help="chunked-CE sequence chunk (train cells)")
    ap.add_argument("--ce-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="materialised CE logits dtype (train cells)")
    ap.add_argument("--kv-dtype", default="native", choices=["native", "int8"],
                    help="decode KV-cache storage dtype")
    ap.add_argument("--tag", default="", help="artifact suffix (perf variants)")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    archs = args.arch or list(ASSIGNED_ARCHS)
    shapes = args.shape or list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh_name, out_dir,
                               force=args.force, step_kind=args.step,
                               sp=None if args.sp is None else bool(args.sp),
                               remat=args.remat, serve_layout=args.layout,
                               seq_chunk=args.seq_chunk,
                               ce_dtype=args.ce_dtype,
                               cache_dtype=args.kv_dtype, tag=args.tag)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    mem = rec["memory_per_device_bytes"]["total_live"] / 2**30
                    dom = rec["roofline"]["dominant"]
                    extra = f" mem/dev={mem:.2f}GiB dominant={dom}"
                elif status == "error":
                    n_fail += 1
                    extra = " " + rec["error"][:120]
                print(f"[{status:5s}] {arch:22s} {shape_name:12s} "
                      f"{mesh_name:6s} ({time.time()-t0:6.1f}s){extra}",
                      flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
