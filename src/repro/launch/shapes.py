"""Assigned input-shape set + per-arch applicability + ShapeDtypeStruct specs.

LM transformer shapes are seq_len x global_batch; decode_*/long_* lower
``serve_step`` (one new token against a KV cache of seq_len), NOT train_step.
long_500k requires sub-quadratic attention (cfg.sub_quadratic) and is skipped
— with the reason recorded — for pure full-attention archs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["ShapeCase", "SHAPES", "cell_applicability", "batch_specs"]


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str      # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524_288, 1),
}


def cell_applicability(cfg: ModelConfig, shape: ShapeCase) -> tuple[bool, str]:
    """(runs?, reason).  Skips are part of the deliverable record."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skip: pure full-attention arch — 512k decode KV is "
                       "quadratic-history; per DESIGN.md §Shape-applicability")
    if cfg.name == "whisper-small" and shape.name == "long_500k":
        return False, "skip: enc-dec decoder is architecturally short-context"
    return True, "ok"


def _token_dtype() -> jnp.dtype:
    return jnp.int32


def batch_specs(cfg: ModelConfig, shape: ShapeCase) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, no device allocation.  Frontend stubs per
    the assignment: [vlm] precomputed patch embeddings, [audio] precomputed
    encoder frame states.
    """
    b = shape.global_batch
    s = shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    tok = _token_dtype()

    extras: dict = {}
    if cfg.frontend == "vision_patches":
        extras["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), dt)
    if cfg.is_encoder_decoder:
        extras["enc_states"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), dt)

    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((b, s), tok),
                "labels": jax.ShapeDtypeStruct((b, s), tok), **extras}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((b, s), tok), **extras}
    # decode: one new token; the cache specs come from launch/steps.py
    return {"token": jax.ShapeDtypeStruct((b,), tok), **extras}
