"""Attention: GQA/MQA self-attention (full / sliding-window / local:global),
cross-attention (enc-dec), and single-token decode against (ring-)KV caches.

Full-sequence paths call kernels.ops.flash_attention; decode is a GEMV
(memory-bound — no kernel needed).  Bounded windows use ring-buffer caches:
position p lives in slot p % window (shapes in this framework keep
S % window == 0, asserted at prefill).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.kernels import ops
from repro.parallel import constrain

from .layers import apply_rope, dense_init, rope_cos_sin, zeros

Array = jax.Array
_NEG_INF = -1e30


class KVCache(NamedTuple):
    k: Array  # (B, L_cache, KV, Dh)  (L_cache = window for ring buffers)
    v: Array


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(slot, head) scales — halves decode HBM traffic
    (the dominant term for MHA serving; see EXPERIMENTS.md §Perf)."""

    k: Array        # int8 (B, L, KV, Dh)
    v: Array        # int8
    k_scale: Array  # f32 (B, L, KV, 1)
    v_scale: Array  # f32


def _quant(x: Array) -> tuple[Array, Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) \
        / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def quantize_kv(kvc: KVCache) -> QuantKVCache:
    kq, ks = _quant(kvc.k)
    vq, vs = _quant(kvc.v)
    return QuantKVCache(k=kq, v=vq, k_scale=ks, v_scale=vs)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    e = cfg.d_model
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], e, (e, h * dh), dt),
        "wk": dense_init(ks[1], e, (e, kv * dh), dt),
        "wv": dense_init(ks[2], e, (e, kv * dh), dt),
        "wo": dense_init(ks[3], h * dh, (h * dh, e), dt),
    }
    if cfg.qkv_bias and not cross:
        p.update(bq=zeros((h * dh,), dt), bk=zeros((kv * dh,), dt),
                 bv=zeros((kv * dh,), dt))
    return p


def _split_heads(x: Array, n: int, dh: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, dh)


def _proj_qkv(p: dict, xq: Array, xkv: Array, cfg: ModelConfig
              ) -> tuple[Array, Array, Array]:
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (_split_heads(q, h, dh), _split_heads(k, kv, dh),
            _split_heads(v, kv, dh))


# ---------------------------------------------------------------------------
# full-sequence (train / prefill)
# ---------------------------------------------------------------------------

def self_attention(p: dict, x: Array, positions: Array, cfg: ModelConfig,
                   spec: LayerSpec) -> tuple[Array, KVCache]:
    """x (B,S,E), positions (S,) -> (out (B,S,E), full-length KVCache)."""
    q, k, v = _proj_qkv(p, x, x, cfg)
    if cfg.rope_theta is not None:
        cos, sin = rope_cos_sin(positions, cfg.head_dim_, cfg.rope_theta,
                                dtype=jnp.float32)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    out = ops.flash_attention(q, k, v, causal=True, window=spec.window,
                              logits_soft_cap=cfg.logits_soft_cap)
    out = out.reshape(x.shape[0], x.shape[1], -1)
    return out @ p["wo"], KVCache(k=k, v=v)


def cross_attention(p: dict, x: Array, enc_k: Array, enc_v: Array,
                    cfg: ModelConfig) -> Array:
    """x (B,S,E) queries vs precomputed encoder K/V (B,F,KV,Dh)."""
    h, dh = cfg.n_heads, cfg.head_dim_
    q = _split_heads(x @ p["wq"], h, dh)
    out = ops.flash_attention(q, enc_k, enc_v, causal=False, window=None)
    out = out.reshape(x.shape[0], x.shape[1], -1)
    return out @ p["wo"]


def encode_cross_kv(p: dict, enc_states: Array, cfg: ModelConfig) -> KVCache:
    kv, dh = cfg.n_kv_heads, cfg.head_dim_
    k = _split_heads(enc_states @ p["wk"], kv, dh)
    v = _split_heads(enc_states @ p["wv"], kv, dh)
    return KVCache(k=k, v=v)


def prefill_cache(kvc: KVCache, spec: LayerSpec) -> KVCache:
    """Convert a full-length prefill KV to the decode cache layout.

    Ring-buffer layers keep only the last ``window`` positions; because
    S % window == 0 there, slot s holds position S - window + s == s (mod w).
    """
    if spec.window is None:
        return kvc
    s = kvc.k.shape[1]
    w = spec.window
    if s <= w:
        return kvc
    assert s % w == 0, (s, w)
    return KVCache(k=kvc.k[:, -w:], v=kvc.v[:, -w:])


def grow_cache(kvc: KVCache, spec: LayerSpec, max_len: int) -> KVCache:
    """Pad a prefill cache out to decode capacity (full-attention layers)."""
    if spec.window is not None:
        return kvc  # ring buffers are already at capacity
    b, s, kv, dh = kvc.k.shape
    if s >= max_len:
        return kvc
    pad = [(0, 0), (0, max_len - s), (0, 0), (0, 0)]
    return KVCache(k=jnp.pad(kvc.k, pad), v=jnp.pad(kvc.v, pad))


# ---------------------------------------------------------------------------
# decode (single token vs cache)
# ---------------------------------------------------------------------------

def self_attention_decode(p: dict, x1: Array, cache, pos: Array,
                          cfg: ModelConfig, spec: LayerSpec):
    """x1 (B,1,E); pos: scalar int32. cache: KVCache or QuantKVCache."""
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q, k, v = _proj_qkv(p, x1, x1, cfg)
    if cfg.rope_theta is not None:
        cos, sin = rope_cos_sin(pos[None], dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    quant = isinstance(cache, QuantKVCache)
    lcache = cache.k.shape[1]
    slot = pos % lcache if spec.window is not None else pos
    if quant:
        kq, ks = _quant(k)
        vq, vs = _quant(v)
        new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, kq, slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, vq, slot, axis=1)
        new_ks = jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ks, slot,
                                                     axis=1)
        new_vs = jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vs, slot,
                                                     axis=1)
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)

    # positions actually held by each slot (ring-aware), for masking
    slots = jnp.arange(lcache)
    if spec.window is not None:
        held = pos - jnp.mod(pos - slots, lcache)   # largest p<=pos, p%L==slot
        valid = (held >= 0) & (held > pos - spec.window) & (held <= pos)
    else:
        valid = slots <= pos

    group = h // kv
    # cache stays in its storage dtype; accumulation in f32 via the einsum
    # (casting a 32k-deep cache to f32 would double decode HBM traffic)
    qg = (q * dh ** -0.5).reshape(q.shape[0], kv, group, dh)   # (B,KV,G,Dh)
    if quant:
        # scales factor out of the per-slot dot products exactly
        logits = jnp.einsum("bkgd,blkd->bkgl", qg.astype(jnp.bfloat16),
                            new_k.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        logits = logits * new_ks[..., 0].transpose(0, 2, 1)[:, :, None, :]
    else:
        logits = jnp.einsum("bkgd,blkd->bkgl", qg.astype(new_k.dtype), new_k,
                            preferred_element_type=jnp.float32)
    if cfg.logits_soft_cap is not None:
        logits = cfg.logits_soft_cap * jnp.tanh(logits / cfg.logits_soft_cap)
    logits = jnp.where(valid[None, None, None, :], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if quant:
        pw = probs * new_vs[..., 0].transpose(0, 2, 1)[:, :, None, :]
        out = jnp.einsum("bkgl,blkd->bkgd", pw.astype(jnp.bfloat16),
                         new_v.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
        new_cache = QuantKVCache(k=new_k, v=new_v, k_scale=new_ks,
                                 v_scale=new_vs)
    else:
        out = jnp.einsum("bkgl,blkd->bkgd", probs.astype(new_v.dtype), new_v,
                         preferred_element_type=jnp.float32)
        new_cache = KVCache(k=new_k, v=new_v)
    out = out.reshape(x1.shape[0], 1, h * dh).astype(x1.dtype)
    return out @ p["wo"], new_cache


def cross_attention_decode(p: dict, x1: Array, cross: KVCache,
                           cfg: ModelConfig) -> Array:
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    group = h // kv
    q = _split_heads(x1 @ p["wq"], h, dh).astype(jnp.float32) * dh ** -0.5
    qg = q.reshape(q.shape[0], kv, group, dh)
    logits = jnp.einsum("bkgd,blkd->bkgl", qg, cross.k.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgl,blkd->bkgd", probs, cross.v.astype(jnp.float32))
    out = out.reshape(x1.shape[0], 1, h * dh).astype(x1.dtype)
    return out @ p["wo"]
