"""RG-LRU temporal-mixing block (Griffin / recurrentgemma).

Block: in-branch linear -> causal conv(4) -> RG-LRU recurrence; gate branch
linear -> gelu; merged = rglru_out * gate -> out projection.

RG-LRU recurrence (per channel, c = 8):
    i_t = sigmoid(x_t W_in)            input gate
    r_t = sigmoid(x_t W_rec)           recurrence gate
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Linear in h -> associative scan over time; O(1) decode state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel import constrain

from .layers import dense_init, zeros
from .ssm import _conv_scan

Array = jax.Array
_C = 8.0


class RGLRUState(NamedTuple):
    h: Array      # (B, W) float32 recurrent state
    conv: Array   # (B, conv_width-1, W)


def init_rglru(key, cfg: ModelConfig) -> dict:
    e, w = cfg.d_model, cfg.lru_width_
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    # Lambda init so a ~ Uniform(0.9, 0.999)^c at r=1 (griffin appendix)
    u = jax.random.uniform(ks[5], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    return {
        "in_proj": dense_init(ks[0], e, (e, w), dt),
        "gate_proj": dense_init(ks[1], e, (e, w), dt),
        "conv_w": dense_init(ks[2], cfg.conv_width, (w, cfg.conv_width), dt),
        "conv_b": zeros((w,), dt),
        "lru_in_gate": dense_init(ks[3], w, (w, w), dt),
        "lru_rec_gate": dense_init(ks[4], w, (w, w), dt),
        "lru_a": lam,
        "out_proj": dense_init(ks[0], w, (w, e), dt),
    }


def _gates(p: dict, xc: Array) -> tuple[Array, Array]:
    i = jax.nn.sigmoid(xc @ p["lru_in_gate"])
    r = jax.nn.sigmoid(xc @ p["lru_rec_gate"])
    a = jnp.exp(-_C * jax.nn.softplus(p["lru_a"]).astype(jnp.float32)
                * r.astype(jnp.float32))
    return i, a


def rglru_forward(p: dict, x: Array, cfg: ModelConfig
                  ) -> tuple[Array, RGLRUState]:
    """x (B, L, E) -> (out (B, L, E), final state)."""
    xs = x @ p["in_proj"]
    xs = constrain(xs, "batch", None, "model")
    gate = jax.nn.gelu(x @ p["gate_proj"], approximate=True)
    xc = _conv_scan(xs, p["conv_w"], p["conv_b"], tail=None)
    i, a = _gates(p, xc)
    drive = (jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12))
             * (i * xc).astype(jnp.float32))

    def combine(u, v):
        au, bu = u
        av, bv = v
        return au * av, bu * av + bv

    _, h = jax.lax.associative_scan(combine, (a, drive), axis=1)
    y = (h.astype(x.dtype) * gate) @ p["out_proj"]
    kc = cfg.conv_width - 1
    tail = jax.lax.dynamic_slice_in_dim(xs, xs.shape[1] - kc, kc, axis=1)
    return y, RGLRUState(h=h[:, -1], conv=tail.astype(jnp.float32))


def rglru_step(p: dict, x1: Array, state: RGLRUState, cfg: ModelConfig
               ) -> tuple[Array, RGLRUState]:
    """Single-token decode. x1 (B, 1, E)."""
    xs = x1 @ p["in_proj"]                               # (B,1,W)
    gate = jax.nn.gelu(x1 @ p["gate_proj"], approximate=True)
    window = jnp.concatenate([state.conv.astype(xs.dtype), xs], axis=1)
    xc = (jnp.einsum("bkw,wk->bw", window, p["conv_w"]) + p["conv_b"])[:, None]
    i, a = _gates(p, xc)
    drive = (jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12))
             * (i * xc).astype(jnp.float32))[:, 0]
    h = a[:, 0] * state.h + drive
    y = (h[:, None].astype(x1.dtype) * gate) @ p["out_proj"]
    return y, RGLRUState(h=h, conv=window[:, 1:].astype(jnp.float32))
