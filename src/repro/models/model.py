"""Unified model: init / train-loss / prefill / decode / denoise for every
zoo architecture (DESIGN.md §4-5).

Layer stack = pattern-grouped scan (HLO size O(1) in depth) + unrolled
remainder.  Caches mirror the params layout so decode scans over
(params, cache) jointly.  The ``denoise`` path is the diffusion-LM mode the
paper's technique corrects (sigma-FiLM conditioning + eps head).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.parallel import constrain

from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import (apply_film_cond, apply_mlp, apply_norm, dense_init,
                     init_film, init_mlp, init_norm)

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": init_norm(cfg)}
    if spec.kind == "attn":
        p["attn"] = attn.init_attention(ks[0], cfg)
    elif spec.kind == "mamba":
        p["mamba"] = ssm_mod.init_mamba(ks[0], cfg)
    elif spec.kind == "rglru":
        p["rglru"] = rglru_mod.init_rglru(ks[0], cfg)
    else:
        raise ValueError(spec.kind)
    if spec.cross_attn:
        p["lnc"] = init_norm(cfg)
        p["cross"] = attn.init_attention(ks[1], cfg, cross=True)
    if spec.kind != "mamba" and cfg.d_ff > 0:
        p["ln2"] = init_norm(cfg)
        if cfg.n_experts > 0:
            p["moe"] = moe_mod.init_moe(ks[2], cfg)
        else:
            p["mlp"] = init_mlp(ks[2], cfg)
    return p


def init_params(key, cfg: ModelConfig, with_diffusion_head: bool = False) -> dict:
    dt = jnp.dtype(cfg.dtype)
    n_pat = len(cfg.pattern)
    keys = jax.random.split(key, 5 + n_pat + cfg.n_remainder)

    params: dict[str, Any] = {
        "tok_embed": dense_init(keys[0], cfg.d_model,
                                (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": init_norm(cfg),
    }
    if cfg.rope_theta is None and cfg.pattern[0].kind == "attn":
        params["pos_embed"] = dense_init(
            keys[1], cfg.d_model, (cfg.max_position, cfg.d_model), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[2], cfg.d_model, (cfg.d_model, cfg.vocab_size), dt)

    blocks = []
    for pos, spec in enumerate(cfg.pattern):
        if cfg.n_groups == 0:
            blocks.append(None)
            continue
        gks = jax.random.split(keys[5 + pos], cfg.n_groups)
        blocks.append(jax.vmap(
            lambda k, s=spec: init_block(k, cfg, s))(gks))
    params["blocks"] = tuple(blocks)
    params["tail"] = tuple(
        init_block(keys[5 + n_pat + i], cfg, cfg.pattern[i])
        for i in range(cfg.n_remainder))
    if with_diffusion_head:
        params["diffusion"] = init_film(keys[3], cfg)
    return params


def param_specs(cfg: ModelConfig, with_diffusion_head: bool = False):
    """ShapeDtypeStruct pytree of the params (no allocation — dry-run path)."""
    return jax.eval_shape(
        lambda: init_params(jax.random.key(0), cfg, with_diffusion_head))


# ---------------------------------------------------------------------------
# block application (full sequence)
# ---------------------------------------------------------------------------

def _modulate(h: Array, t_cond: Optional[Array]) -> Array:
    if t_cond is None:
        return h
    scale, shift = jnp.split(t_cond[:, None, :], 2, axis=-1)
    return h * (1.0 + scale) + shift


def apply_block(p: dict, x: Array, cfg: ModelConfig, spec: LayerSpec,
                positions: Array, enc_states: Optional[Array] = None,
                t_cond: Optional[Array] = None) -> tuple[Array, dict]:
    aux: dict[str, Array] = {}
    h = _modulate(apply_norm(p["ln1"], x, cfg), t_cond)
    if spec.kind == "attn":
        mix, _ = attn.self_attention(p["attn"], h, positions, cfg, spec)
    elif spec.kind == "mamba":
        mix, _ = ssm_mod.mamba_forward(p["mamba"], h, cfg)
    else:
        mix, _ = rglru_mod.rglru_forward(p["rglru"], h, cfg)
    x = x + mix
    x = constrain(x, "batch", "seq", None)
    if spec.cross_attn and enc_states is not None:
        hc = apply_norm(p["lnc"], x, cfg)
        ckv = attn.encode_cross_kv(p["cross"], enc_states, cfg)
        x = x + attn.cross_attention(p["cross"], hc, ckv.k, ckv.v, cfg)
    if "mlp" in p or "moe" in p:
        h2 = _modulate(apply_norm(p["ln2"], x, cfg), t_cond)
        if "moe" in p:
            y, aux = moe_mod.apply_moe(p["moe"], h2, cfg)
        else:
            y = apply_mlp(p["mlp"], h2, cfg)
        x = x + y
        x = constrain(x, "batch", "seq", None)
    return x, aux


def _remat_group_size(n_groups: int, target: int = 8) -> int:
    """Largest divisor of n_groups <= target (keeps >= 2 scan steps)."""
    best = 1
    for k in range(2, target + 1):
        if n_groups % k == 0 and n_groups // k >= 2:
            best = k
    return best


def _stack_forward(params: dict, x: Array, cfg: ModelConfig, positions: Array,
                   enc_states: Optional[Array] = None,
                   t_cond: Optional[Array] = None,
                   remat: str = "none",
                   remat_group: int = 1) -> tuple[Array, dict]:
    """Scan over pattern groups + unrolled remainder. Returns (x, aux).

    remat: "none" | "full" (recompute everything in backward — training at
    scale) | "dots" (keep matmul outputs, recompute the rest).
    remat_group: scan over super-groups of this many pattern groups — the
    saved-activation stack shrinks by the same factor (recompute grows within
    the super-group).  0 -> auto (divisor of n_groups up to 8).
    """
    aux_acc = {"load_balance_loss": jnp.zeros((), jnp.float32),
               "dropped_fraction": jnp.zeros((), jnp.float32)}

    def one_group(x, gp):
        a = {k: jnp.zeros((), jnp.float32) for k in aux_acc}
        for pos, spec in enumerate(cfg.pattern):
            x, aux = apply_block(gp[pos], x, cfg, spec, positions,
                                 enc_states, t_cond)
            for k, v in aux.items():
                a[k] = a[k] + v.astype(jnp.float32)
        return x, a

    k_group = remat_group if remat_group else _remat_group_size(cfg.n_groups)
    if cfg.n_groups % max(k_group, 1) != 0:
        k_group = 1

    def super_group(x, sgp):
        a = {k: jnp.zeros((), jnp.float32) for k in aux_acc}
        for i in range(k_group):
            gp = jax.tree.map(lambda t: t[i], sgp) if k_group > 1 else sgp
            x, aux = one_group(x, gp)
            for k, v in aux.items():
                a[k] = a[k] + v
        return x, a

    if remat == "full":
        super_group = jax.checkpoint(super_group, prevent_cse=False)
    elif remat == "dots":
        super_group = jax.checkpoint(
            super_group, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if cfg.n_groups > 0:
        blocks = params["blocks"]
        if k_group > 1:
            blocks = jax.tree.map(
                lambda t: t.reshape((cfg.n_groups // k_group, k_group)
                                    + t.shape[1:]), blocks)
        x, auxs = jax.lax.scan(super_group, x, blocks)
        for k in aux_acc:
            aux_acc[k] = aux_acc[k] + jnp.sum(auxs[k])
    for i in range(cfg.n_remainder):
        x, aux = apply_block(params["tail"][i], x, cfg, cfg.pattern[i],
                             positions, enc_states, t_cond)
        for k, v in aux.items():
            aux_acc[k] = aux_acc[k] + v.astype(jnp.float32)
    n_moe_layers = max(cfg.n_layers if cfg.n_experts else 1, 1)
    aux_acc = {k: v / n_moe_layers for k, v in aux_acc.items()}
    return x, aux_acc


def _embed(params: dict, tokens: Array, cfg: ModelConfig,
           positions: Array) -> Array:
    x = params["tok_embed"][tokens]
    if "pos_embed" in params:
        x = x + params["pos_embed"][positions][None]
    return constrain(x, "batch", "seq", None)


def _logits(params: dict, x: Array, cfg: ModelConfig) -> Array:
    head = params["lm_head"] if "lm_head" in params else params["tok_embed"].T
    # vocab-TP logits: gather the (SP-sharded) hidden over seq, shard the
    # vocab dim instead — keeps the lm_head backward a local partial matmul
    # + small all-reduce rather than a replicated (E, V) f32 gradient
    x = constrain(x, "batch", None, None)
    logits = (x @ head).astype(jnp.float32)
    return constrain(logits, "batch", None, "model")


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def hidden_states(params: dict, tokens: Array, cfg: ModelConfig,
                  prefix_embeds: Optional[Array] = None,
                  enc_states: Optional[Array] = None,
                  remat: str = "none",
                  remat_group: int = 1) -> tuple[Array, dict]:
    """Final-norm hidden states (B, S_total, E) + aux (no logits)."""
    s = tokens.shape[1]
    prefix = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    positions = jnp.arange(prefix + s)
    x = _embed(params, tokens, cfg, positions[prefix:])
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x, aux = _stack_forward(params, x, cfg, positions, enc_states,
                            remat=remat, remat_group=remat_group)
    return apply_norm(params["final_norm"], x, cfg), aux


def forward(params: dict, tokens: Array, cfg: ModelConfig,
            prefix_embeds: Optional[Array] = None,
            enc_states: Optional[Array] = None,
            remat: str = "none") -> tuple[Array, dict]:
    """Causal LM forward. tokens (B, S) -> (logits (B, S_total, V), aux)."""
    x, aux = hidden_states(params, tokens, cfg, prefix_embeds, enc_states,
                           remat=remat)
    return _logits(params, x, cfg), aux


def _ce_chunk(params, x_chunk: Array, lab_chunk: Array, cfg: ModelConfig,
              ce_dtype: str = "float32") -> tuple[Array, Array]:
    """Sum-NLL + valid-count for one sequence chunk (vocab-sharded logits).

    ce_dtype="bfloat16" keeps the materialised logits buffer in bf16 (halving
    the CE HBM traffic of huge-vocab models); the logsumexp/NLL reductions
    still accumulate in f32 (the converts fuse — nothing f32 materialises).
    """
    head = params["lm_head"] if "lm_head" in params else params["tok_embed"].T
    xg = constrain(x_chunk, "batch", None, None)
    logits = (xg @ head).astype(jnp.dtype(ce_dtype))
    logits = constrain(logits, "batch", None, "model")
    valid = (lab_chunk >= 0)
    lab = jnp.where(valid, lab_chunk, 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    one_hot = jax.nn.one_hot(lab, logits.shape[-1], dtype=logits.dtype)
    one_hot = constrain(one_hot, "batch", None, "model")
    picked = jnp.einsum("bsv,bsv->bs", logits, one_hot,
                        preferred_element_type=jnp.float32)
    nll = lse - picked
    return (jnp.sum(jnp.where(valid, nll, 0.0)),
            jnp.sum(valid).astype(jnp.float32))


def ce_loss(params: dict, x: Array, labels: Array, cfg: ModelConfig,
            seq_chunk: int = 1024, ce_dtype: str = "float32") -> Array:
    """Chunked cross-entropy: the (B, chunk, V) logits exist one chunk at
    a time (forward AND backward — the chunk body is rematted), instead of a
    (B, S, V) f32 buffer.  Falls back to one chunk for short sequences."""
    s = labels.shape[1]
    if s % seq_chunk != 0 or s <= seq_chunk:
        tot, cnt = _ce_chunk(params, x, labels, cfg, ce_dtype)
        return tot / jnp.maximum(cnt, 1.0)

    body = jax.checkpoint(
        lambda carry, xs: ((carry[0] + (r := _ce_chunk(params, xs[0], xs[1],
                                                       cfg, ce_dtype))[0],
                            carry[1] + r[1]), None),
        prevent_cse=False)
    n = s // seq_chunk
    xs = x.reshape(x.shape[0], n, seq_chunk, -1).swapaxes(0, 1)
    ls = labels.reshape(labels.shape[0], n, seq_chunk).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params: dict, batch: dict, cfg: ModelConfig,
            remat: str = "none", remat_group: int = 1,
            seq_chunk: int = 1024, ce_dtype: str = "float32"
            ) -> tuple[Array, dict]:
    """Next-token CE. batch: tokens (B,S), labels (B,S; <0 = ignore),
    optional prefix_embeds / enc_states.  Returns (loss, metrics)."""
    x, aux = hidden_states(params, batch["tokens"], cfg,
                           prefix_embeds=batch.get("prefix_embeds"),
                           enc_states=batch.get("enc_states"), remat=remat,
                           remat_group=remat_group)
    labels = batch["labels"]
    if x.shape[1] != labels.shape[1]:            # VLM prefix positions
        x = x[:, -labels.shape[1]:]
    loss = ce_loss(params, x, labels, cfg, seq_chunk=seq_chunk,
                   ce_dtype=ce_dtype)
    metrics = {"ce_loss": loss, **aux}
    if cfg.n_experts > 0:
        loss = loss + 1e-2 * aux["load_balance_loss"]
    return loss, metrics


# ----- serving -----

class Cache(NamedTuple):
    blocks: tuple          # per pattern position, stacked over groups
    tail: tuple            # per remainder layer
    cross: Optional[tuple] # per pattern position (whisper)
    cross_tail: Optional[tuple]
    pos: Array             # next position (scalar int32)


def _layer_cache_from_prefill(kind_cache, spec: LayerSpec, max_len: int,
                              cache_dtype: str = "native"):
    if isinstance(kind_cache, attn.KVCache):
        c = attn.prefill_cache(kind_cache, spec)
        c = attn.grow_cache(c, spec, max_len)
        if cache_dtype == "int8":
            c = attn.quantize_kv(c)
        return c
    return kind_cache  # Mamba/RGLRU states are already O(1)


def apply_block_prefill(p: dict, x: Array, cfg: ModelConfig, spec: LayerSpec,
                        positions: Array, max_len: int,
                        enc_states: Optional[Array] = None,
                        cache_dtype: str = "native"):
    """Like apply_block but returns the decode-layout cache (+cross KV)."""
    h = apply_norm(p["ln1"], x, cfg)
    cross_kv = None
    if spec.kind == "attn":
        mix, kvc = attn.self_attention(p["attn"], h, positions, cfg, spec)
        cache = _layer_cache_from_prefill(kvc, spec, max_len, cache_dtype)
    elif spec.kind == "mamba":
        mix, cache = ssm_mod.mamba_forward(p["mamba"], h, cfg)
    else:
        mix, cache = rglru_mod.rglru_forward(p["rglru"], h, cfg)
    x = x + mix
    if spec.cross_attn and enc_states is not None:
        hc = apply_norm(p["lnc"], x, cfg)
        cross_kv = attn.encode_cross_kv(p["cross"], enc_states, cfg)
        x = x + attn.cross_attention(p["cross"], hc, cross_kv.k, cross_kv.v, cfg)
    if "mlp" in p or "moe" in p:
        h2 = apply_norm(p["ln2"], x, cfg)
        if "moe" in p:
            y, _ = moe_mod.apply_moe(p["moe"], h2, cfg)
        else:
            y = apply_mlp(p["mlp"], h2, cfg)
        x = x + y
    return x, cache, cross_kv


def prefill(params: dict, tokens: Array, cfg: ModelConfig, max_len: int,
            prefix_embeds: Optional[Array] = None,
            enc_states: Optional[Array] = None,
            cache_dtype: str = "native") -> tuple[Array, Cache]:
    """Process the prompt; returns (last-token logits (B,V), decode Cache).

    cache_dtype="int8" quantises the attention KV caches (per-slot, per-head
    scales) — the §Perf serving-memory optimization."""
    s = tokens.shape[1]
    prefix = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    positions = jnp.arange(prefix + s)
    x = _embed(params, tokens, cfg, positions[prefix:])
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)

    def one_group(x, gp):
        caches, crosses = [], []
        for pos, spec in enumerate(cfg.pattern):
            x, c, ckv = apply_block_prefill(gp[pos], x, cfg, spec, positions,
                                            max_len, enc_states, cache_dtype)
            caches.append(c)
            crosses.append(ckv)
        return x, (tuple(caches), tuple(crosses))

    block_caches, cross_caches = (), ()
    if cfg.n_groups > 0:
        x, (block_caches, cross_caches) = jax.lax.scan(
            one_group, x, params["blocks"])
    tail_caches, tail_cross = [], []
    for i in range(cfg.n_remainder):
        x, c, ckv = apply_block_prefill(params["tail"][i], x, cfg,
                                        cfg.pattern[i], positions, max_len,
                                        enc_states, cache_dtype)
        tail_caches.append(c)
        tail_cross.append(ckv)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = _logits(params, x[:, -1:], cfg)[:, 0]
    has_cross = any(sp.cross_attn for sp in cfg.pattern) and enc_states is not None
    cache = Cache(
        blocks=block_caches,
        tail=tuple(tail_caches),
        cross=cross_caches if has_cross else None,
        cross_tail=tuple(tail_cross) if has_cross else None,
        pos=jnp.asarray(prefix + s, jnp.int32),
    )
    return logits, cache


def apply_block_decode(p: dict, x1: Array, cache, cross_kv, pos: Array,
                       cfg: ModelConfig, spec: LayerSpec):
    h = apply_norm(p["ln1"], x1, cfg)
    if spec.kind == "attn":
        mix, cache = attn.self_attention_decode(p["attn"], h, cache, pos,
                                                cfg, spec)
    elif spec.kind == "mamba":
        mix, cache = ssm_mod.mamba_step(p["mamba"], h, cache, cfg)
    else:
        mix, cache = rglru_mod.rglru_step(p["rglru"], h, cache, cfg)
    x1 = x1 + mix
    if spec.cross_attn and cross_kv is not None:
        hc = apply_norm(p["lnc"], x1, cfg)
        x1 = x1 + attn.cross_attention_decode(p["cross"], hc, cross_kv, cfg)
    if "mlp" in p or "moe" in p:
        h2 = apply_norm(p["ln2"], x1, cfg)
        if "moe" in p:
            y, _ = moe_mod.apply_moe(p["moe"], h2, cfg)
        else:
            y = apply_mlp(p["mlp"], h2, cfg)
        x1 = x1 + y
    return x1, cache


def decode_step(params: dict, cache: Cache, token: Array, cfg: ModelConfig
                ) -> tuple[Array, Cache]:
    """One AR step. token (B,) int32 -> (logits (B, V), updated cache)."""
    pos = cache.pos
    x = params["tok_embed"][token][:, None, :]            # (B,1,E)
    if "pos_embed" in params:
        x = x + params["pos_embed"][pos][None, None]
    x = constrain(x, "batch", None, None)

    def one_group(x, xs):
        gp, gcache, gcross = xs
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            ckv = gcross[i] if gcross is not None else None
            x, c = apply_block_decode(gp[i], x, gcache[i], ckv, pos, cfg, spec)
            new_caches.append(c)
        return x, tuple(new_caches)

    new_blocks = cache.blocks
    if cfg.n_groups > 0:
        cross_xs = cache.cross if cache.cross is not None \
            else tuple(None for _ in cfg.pattern)
        x, new_blocks = jax.lax.scan(
            one_group, x, (params["blocks"], cache.blocks, cross_xs))
    new_tail = []
    for i in range(cfg.n_remainder):
        ckv = cache.cross_tail[i] if cache.cross_tail is not None else None
        x, c = apply_block_decode(params["tail"][i], x, cache.tail[i], ckv,
                                  pos, cfg, cfg.pattern[i])
        new_tail.append(c)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = _logits(params, x, cfg)[:, 0]
    new_cache = Cache(blocks=new_blocks, tail=tuple(new_tail),
                      cross=cache.cross, cross_tail=cache.cross_tail,
                      pos=pos + 1)
    return logits, new_cache


# ----- diffusion-LM mode (the paper's serving path) -----

def denoise(params: dict, x_sigma: Array, sigma: Array, cfg: ModelConfig
            ) -> Array:
    """Raw denoiser F(x; sigma): x (B,S,E), sigma (B,) -> (B,S,E).

    EDM preconditioning (c_in/c_skip/c_out) lives in diffusion/edm.py; PAS
    consumes the resulting eps via repro.diffusion.lm_eps_fn.
    """
    if "diffusion" not in params:
        raise ValueError("init_params(..., with_diffusion_head=True) required")
    pd = params["diffusion"]
    t_cond = apply_film_cond(pd, sigma, cfg)
    x = x_sigma.astype(jnp.dtype(cfg.dtype)) @ pd["head_in"]
    x = constrain(x, "batch", "seq", None)
    positions = jnp.arange(x.shape[1])
    x, _ = _stack_forward(params, x, cfg, positions, t_cond=t_cond)
    x = apply_norm(params["final_norm"], x, cfg)
    return x @ pd["head_out"]
