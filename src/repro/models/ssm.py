"""Mamba-1 block (falcon-mamba): depthwise causal conv + selective scan.

The block has no separate MLP (d_ff == 0): norm -> mamba -> residual.
Prefill returns the recurrent state + conv tail so decode continues exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.parallel import constrain

from .layers import dense_init, zeros

Array = jax.Array


class MambaState(NamedTuple):
    h: Array           # (B, Di, N) float32 SSM state
    conv: Array        # (B, d_conv-1, Di) trailing pre-conv inputs


def init_mamba(key, cfg: ModelConfig) -> dict:
    e, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A; dt bias ~ softplus^-1 of [1e-3, 1e-1]
    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                      (di, n)))
    u = jax.random.uniform(ks[5], (di,), minval=1e-3, maxval=1e-1)
    dt_bias = jnp.log(jnp.expm1(u))
    return {
        "in_proj": dense_init(ks[0], e, (e, 2 * di), dt),
        "conv_w": dense_init(ks[1], cfg.d_conv, (di, cfg.d_conv), dt),
        "conv_b": zeros((di,), dt),
        "x_proj": dense_init(ks[2], di, (di, r + 2 * n), dt),
        "dt_proj": dense_init(ks[3], r, (r, di), dt),
        "dt_bias": dt_bias,
        "a_log": a_init,
        "skip_d": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, (di, e), dt),
    }


def _conv_scan(xs: Array, w: Array, b: Array, tail: Array | None) -> Array:
    """Depthwise causal conv1d. xs (B, L, Di), w (Di, K) -> (B, L, Di)."""
    k = w.shape[-1]
    if tail is None:
        pad = jnp.zeros((xs.shape[0], k - 1, xs.shape[2]), xs.dtype)
    else:
        pad = tail.astype(xs.dtype)
    xp = jnp.concatenate([pad, xs], axis=1)  # (B, L+K-1, Di)
    out = sum(xp[:, i:i + xs.shape[1]] * w[:, i] for i in range(k))
    return out + b


def _ssm_inputs(p: dict, xc: Array, cfg: ModelConfig):
    n, r = cfg.ssm_state, cfg.dt_rank_
    xdb = xc @ p["x_proj"]
    dt_r, bmat, cmat = jnp.split(xdb, [r, r + n], axis=-1)
    delta = jax.nn.softplus(dt_r @ p["dt_proj"]
                            + p["dt_bias"].astype(dt_r.dtype))
    a = -jnp.exp(p["a_log"])
    return delta, a, bmat, cmat


def mamba_forward(p: dict, x: Array, cfg: ModelConfig
                  ) -> tuple[Array, MambaState]:
    """x (B, L, E) -> (out (B, L, E), final MambaState)."""
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", None, "model")
    xc = jax.nn.silu(_conv_scan(xs, p["conv_w"], p["conv_b"], tail=None))
    delta, a, bmat, cmat = _ssm_inputs(p, xc, cfg)
    y, h_last = ops.ssm_scan(xc, delta, a, bmat, cmat, d=p["skip_d"])
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    kc = cfg.d_conv - 1
    tail = jax.lax.dynamic_slice_in_dim(xs, xs.shape[1] - kc, kc, axis=1)
    return out, MambaState(h=h_last, conv=tail.astype(jnp.float32))


def mamba_step(p: dict, x1: Array, state: MambaState, cfg: ModelConfig
               ) -> tuple[Array, MambaState]:
    """Single-token decode. x1 (B, 1, E)."""
    xz = x1 @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                   # (B,1,Di)
    window = jnp.concatenate([state.conv.astype(xs.dtype), xs], axis=1)
    xc = jnp.einsum("bkd,dk->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)[:, None]                        # (B,1,Di)
    delta, a, bmat, cmat = _ssm_inputs(p, xc, cfg)
    decay = jnp.exp(delta[:, 0, :, None].astype(jnp.float32)
                    * a[None].astype(jnp.float32))       # (B,Di,N)
    drive = (delta[:, 0, :, None] * bmat[:, 0, None, :]
             * xc[:, 0, :, None]).astype(jnp.float32)
    h = decay * state.h + drive
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0].astype(jnp.float32))
    y = y + p["skip_d"] * xc[:, 0].astype(jnp.float32)
    y = (y.astype(x1.dtype) * jax.nn.silu(z[:, 0]))[:, None]
    out = y @ p["out_proj"]
    new_conv = window[:, 1:].astype(jnp.float32)
    return out, MambaState(h=h, conv=new_conv)
