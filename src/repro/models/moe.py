"""Mixture-of-Experts FFN: top-k routing with grouped, sort-based capacity
dispatch.

Routing groups: tokens are routed *per sequence* (leading batch dim), so the
argsort/bincount stay local to a data shard — a single global sort would force
XLA to all-gather every token (catastrophic at 1M tokens; observed 80+ GiB
per device before this formulation).  The dense (G, n_exp, capacity, E)
dispatch buffer is the production TPU pattern: batch groups shard over DP,
experts over the EP axis (all-to-all inserted by GSPMD at the group->expert
transpose); when n_experts doesn't divide the EP axis (mixtral's 8 on a
16-way axis) the capacity dim takes the axis instead (token-parallel experts).

FLOPs scale with top_k * capacity_factor, not n_experts.  Aux outputs:
Switch-style load-balance loss + dropped-token fraction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel import constrain

from .layers import dense_init

Array = jax.Array


def init_moe(key, cfg: ModelConfig) -> dict:
    e, f, n = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    gated = cfg.act in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], e, (e, n), jnp.float32),
        "experts": {
            "w1": dense_init(ks[1], e, (n, e, f), dt),
            "w2": dense_init(ks[2], f, (n, f, e), dt),
        },
    }
    if gated:
        p["experts"]["w3"] = dense_init(ks[3], e, (n, e, f), dt)
    if cfg.shared_expert:
        from .layers import init_mlp
        p["shared"] = init_mlp(ks[4], cfg)
    return p


def _expert_ffn(pe: dict, xb: Array, cfg: ModelConfig) -> Array:
    """xb (G, n_exp, cap, E) -> same, via batched expert matmuls."""
    h = jnp.einsum("gxcd,xdf->gxcf", xb, pe["w1"].astype(xb.dtype))
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("gxcd,xdf->gxcf", xb,
                                        pe["w3"].astype(xb.dtype))
    elif cfg.act == "geglu":
        h = jax.nn.gelu(h, approximate=True) * jnp.einsum(
            "gxcd,xdf->gxcf", xb, pe["w3"].astype(xb.dtype))
    else:
        h = jax.nn.gelu(h, approximate=True)
    h = constrain(h, "batch", "expert", "model", None)
    return jnp.einsum("gxcf,xfd->gxcd", h, pe["w2"].astype(xb.dtype))


def _route_group(xg: Array, router: Array, n: int, k: int, capacity: int):
    """One routing group (t, E): returns dispatch indices + gates (all local)."""
    t = xg.shape[0]
    logits = xg.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                 # (t, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    flat_expert = idx.reshape(-1)                       # (t*k,)
    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    counts = jnp.bincount(flat_expert, length=n)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k) - starts[sorted_expert]
    keep = rank < capacity
    token_of = order // k
    slot = jnp.where(keep, rank, 0)
    return sorted_expert, slot, keep, token_of, gate.reshape(-1)[order], probs, idx


def apply_moe(p: dict, x: Array, cfg: ModelConfig) -> tuple[Array, dict]:
    """x (B, S, E) -> (y (B, S, E), aux metrics)."""
    b, s, e = x.shape
    n, k = cfg.n_experts, cfg.moe_top_k
    # group per sequence when sequences are long enough to fill experts;
    # tiny-token calls (decode: S == 1) route as a single group
    if s >= 4 * n:
        g, t = b, s
    else:
        g, t = 1, b * s
    xg = x.reshape(g, t, e)

    capacity = int(max(1, round(cfg.capacity_factor * t * k / n)))
    capacity = -(-capacity // 8) * 8

    sorted_e, slot, keep, token_of, gate_s, probs, idx = jax.vmap(
        lambda xx: _route_group(xx, p["router"], n, k, capacity))(xg)

    def scatter_raw(xg_i, se, sl, kp, tok):
        buf = jnp.zeros((n, capacity, e), x.dtype)
        return buf.at[se, sl].add(jnp.where(kp[:, None], xg_i[tok], 0))

    buf = jax.vmap(scatter_raw)(xg, sorted_e, slot, keep, token_of)
    buf = constrain(buf, "batch", "expert", "model", None)

    yb = _expert_ffn(p["experts"], buf, cfg)            # (G, n, cap, E)
    yb = constrain(yb, "batch", "expert", "model", None)

    def combine_group(yb_i, se, sl, kp, tok, gs):
        y_tok = yb_i[se, sl] * jnp.where(kp, gs, 0.0)[:, None].astype(x.dtype)
        return jnp.zeros((t, e), x.dtype).at[tok].add(y_tok)

    y = jax.vmap(combine_group)(yb, sorted_e, slot, keep, token_of, gate_s)
    y = y.reshape(b, s, e)

    if cfg.shared_expert:
        from .layers import apply_mlp
        y = y + apply_mlp(p["shared"], x, cfg)

    # Switch-style load-balance loss + drop fraction (monitoring / training)
    probs_flat = probs.reshape(-1, n)
    me = jnp.mean(probs_flat, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0].reshape(-1), n), axis=0)
    aux = {
        "load_balance_loss": n * jnp.sum(me * ce),
        "dropped_fraction": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux
