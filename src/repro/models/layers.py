"""Shared neural building blocks (pure JAX, no flax): norms, RoPE, MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.parallel import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, fan_in: int, shape, dtype) -> Array:
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def zeros(shape, dtype=jnp.float32) -> Array:
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig) -> dict:
    p = {"scale": zeros((cfg.d_model,))}
    if cfg.norm == "layernorm":
        p["bias"] = zeros((cfg.d_model,))
    return p


def apply_norm(p: dict, x: Array, cfg: ModelConfig) -> Array:
    if cfg.norm == "rmsnorm":
        return ops.rmsnorm(x, p["scale"], eps=cfg.norm_eps)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * (1.0 + p["scale"].astype(jnp.float32)) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: Array, head_dim: int, theta: float,
                 dtype=jnp.float32) -> tuple[Array, Array]:
    """positions (...,) -> cos/sin (..., head_dim//2)."""
    inv = theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x (B, S, H, Dh); cos/sin (S, Dh//2) or (B, S, Dh//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, Dh/2) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:              # (B, S, Dh/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig) -> dict:
    e, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], e, (e, f), dt),
         "w2": dense_init(ks[1], f, (f, e), dt)}
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = dense_init(ks[2], e, (e, f), dt)
    return p


def apply_mlp(p: dict, x: Array, cfg: ModelConfig) -> Array:
    h = x @ p["w1"]
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(h, approximate=True) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(h, approximate=True)
    h = constrain(h, "batch", None, "model")
    return h @ p["w2"]


# ---------------------------------------------------------------------------
# sigma conditioning (diffusion-LM mode)
# ---------------------------------------------------------------------------

def sigma_embedding(sigma: Array, dim: int) -> Array:
    """Sinusoidal embedding of log-sigma; sigma (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(1e4) / max(half - 1, 1)))
    ang = 0.25 * jnp.log(sigma.astype(jnp.float32))[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def init_film(key, cfg: ModelConfig) -> dict:
    e = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "head_in": dense_init(k1, e, (e, e), dt),
        "head_out": zeros((e, e), dt),   # zero-init output head (stable start)
        "t_mlp1": dense_init(k2, e, (e, e), dt),
        "t_mlp2": zeros((e, 2 * e), dt),  # zero-init FiLM (identity modulation)
    }


def apply_film_cond(p: dict, sigma: Array, cfg: ModelConfig) -> Array:
    """(B,) sigma -> (B, 2E) [scale||shift] modulation vector."""
    t = sigma_embedding(sigma, cfg.d_model).astype(jnp.dtype(cfg.dtype))
    return jax.nn.silu(t @ p["t_mlp1"]) @ p["t_mlp2"]
