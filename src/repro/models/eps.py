"""Mesh-placed eps models: zoo backbones as first-class diffusion eps fns.

This module promotes ``launch/serve``'s old private ``_diffusion_lm_eps``
helper into the real model/engine boundary: ``build_eps`` turns any zoo
architecture (``repro.configs.get_config``) into an :class:`EpsModel` — an
``eps(x_flat, t)`` callable in the EDM convention the engines consume, plus
the *one shared parameter tree* every lane / engine / ladder rung built from
it reuses.

Tensor parallelism composes with sampling DP here, not in the engines:

* **Params** are materialized once with the placement-free jitted
  initializer and then ``device_put`` onto per-leaf shardings from
  ``parallel.sharding.param_partition_specs``, so every placement of the
  same (arch, seq, seed) sees the bit-identical weight tree — tp=1, tp=4
  and the old replicated helper all agree (see ``_materialize_params`` for
  why init-then-place rather than sharded ``out_shardings``).
* **Activations** are constrained per layer: the zoo models already call
  ``parallel.sharding.constrain`` at every block; the eps closure enters an
  ``axis_rules`` context *inside its own body*, which is active whenever an
  engine traces the eps — including inside ``SamplingEngine`` /
  ``CalibrationEngine`` / ``AdaptiveEngine`` scans — so the backbone's TP
  collectives nest inside the compiled sampling program.
* **Engine buffers** stay (B, D) sharded over (dp, state) only; the TP axis
  (``MeshSpec.tp`` / mesh axis "tensor") is invisible to the solver math.
  Entering/leaving the backbone resharsd activations between the engine
  layout and the TP layout; XLA inserts the collectives.

Mesh tolerance: TP reshards weight contractions (heads / ff / expert dims),
which reassociates the reductions, so TP-vs-replicated outputs agree to
floating-point tolerance, not bitwise — see ``EPS_TP_TOL`` and
tests/test_backbone_mesh.py.  dp/state placement of the *engine* buffers
remains bit-exact, as before.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.parallel.mesh import MeshSpec
from repro.parallel.sharding import AxisRules, axis_rules, param_partition_specs

from . import model as _model

__all__ = ["EpsModel", "EPS_TP_TOL", "build_eps", "get_eps_model",
           "eps_axis_rules", "clear_eps_cache"]

# documented mesh tolerance for TP-vs-replicated eps outputs (fp32, reduced
# configs): TP reassociates head/ff/expert reductions.  Engine-level
# dp/state placement stays bit-exact; only backbone TP pays this.
EPS_TP_TOL = dict(rtol=2e-4, atol=2e-4)


def eps_axis_rules(mesh: jax.sharding.Mesh, spec: MeshSpec) -> AxisRules:
    """Logical->physical rules for a backbone running inside the sampler.

    The backbone's "batch" rides the engine's data-parallel axis, its
    "model"/"expert" (TP/EP) dims ride the dedicated ``tp_axis`` ("tensor").
    The engine's *state* axis is deliberately absent: it shards the
    flattened (B, D) sample dim, which has no meaning inside the backbone.
    """
    return AxisRules(mesh=mesh,
                     batch=(spec.batch_axis,),
                     model=(spec.tp_axis,),
                     fsdp=(),
                     expert=(spec.tp_axis,))


@dataclasses.dataclass(frozen=True)
class EpsModel:
    """A mesh-placed zoo backbone wrapped as a diffusion eps function.

    ``fn(x_flat, t) -> eps`` follows the engine convention: ``x_flat`` is
    the flattened ``(B, dim)`` state, ``t`` the sigma/time vector.  All
    consumers share ``params`` — one tree, materialized once, placed on the
    launch mesh (replicated when ``mesh.tp == 1``, TP-sharded otherwise).
    """

    fn: Callable[..., Any]
    dim: int
    params: Any
    cfg: Any
    arch: str
    seq: int
    seed: int
    mesh_spec: MeshSpec

    @property
    def model_key(self) -> str:
        """Identity for the persistent executable-serialization cache.

        Placement (mesh/tp) is *not* part of the model identity — the
        engine fingerprint already hashes the full ``MeshSpec`` — so the
        key names exactly what determines the weights: arch, geometry, seed.
        """
        return f"diffusion:{self.arch}:seq{self.seq}:seed{self.seed}:{self.dim}"

    @property
    def n_params(self) -> int:
        return sum(int(p.size) for p in jax.tree_util.tree_leaves(self.params))


def _materialize_params(cfg, seed: int, mesh_spec: MeshSpec):
    """Init the param tree, then place it onto the launch mesh.

    The initializer always runs as the plain jitted program — the same
    random stream regardless of placement — and the tree is then
    ``device_put`` onto per-leaf ``NamedSharding``s computed from
    ``param_partition_specs`` under :func:`eps_axis_rules`.  Init-then-place
    (rather than ``jax.jit(init, out_shardings=...)``) is deliberate: with
    the default (non-partitionable) threefry, sharded out_shardings let the
    SPMD partitioner split the RNG computation non-value-preservingly on
    meshes with a replicated axis (observed: dp>1 x tp>1 flipped the
    row-sharded leaves), and opting into ``jax_threefry_partitionable``
    changes the stream itself, breaking parity with pre-mesh checkpoints.
    Value identity across placements is the contract the parity tests pin.
    """
    init = lambda k: _model.init_params(k, cfg, with_diffusion_head=True)
    key = jax.random.key(seed)
    params = jax.jit(init)(key)
    if mesh_spec.is_single:
        return params
    mesh = mesh_spec.build()
    rules = eps_axis_rules(mesh, mesh_spec)
    abstract = jax.eval_shape(init, key)
    pspecs = param_partition_specs(abstract, rules)
    shardings = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), pspecs,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    return jax.device_put(params, shardings)


def build_eps(arch: str, *, seq: int = 32, seed: int = 0,
              mesh: Optional[MeshSpec] = None, reduced: bool = True,
              sigma_data: float = 1.0) -> EpsModel:
    """Build a mesh-placed diffusion-LM eps function from a zoo arch.

    The backbone runs in diffusion mode (sigma-FiLM conditioning + EDM
    preconditioning, ``sigma = exp(4 * c_noise)`` — the same convention the
    old ``launch/serve._diffusion_lm_eps`` used).  ``seq`` and ``seed`` are
    finally configurable (they were hardcoded to 32 / key(0)); ``mesh``
    places params and activations, with ``mesh.tp`` sharding the backbone.
    """
    from repro.diffusion import EDMConfig, eps_from_denoiser, precondition

    mesh_spec = mesh or MeshSpec()
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if seq < 1:
        raise ValueError(f"seq must be >= 1, got {seq}")
    params = _materialize_params(cfg, seed, mesh_spec)
    dim = seq * cfg.d_model
    rules = (None if mesh_spec.is_single
             else eps_axis_rules(mesh_spec.build(), mesh_spec))

    def raw_fn(x_flat, c_noise):
        x = x_flat.reshape(-1, seq, cfg.d_model)
        out = _model.denoise(params, x, jnp.exp(4.0 * c_noise), cfg)
        return out.reshape(x_flat.shape)

    eps0 = eps_from_denoiser(precondition(raw_fn, EDMConfig(sigma_data=sigma_data)))

    if rules is None:
        fn = jax.jit(eps0)
    else:
        # the rules context is entered inside the traced body, so the
        # per-layer constrain() calls bind whether the eps is called
        # directly, jitted, or traced inside an engine's compiled scan
        def fn(x_flat, t):
            with axis_rules(rules):
                return eps0(x_flat, t)

    return EpsModel(fn=fn, dim=dim, params=params, cfg=cfg, arch=arch,
                    seq=seq, seed=seed, mesh_spec=mesh_spec)


# ---------------------------------------------------------------------------
# the shared-tree cache: every lane of a ladder/router built from the same
# (arch, seq, seed, mesh) gets the SAME EpsModel — one param tree, one eps
# closure, one engine `_fn_key` — instead of a per-lane re-init
# ---------------------------------------------------------------------------

_EPS_CACHE: dict[tuple, EpsModel] = {}
_EPS_CACHE_CAP = 8


def get_eps_model(arch: str, *, seq: int = 32, seed: int = 0,
                  mesh: Optional[MeshSpec] = None,
                  reduced: bool = True) -> EpsModel:
    """Cached :func:`build_eps` — the one-shared-param-tree entry point."""
    key = (arch, seq, seed, mesh or MeshSpec(), reduced)
    hit = _EPS_CACHE.get(key)
    if hit is not None:
        return hit
    model = build_eps(arch, seq=seq, seed=seed, mesh=mesh, reduced=reduced)
    if len(_EPS_CACHE) >= _EPS_CACHE_CAP:
        _EPS_CACHE.pop(next(iter(_EPS_CACHE)))
    _EPS_CACHE[key] = model
    return model


def clear_eps_cache() -> None:
    _EPS_CACHE.clear()
