from .eps import (EPS_TP_TOL, EpsModel, build_eps, clear_eps_cache,
                  eps_axis_rules, get_eps_model)
from .model import (Cache, decode_step, denoise, forward, init_params,
                    lm_loss, param_specs, prefill)

__all__ = ["Cache", "decode_step", "denoise", "forward", "init_params",
           "lm_loss", "param_specs", "prefill",
           "EPS_TP_TOL", "EpsModel", "build_eps", "clear_eps_cache",
           "eps_axis_rules", "get_eps_model"]
