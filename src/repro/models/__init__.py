from .model import (Cache, decode_step, denoise, forward, init_params,
                    lm_loss, param_specs, prefill)

__all__ = ["Cache", "decode_step", "denoise", "forward", "init_params",
           "lm_loss", "param_specs", "prefill"]
