"""Persistent compilation cache: a serve fleet pays compilation once.

The fused engines front-load big XLA compiles — ~8.6s for the end-to-end
CalibrationEngine program (BENCH_calibration_fusion.json recorded
``speedup_cold`` at an honest 0.64x: a cold process was *slower* than the
legacy eager loop), and every router/ladder lane pays its own first-flush
compile.  A freshly launched fleet therefore serves its worst latencies
exactly when traffic arrives.  This module removes the per-process compile
tax with two complementary layers:

* **the XLA persistent cache** (``configure(cache_dir)``) — JAX's on-disk
  compilation cache, keyed on the lowered HLO + compile options.  It is
  content-addressed, so it is *always safe*: a different model, jax
  version, or backend lowers to different HLO and simply misses.  Every
  ``jax.jit`` call and every ``.lower().compile()`` in the process goes
  through it, so a warm cache accelerates the jit hot paths and the AOT
  pre-warm paths alike.  Hits/misses are counted via JAX's monitoring
  events and surface in ``cache_stats()`` (re-exported through
  ``repro.engine.engine_cache_stats()['persistent']``).

* **executable serialization** (``save_executable``/``load_executable``) —
  ``jax.experimental.serialize_executable`` export/import of AOT-compiled
  programs.  Restoring a serialized executable skips tracing *and*
  lowering entirely (the XLA cache still pays both), which is what makes a
  warm ``CalibrationEngine.aot_compile``/``PipelineRouter.precompile``
  nearly free.  Unlike the HLO-keyed layer this one never sees the
  computation, so entries are keyed on (engine fingerprint, program kind,
  shapes, caller-supplied ``model_key``) plus a jax/backend fingerprint
  — any mismatch (jax upgraded, backend changed, device count changed,
  blob tampered/truncated) is a *counted* stale miss that falls back to
  recompilation, never a crash.  Callers that cannot name their eps model
  (``model_key=None``) skip this layer and keep only the always-safe XLA
  cache.

Layout under ``cache_dir``::

    <cache_dir>/xla/           the JAX persistent compilation cache
    <cache_dir>/executables/   <sha256-key>.bin   pickled (payload, trees)
                               <sha256-key>.json  fingerprint + checksum

One process-wide cache is active at a time (``configure``/``active``);
engines take an explicit ``cache=`` handle too so tests can isolate
directories.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pickle
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax

__all__ = [
    "CompileCache",
    "configure",
    "active",
    "deactivate",
    "cache_stats",
    "reset_cache_stats",
]

_ENTRY_VERSION = 1


def runtime_fingerprint() -> dict:
    """The (jax, backend) identity a serialized executable is only valid for.

    Serialized executables embed device topology and jaxlib ABI; any drift
    here invalidates the blob (the XLA-level cache handles its own keying
    and needs none of this).
    """
    import jaxlib

    return {
        "jax": jax.__version__,
        "jaxlib": getattr(jaxlib, "__version__", "unknown"),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


@dataclasses.dataclass
class _Stats:
    """Process-wide counters (shared by every ``CompileCache`` instance)."""

    persistent_hits: int = 0        # XLA disk-cache hits (monitoring events)
    persistent_misses: int = 0      # XLA disk-cache misses
    executable_hits: int = 0        # serialized executables restored
    executable_misses: int = 0      # no entry on disk
    executable_stale: int = 0       # entry rejected: fingerprint/checksum/
    #                                 deserialization failure -> recompile
    executable_saves: int = 0
    compile_seconds: float = 0.0    # wall seconds spent in lower+compile
    deserialize_seconds: float = 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["compile_seconds"] = round(d["compile_seconds"], 3)
        d["deserialize_seconds"] = round(d["deserialize_seconds"], 3)
        return d


_STATS = _Stats()
_STATS_LOCK = threading.Lock()
_ACTIVE: Optional["CompileCache"] = None
_LISTENER_INSTALLED = False


def _on_monitoring_event(event: str, *args, **kw) -> None:
    if event == "/jax/compilation_cache/cache_hits":
        with _STATS_LOCK:
            _STATS.persistent_hits += 1
    elif event == "/jax/compilation_cache/cache_misses":
        with _STATS_LOCK:
            _STATS.persistent_misses += 1


def _install_listener() -> None:
    """Count XLA disk-cache hits/misses via JAX's monitoring events.

    Installed once per process, on first ``configure``; counting is the only
    observability JAX offers here (the cache itself is internal to
    ``jax._src.compiler``).
    """
    global _LISTENER_INSTALLED
    if _LISTENER_INSTALLED:
        return
    try:
        from jax._src import monitoring
        monitoring.register_event_listener(_on_monitoring_event)
        _LISTENER_INSTALLED = True
    except Exception:                                    # pragma: no cover
        pass                  # older jax: stats stay zero, nothing breaks


def record_compile_seconds(seconds: float) -> None:
    """Attribute wall-clock compile time to the process counters."""
    with _STATS_LOCK:
        _STATS.compile_seconds += float(seconds)


class CompileCache:
    """One cache directory: the XLA disk cache + serialized executables."""

    def __init__(self, cache_dir: str | Path):
        self.cache_dir = Path(cache_dir)
        self.xla_dir = self.cache_dir / "xla"
        self.exec_dir = self.cache_dir / "executables"
        self.cache_dir.mkdir(parents=True, exist_ok=True)

    # -- the XLA persistent cache -------------------------------------------

    def enable_xla_cache(self, *, min_compile_seconds: float = 0.0) -> None:
        """Point JAX's persistent compilation cache at ``<dir>/xla``.

        ``min_compile_seconds=0`` caches every program — the engine programs
        this repo compiles are each worth persisting, and serve fleets would
        otherwise miss the small per-lane variants that add up to the
        first-flush stall.
        """
        self.xla_dir.mkdir(parents=True, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(self.xla_dir))
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          float(min_compile_seconds))
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        # jax memoizes its cache-used decision on the FIRST compile of the
        # process (compilation_cache._cache_checked): configuring after any
        # jit has run would otherwise silently disable the disk cache for
        # the process lifetime.  reset_cache() restores the pristine state
        # so the next compile re-evaluates against the dir set above.
        try:
            from jax._src import compilation_cache
            compilation_cache.reset_cache()
        except Exception:                                # pragma: no cover
            pass          # future jax: memoization gone or API moved
        _install_listener()

    # -- executable serialization --------------------------------------------

    def _entry_paths(self, key: str) -> tuple[Path, Path]:
        digest = hashlib.sha256(key.encode()).hexdigest()
        return (self.exec_dir / f"{digest}.bin",
                self.exec_dir / f"{digest}.json")

    def save_executable(self, key: str, compiled: Any) -> Optional[Path]:
        """Serialize an AOT-compiled executable under ``key``.

        Returns the blob path, or ``None`` when this executable type cannot
        be serialized on this backend (a skip, never an error: the XLA-level
        cache still covers it).
        """
        from jax.experimental.serialize_executable import serialize
        try:
            payload, in_tree, out_tree = serialize(compiled)
            blob = pickle.dumps((payload, in_tree, out_tree))
        except Exception:
            return None
        self.exec_dir.mkdir(parents=True, exist_ok=True)
        bin_path, meta_path = self._entry_paths(key)
        bin_path.write_bytes(blob)
        meta_path.write_text(json.dumps({
            "version": _ENTRY_VERSION,
            "key": key,
            "fingerprint": runtime_fingerprint(),
            "sha256": hashlib.sha256(blob).hexdigest(),
        }, indent=1, sort_keys=True))
        with _STATS_LOCK:
            _STATS.executable_saves += 1
        return bin_path

    def load_executable(self, key: str) -> Optional[Callable]:
        """Restore the executable saved under ``key``; ``None`` on any miss.

        Every rejection path — absent entry, version/fingerprint mismatch,
        checksum failure on a tampered/truncated blob, a deserialization
        error — is counted (``executable_misses`` / ``executable_stale``)
        and falls back to ``None`` so the caller recompiles; nothing here
        ever raises on bad cache state.
        """
        bin_path, meta_path = self._entry_paths(key)
        if not (bin_path.exists() and meta_path.exists()):
            with _STATS_LOCK:
                _STATS.executable_misses += 1
            return None
        t0 = time.perf_counter()
        try:
            meta = json.loads(meta_path.read_text())
            blob = bin_path.read_bytes()
            if (meta.get("version") != _ENTRY_VERSION
                    or meta.get("key") != key
                    or meta.get("fingerprint") != runtime_fingerprint()
                    or meta.get("sha256")
                    != hashlib.sha256(blob).hexdigest()):
                raise ValueError("stale cache entry")
            from jax.experimental.serialize_executable import \
                deserialize_and_load
            payload, in_tree, out_tree = pickle.loads(blob)
            fn = deserialize_and_load(payload, in_tree, out_tree)
        except Exception:
            with _STATS_LOCK:
                _STATS.executable_stale += 1
            return None
        with _STATS_LOCK:
            _STATS.executable_hits += 1
            _STATS.deserialize_seconds += time.perf_counter() - t0
        return fn

    def __repr__(self) -> str:
        return f"CompileCache({str(self.cache_dir)!r})"


# ---------------------------------------------------------------------------
# the process-wide active cache
# ---------------------------------------------------------------------------


def configure(cache_dir: str | Path, *,
              min_compile_seconds: float = 0.0) -> CompileCache:
    """Activate a cache directory for this process (the ``--cache-dir`` hook).

    Wires the XLA persistent cache immediately; engines pick the active
    cache up by default for their executable-serialization paths
    (``aot_compile(cache=...)`` overrides per call).
    """
    global _ACTIVE
    cache = CompileCache(cache_dir)
    cache.enable_xla_cache(min_compile_seconds=min_compile_seconds)
    _ACTIVE = cache
    return cache


def active() -> Optional[CompileCache]:
    """The process-wide cache set by ``configure`` (None when unset)."""
    return _ACTIVE


def deactivate() -> None:
    """Forget the active cache (tests); the XLA cache dir stays configured."""
    global _ACTIVE
    _ACTIVE = None


def cache_stats() -> dict:
    """Process-wide persistent-cache counters, one dict.

    ``persistent_hits``/``persistent_misses`` are XLA disk-cache events;
    the ``executable_*`` counters track the serialized-executable layer;
    ``compile_seconds`` accumulates wall time the engines spent in
    lower+compile (so a fleet can tell a warm start from a cold one at a
    glance).
    """
    with _STATS_LOCK:
        out = _STATS.to_dict()
    out["cache_dir"] = str(_ACTIVE.cache_dir) if _ACTIVE else None
    return out


def reset_cache_stats() -> None:
    """Zero the process-wide counters (restart-simulation in tests)."""
    with _STATS_LOCK:
        _STATS.__init__()
