"""repro.engine — the fused, cached sampling surface (see engine.py).

Engines are cached with ``repro.api.SamplerSpec`` keying as the canonical
scheme (``get_engine_for_spec``); the legacy ``(name, ts, dtype)`` entry
points remain as thin shims onto it.
"""

from .engine import (SamplingEngine, clear_engine_cache, engine_cache_stats,
                     engine_for_solver, get_engine, get_engine_for_spec)

__all__ = [
    "SamplingEngine",
    "clear_engine_cache",
    "engine_cache_stats",
    "engine_for_solver",
    "get_engine",
    "get_engine_for_spec",
]
