"""repro.engine — the fused, cached sampling surface (see engine.py)."""

from .engine import (SamplingEngine, clear_engine_cache, engine_cache_stats,
                     engine_for_solver, get_engine)

__all__ = [
    "SamplingEngine",
    "clear_engine_cache",
    "engine_cache_stats",
    "engine_for_solver",
    "get_engine",
]
