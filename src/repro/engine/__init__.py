"""repro.engine — the fused, cached sampling + calibration surface.

Engines are cached with ``repro.api.SamplerSpec`` keying as the canonical
scheme (``get_engine_for_spec`` / ``get_calibration_engine_for_spec``); the
legacy ``(name, ts, dtype)`` and solver-bound entry points remain as thin
shims onto it.  ``SamplingEngine`` (engine.py) compiles Algorithm 2;
``CalibrationEngine`` (calibration.py) compiles Algorithm 1 end-to-end on
the same mesh and kernels.
"""

from . import compile_cache
from .adaptive import (AdaptiveEngine, adaptive_engine_cache_stats,
                       clear_adaptive_engine_cache,
                       get_adaptive_engine_for_spec)
from .calibration import (CalibrationEngine, calibration_engine_cache_stats,
                          calibration_engine_for_solver,
                          clear_calibration_engine_cache,
                          get_calibration_engine_for_spec)
from .compile_cache import CompileCache
from .engine import (PASShardingFallbackWarning, SamplingEngine,
                     clear_engine_cache, engine_cache_stats,
                     engine_for_solver, get_engine, get_engine_for_spec)
from .zoo import ZooCalibrationEngine, calibrate_zoo

__all__ = [
    "AdaptiveEngine",
    "CalibrationEngine",
    "CompileCache",
    "PASShardingFallbackWarning",
    "SamplingEngine",
    "ZooCalibrationEngine",
    "calibrate_zoo",
    "adaptive_engine_cache_stats",
    "calibration_engine_cache_stats",
    "calibration_engine_for_solver",
    "clear_adaptive_engine_cache",
    "clear_calibration_engine_cache",
    "clear_engine_cache",
    "compile_cache",
    "engine_cache_stats",
    "engine_for_solver",
    "get_engine",
    "get_adaptive_engine_for_spec",
    "get_engine_for_spec",
]
