"""Zoo-wide batched calibration: one teacher trajectory, one compiled run.

Recalibrating a (solver, NFE) zoo after a model drop used to pay the paper's
SS3.3 nested teacher trajectory once PER SPEC — by far the dominant cost
(the teacher runs a 2-eval solver on an m-times-refined grid).  But a zoo
sharing one schedule family doesn't need per-spec teachers: the polynomial
schedule (eq. 19) is closed under sub-indexing, so the grid with
``L = lcm(nfes)`` student intervals contains every rung's grid as a strided
subset.  ``ZooCalibrationEngine`` therefore

* builds ONE teacher trajectory on the L-interval shared grid, refined at
  least as finely as the finest per-spec teacher would have been (the
  shared refinement ``m`` satisfies ``L*(m+1) >= n_s*(m_s+1)`` for every
  spec — see ``_shared_refinement``), and emits the L+1 aligned states;
* strides that trajectory per spec (``gt_s = gt_shared[::L//n_s]``); and
* batches every spec's Algorithm-1 program into ONE jitted run: each spec's
  ``CalibrationEngine._calibrate_body`` is inlined into a single compiled
  program (one trace, one dispatch, one diagnostics transfer), and groups
  of specs that are shape-compatible (same NFE, same native space — i.e.
  differing only in solver coefficient tables) are **vmapped over a spec
  axis**, so their per-step eps evals execute as one batched backbone call.

The teacher-eval ledger (``teacher_evals`` / per-spec sum) is what
``benchmarks/backbone_mesh.py`` records: teacher evals are counted once,
not once per spec.

Numerics: the sequential path reuses each spec's own ``_calibrate_body``
verbatim (bit-identical program to per-spec calibration given the same
``gt``).  The vmapped path re-expresses the corrected step through
``solver.phi`` with traced coefficient tables — the same contraction the
fused kernels implement — and is asserted against the per-spec path in
tests/test_zoo_calibration.py (same adopted steps, coords allclose).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pas as pas_mod
from repro.core.pas import LOSS_FNS, PASParams, _QBuffer

from .calibration import CalibrationEngine, get_calibration_engine_for_spec
from .engine import _fn_key, _scaled_coords

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]

__all__ = ["ZooCalibrationEngine", "calibrate_zoo"]


def _lcm(values) -> int:
    out = 1
    for v in values:
        out = out * v // math.gcd(out, v)
    return out


class ZooCalibrationEngine:
    """Calibrate many specs on one schedule family against ONE teacher.

    ``specs`` maps lane keys to ``repro.api.SamplerSpec``s that must agree
    on everything except (solver, nfe): same polynomial schedule, same
    PASConfig, teacher, dtype, and mesh.  Each spec still gets its own
    cached ``CalibrationEngine`` (so the final gate, artifacts, and any
    later per-spec recalibration are unchanged); the zoo engine only
    replaces the teacher build and the Algorithm-1 dispatch.
    """

    def __init__(self, specs: Mapping[str, Any]):
        if not specs:
            raise ValueError("ZooCalibrationEngine needs at least one spec")
        self.specs = dict(specs)
        base = next(iter(self.specs.values()))
        for k, s in self.specs.items():
            for field in ("schedule", "pas", "teacher", "dtype", "mesh"):
                if getattr(s, field) != getattr(base, field):
                    raise ValueError(
                        f"zoo specs must share {field}; {k!r} has "
                        f"{getattr(s, field)!r} != {getattr(base, field)!r}")
        if base.schedule.kind != "polynomial":
            raise ValueError(
                "zoo calibration shares one teacher via schedule-family "
                "nesting, which needs the polynomial family (closed under "
                f"sub-indexing); got {base.schedule.kind!r}")
        self.engines: dict[str, CalibrationEngine] = {
            k: get_calibration_engine_for_spec(s) for k, s in self.specs.items()}
        for eng in self.engines.values():
            eng._require_lms()
        self.nfes = {k: s.nfe for k, s in self.specs.items()}
        self.L = _lcm(self.nfes.values())
        self.strides = {k: self.L // n for k, n in self.nfes.items()}
        # the shared-grid spec: same solver family as base (the teacher
        # build only uses its schedule + teacher), L student intervals.
        # When the shared grid is already at least teacher-fine, refine one
        # extra level (2L) instead of degrading below any rung's teacher —
        # 2L >= n*ceil(T/n) for every rung (T <= L, n <= L).
        self._shared_spec = base.replace(nfe=self.L)
        if base.teacher.nfe <= self.L:
            self._shared_spec = self._shared_spec.replace(
                teacher=dataclasses.replace(base.teacher, nfe=2 * self.L))
        self._teacher_engine = get_calibration_engine_for_spec(
            self._shared_spec)
        self._compiled: dict[Any, Callable] = {}

    # -- the teacher-eval ledger --------------------------------------------

    @property
    def teacher_evals(self) -> int:
        """Model evals the ONE shared teacher trajectory costs."""
        _, t_ts, _ = self._shared_spec.teacher_grid()
        return self._shared_spec.make_teacher(t_ts).nfe

    @property
    def teacher_evals_per_spec(self) -> dict[str, int]:
        """What each spec's own teacher would have cost (the old path)."""
        out = {}
        for k, s in self.specs.items():
            _, t_ts, _ = s.teacher_grid()
            out[k] = s.make_teacher(t_ts).nfe
        return out

    # -- shared teacher ------------------------------------------------------

    def shared_teacher(self, eps_fn: EpsFn, x_t: Array) -> Array:
        """The one teacher trajectory, (L+1, B, D) on the shared grid.

        Refinement note (``_shared_refinement`` in the module docstring):
        with ``m = teacher_refinement(L, teacher.nfe)`` the refined grid has
        ``L*(m+1)`` steps; since every rung NFE divides L, a standard
        ceiling inequality gives ``L*ceil(T/L) >= n*ceil(T/n)`` — the
        shared trajectory is always at least as refined as any per-spec
        teacher, so rung quality can only improve.
        """
        return self._teacher_engine.teacher_trajectory(eps_fn, x_t)

    def gt_for(self, key: str, gt_shared: Array) -> Array:
        """Stride the shared trajectory onto one spec's student grid."""
        return gt_shared[::self.strides[key]]

    # -- the one compiled zoo program ---------------------------------------

    def _vmap_groups(self) -> list[list[str]]:
        """Group keys whose Algorithm-1 bodies can share one vmapped trace.

        Shape-compatible = same NFE and same native space (solver tables
        vmap after K-padding).  The vmapped body skips per-step sharding
        constraints, so it is only used on the trivial mesh; sharded zoos
        run every body sequentially inside the same compiled program.
        """
        groups: dict[tuple, list[str]] = {}
        for k, eng in self.engines.items():
            single = eng.sampling.mesh is None
            sig = (eng.nfe, eng.solver.native) if single else ("seq", k)
            groups.setdefault(sig, []).append(k)
        return list(groups.values())

    def _vmapped_group(self, keys: list[str], eps_fn: EpsFn) -> Callable:
        """One vmapped Algorithm-1 body over the stacked spec axis.

        Specs in the group differ only in their (alpha, beta) coefficient
        tables; betas are zero-padded to the widest history K (zero-beta
        terms are exact no-ops in ``phi``).  The corrected step runs
        through ``solver.phi`` on the traced tables — the same linear
        contraction ``ops.fused_pas_step`` fuses — instead of the
        closure-constant kernels, which is what makes the spec axis
        mappable.
        """
        engines = [self.engines[k] for k in keys]
        base = engines[0]
        cfg, n = base.cfg, base.nfe
        ts = base.solver.ts_jax
        for e in engines[1:]:
            if not np.array_equal(e.solver.ts, base.solver.ts):
                raise AssertionError("grouped specs must share the grid")
        kmax = max(int(e.solver.beta.shape[1]) for e in engines)

        def pad(b):
            b = jnp.asarray(b)
            return jnp.pad(b, ((0, 0), (0, kmax - b.shape[1])))

        alphas = jnp.stack([jnp.asarray(e.solver.alpha) for e in engines])
        betas = jnp.stack([pad(e.solver.beta) for e in engines])
        basis = base.sampling._basis_fn(cfg.n_basis)
        solver0 = base.solver

        def one(alpha, beta, x_t, gt):
            sol = dataclasses.replace(solver0, alpha=alpha, beta=beta)
            sgd = pas_mod._sgd_loop(sol, cfg, LOSS_FNS[cfg.loss])
            b = x_t.shape[0]
            n_val = int(round(b * cfg.val_fraction))
            tr = slice(n_val, None)
            va = slice(0, n_val) if n_val > 0 else slice(None)
            x = x_t
            hist = sol.init_hist(x_t)
            q = _QBuffer.create(x_t, cap=n + 1)
            actives, coords, l2ps, l2cs = [], [], [], []
            for j in range(n):
                t = ts[j]
                d = eps_fn(x, t)
                u = basis(q.rows, q.mask, d)
                d_norm = jax.vmap(jnp.linalg.norm)(d)
                c0 = pas_mod._init_coords(d, cfg.coord_mode, cfg.n_basis)
                c_opt = sgd(c0, x[tr], u[tr], d_norm[tr],
                            pas_mod._hist_slice(hist, tr), gt[j + 1][tr], j)
                cs = _scaled_coords(c_opt, d, cfg.coord_mode)
                d_tilde = jnp.einsum("bk,bkd->bd", cs, u).astype(d.dtype)
                x_corr = sol.phi(x, d_tilde, j, hist)
                x_plain = sol.phi(x, d, j, hist)
                l2_plain = jnp.mean((x_plain[va] - gt[j + 1][va]) ** 2)
                l2_corr = jnp.mean((x_corr[va] - gt[j + 1][va]) ** 2)
                adopt = (l2_plain - (l2_corr + cfg.tolerance)) > 0.0
                x_new, d_used, c_used = jax.lax.cond(
                    adopt,
                    lambda: (x_corr, d_tilde, c_opt),
                    lambda: (x_plain, d, jnp.zeros_like(c_opt)))
                hist = sol.push(x, d_used, j, hist)
                q = q.push(d_used, j + 1)
                x = x_new
                actives.append(adopt)
                coords.append(c_used)
                l2ps.append(l2_plain)
                l2cs.append(l2_corr)
            final_l2 = jnp.mean((x - gt[-1]) ** 2)
            return (jnp.stack(actives), jnp.stack(coords),
                    jnp.stack(l2ps), jnp.stack(l2cs), final_l2, x)

        mapped = jax.vmap(one, in_axes=(0, 0, None, None))
        return lambda x_t, gt: mapped(alphas, betas, x_t, gt)

    def _build_zoo(self, eps_fn: EpsFn) -> Callable:
        groups = self._vmap_groups()
        parts: list[tuple[list[str], Callable, bool]] = []
        for keys in groups:
            if len(keys) > 1:
                parts.append((keys, self._vmapped_group(keys, eps_fn), True))
            else:
                parts.append(
                    (keys, self.engines[keys[0]]._calibrate_body(eps_fn),
                     False))
        strides = self.strides

        def run(x_t, gt_shared):
            outs = {}
            for keys, body, mapped in parts:
                if mapped:
                    stacked = body(x_t, gt_shared[::strides[keys[0]]])
                    for i, k in enumerate(keys):
                        outs[k] = jax.tree_util.tree_map(
                            lambda leaf: leaf[i], stacked)
                else:
                    k = keys[0]
                    outs[k] = body(x_t, gt_shared[::strides[k]])
            return outs

        return jax.jit(run)

    # -- public API ----------------------------------------------------------

    def calibrate(self, eps_fn: EpsFn, x_t: Array
                  ) -> dict[str, tuple[PASParams, dict]]:
        """Calibrate every spec: one teacher, one compiled Algorithm-1 run.

        Returns ``{key: (params, diag)}`` with the usual per-spec
        diagnostics plus a ``"zoo"`` entry recording the shared-teacher
        ledger.  Per-spec final gates (small val-slice programs) still run
        through each spec's own engine afterwards.
        """
        base_eng = next(iter(self.engines.values()))
        x_t = base_eng.sampling.shard(x_t)
        gt_shared = self.shared_teacher(eps_fn, x_t)

        fkey = _fn_key(eps_fn)
        fn = self._compiled.get(fkey)
        if fn is None:
            fn = self._build_zoo(eps_fn)
            self._compiled[fkey] = fn
        outs = fn(x_t, gt_shared)

        shared_evals = self.teacher_evals
        per_spec = self.teacher_evals_per_spec
        ledger = {"teacher_shared": True,
                  "teacher_evals": shared_evals,
                  "teacher_evals_per_spec_sum": sum(per_spec.values()),
                  "shared_grid_nfe": self.L}
        results: dict[str, tuple[PASParams, dict]] = {}
        for k, eng in self.engines.items():
            gt_k = self.gt_for(k, gt_shared)
            b = int(x_t.shape[0])
            n_val = int(round(b * eng.cfg.val_fraction))
            va = slice(0, n_val) if n_val > 0 else slice(None)
            params, diag = eng._postprocess(
                eps_fn, outs[k], x_t[va] if eng.cfg.final_gate else None,
                gt_k[-1][va])
            diag["zoo"] = dict(ledger)
            results[k] = (params, diag)
        return results


def calibrate_zoo(specs: Mapping[str, Any], eps_fn: EpsFn, x_t: Array
                  ) -> dict[str, tuple[PASParams, dict]]:
    """One-call zoo calibration: shared teacher + one compiled Alg-1 run."""
    return ZooCalibrationEngine(specs).calibrate(eps_fn, x_t)
