"""CalibrationEngine: paper Algorithm 1 compiled end-to-end, one program.

``core.pas.calibrate`` (now ``calibrate_reference``, kept as the parity
oracle) is a Python loop: per step it runs an unjitted eps eval, an eagerly
dispatched PCA/Schmidt basis, a separately-jitted SGD scan, blocking host
syncs for the adoption metrics, and — when the final-state gate fires — a
full eager re-sample per dropped step.  The paper's headline claim is that
calibration is *cheap* (~10 parameters, sub-minute on one accelerator), so
the interpreted loop was the last hot path in the repo that re-paid Python
dispatch per step.

``CalibrationEngine`` compiles the whole of Algorithm 1 into one cached XLA
program per spec and eps model:

* the N calibration steps are **statically unrolled** (Alg. 1 is inherently
  sequential — a corrected step changes every later state) with the per-step
  eps eval, Q-buffer/PCA basis construction (``SamplingEngine._basis_fn``:
  one Gram pass + the weight-space basis of ``pca.basis_weights``, with the
  single tiny Gram psum of ``core.distributed`` whenever the state dim is
  sharded; the basis is materialised here — unlike sampling — because the
  SGD scan reuses U across its ~200 iterations), the SGD inner ``lax.scan``,
  and the corrected-vs-plain rollout through the fused step kernels
  (``kernels.ops.fused_step`` / ``fused_pas_step``) all in the same program;
* the adaptive-search adoption decision is a ``lax.cond`` **on-device** —
  the (x, hist, Q) carries never round-trip host memory, and the
  ``loss_before/loss_after/gain`` diagnostics come back as stacked device
  arrays in one transfer instead of three blocking ``float()`` syncs per
  step;
* the final-state gate is **one compiled scan over candidate active-masks**
  (``lax.map`` over the greedy drop sequence) instead of a Python ``while``
  of eager re-samples, with the plain-trajectory baseline routed through the
  cached ``SamplingEngine`` for the spec — one engine lookup, no per-trial
  re-trace;
* the nested teacher-trajectory builder (paper §3.3) is a jitted
  student-interval x refinement scan on the same mesh, emitting only the
  (N+1) aligned states instead of materialising the full refined grid;
* programs are keyed and mesh-placed exactly like ``SamplingEngine``:
  engines cache on (``spec.engine_key``, PASConfig, teacher), compiled
  programs on the eps model, every (B, D) buffer carries the engine's
  sharding constraints, and the ``donate=True`` path donates the x_T buffer
  to the compiled program (aliased into the corrected end-state carry) when
  the caller owns it (``Pipeline.calibrate``'s key-based path).

Numerics follow ``calibrate_reference`` step for step (same basis, same SGD,
same adoption metric); parity is asserted in tests/test_calibration_engine.py
(same adopted step set, coords allclose, identical stored-parameter count).
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pas as pas_mod
from repro.core.pas import LOSS_FNS, PASConfig, PASParams, _QBuffer
from repro.core.solvers import LinearMultistepSolver, Solver, SolverHist

from repro.kernels import ops

from . import compile_cache
from .engine import (SamplingEngine, _CacheStats, _aot_program,
                     _compiled_lookup, _engine_for_solver, _fn_key,
                     _lru_lookup, _scaled_coords, _shape_sig,
                     get_engine_for_spec)

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]

__all__ = [
    "CalibrationEngine",
    "get_calibration_engine_for_spec",
    "calibration_engine_for_solver",
    "clear_calibration_engine_cache",
    "calibration_engine_cache_stats",
]


class CalibrationEngine:
    """Algorithm 1 as one compiled program, bound to a sampling engine.

    Construction mirrors ``SamplingEngine``: bind once per (spec, PASConfig,
    teacher) through ``get_calibration_engine_for_spec`` (the cached path) or
    directly from an already-bound solver via
    ``calibration_engine_for_solver``.  The engine shares the spec's cached
    ``SamplingEngine`` — same mesh, same packed coefficient tables, same
    fused kernels — so calibration and sampling agree on placement and step
    numerics by construction.
    """

    def __init__(self, spec=None, *, solver: Optional[Solver] = None,
                 cfg: Optional[PASConfig] = None,
                 sampling: Optional[SamplingEngine] = None,
                 dtype: jnp.dtype = jnp.float32):
        if spec is not None:
            sampling = sampling if sampling is not None else \
                get_engine_for_spec(spec)
            cfg = spec.pas if cfg is None else cfg
        else:
            if solver is None:
                raise ValueError("CalibrationEngine needs a spec or a solver")
            sampling = sampling if sampling is not None else \
                _engine_for_solver(solver, dtype)
            cfg = cfg if cfg is not None else PASConfig()
        self.spec = spec
        self.sampling = sampling
        self.solver = sampling.solver
        self.cfg = cfg
        self.nfe = self.solver.nfe
        self._compiled: dict[Any, tuple[Callable, Callable]] = {}
        self._aot: dict[Any, Callable] = {}

    def _require_lms(self) -> None:
        """Calibration (not teacher building) needs a 1-eval solver, checked
        at call time exactly like the reference loop."""
        if not isinstance(self.solver, LinearMultistepSolver):
            raise TypeError(
                "PAS calibration requires a 1-eval solver (paper setup); "
                f"got {self.solver.name}")

    # -- compiled-program cache (the sampler's pinning/LRU helpers) ---------

    def _get_compiled(self, key, build, eps_fn) -> Callable:
        return _compiled_lookup(self._compiled, key, build, eps_fn)

    def compiled_variants(self) -> int:
        return len(self._compiled)

    # -- the fused Algorithm 1 program --------------------------------------

    def _calibrate_body(self, eps_fn: EpsFn) -> Callable:
        """The unjitted Algorithm-1 program body ``run(x_t, gt) -> outputs``.

        ``_build_calibrate`` jits it directly; ``engine.zoo`` embeds many
        spec bodies into ONE jitted program (the batched zoo recalibration),
        so nothing in here may jit, dispatch, or touch the host.
        """
        solver, cfg, eng = self.solver, self.cfg, self.sampling
        n = self.nfe
        ts = solver.ts_jax
        coef = eng.coef
        n_basis = cfg.n_basis
        basis = eng._basis_fn(n_basis)
        # the one Alg. 1 trainer, inlined unjitted into this program — shared
        # with the reference loop so the paths cannot train differently
        sgd = pas_mod._sgd_loop(solver, cfg, LOSS_FNS[cfg.loss])

        def run(x_t: Array, gt: Array):
            b = x_t.shape[0]
            n_val = int(round(b * cfg.val_fraction))
            tr = slice(n_val, None)
            va = slice(0, n_val) if n_val > 0 else slice(None)

            x = eng._constrain(x_t)
            gt = eng._constrain(gt, leading=1)
            hist = solver.init_hist(x_t)
            hist = SolverHist(eng._constrain(hist.buf, leading=1), hist.count)
            q = _QBuffer.create(x_t, cap=n + 1)
            q = _QBuffer(eng._constrain(q.rows, leading=1), q.mask)

            actives, coords, l2ps, l2cs = [], [], [], []
            for j in range(n):               # static unroll: Alg. 1 is sequential
                t = ts[j]
                d = eps_fn(x, t)
                u = basis(q.rows, q.mask, d)                   # (B, k, D)
                d_norm = jax.vmap(jnp.linalg.norm)(d)          # (B,)
                c0 = pas_mod._init_coords(d, cfg.coord_mode, n_basis)
                c_opt = sgd(c0, x[tr], u[tr], d_norm[tr],
                            pas_mod._hist_slice(hist, tr), gt[j + 1][tr], j)

                # corrected-vs-plain rollout through the fused step kernels
                cs = _scaled_coords(c_opt, d, cfg.coord_mode)  # (B, k)
                x_corr, d_tilde, _ = ops.fused_pas_step(
                    x, u, cs, hist.buf, coef[j], native_x0=eng.native_x0)
                x_plain = ops.fused_step(x, eng._native(x, d, t), hist.buf,
                                         coef[j])

                # adaptive-search decision on the L2 metric (paper eq. 20),
                # resolved on-device: the carries never touch the host
                l2_plain = jnp.mean((x_plain[va] - gt[j + 1][va]) ** 2)
                l2_corr = jnp.mean((x_corr[va] - gt[j + 1][va]) ** 2)
                adopt = (l2_plain - (l2_corr + cfg.tolerance)) > 0.0
                x_new, d_used, c_used = jax.lax.cond(
                    adopt,
                    lambda: (x_corr, d_tilde, c_opt),
                    lambda: (x_plain, d, jnp.zeros_like(c_opt)))

                hist = solver.push(x, d_used, j, hist)
                q = q.push(d_used, j + 1)
                x = eng._constrain(x_new)
                actives.append(adopt)
                coords.append(c_used)
                l2ps.append(l2_plain)
                l2cs.append(l2_corr)

            final_l2 = jnp.mean((x - gt[-1]) ** 2)
            # x (the corrected end state) is returned so a donated x_t buffer
            # has a same-shaped output to alias into — the donation is real,
            # not a dead annotation (callers discard it)
            return (jnp.stack(actives), jnp.stack(coords),
                    jnp.stack(l2ps), jnp.stack(l2cs), final_l2, x)

        return run

    def _build_calibrate(self, eps_fn: EpsFn, donate: bool) -> Callable:
        return jax.jit(self._calibrate_body(eps_fn),
                       donate_argnums=(0,) if donate else ())

    # -- the fused final-state gate -----------------------------------------

    def _build_gate(self, eps_fn: EpsFn) -> Callable:
        solver, cfg, eng = self.solver, self.cfg, self.sampling
        n = self.nfe
        ts = solver.ts_jax
        coef = eng.coef
        basis = eng._basis_fn(cfg.n_basis)

        def rollout(x0, gt_end, coords, mask_row):
            x = x0
            hist = eng._hist0(x0)
            q = _QBuffer.create(x0, cap=n + 1)
            for j in range(n):               # static unroll, dynamic mask
                t = ts[j]
                d = eps_fn(x, t)
                u = basis(q.rows, q.mask, d)
                cs = _scaled_coords(coords[j], d, cfg.coord_mode)
                x_corr, d_tilde, nat_c = ops.fused_pas_step(
                    x, u, cs, hist, coef[j], native_x0=eng.native_x0)
                nat_p = eng._native(x, d, t)
                x_plain = ops.fused_step(x, nat_p, hist, coef[j])
                on = mask_row[j]
                x = eng._constrain(jnp.where(on, x_corr, x_plain))
                hist = eng._push_hist(hist, jnp.where(on, nat_c, nat_p))
                q = q.push(jnp.where(on, d_tilde, d), j + 1)
            return jnp.mean(jnp.linalg.norm(x - gt_end, axis=-1))

        def run(x_gate: Array, gt_end: Array, coords: Array, masks: Array):
            x_gate = eng._constrain(x_gate)
            return jax.lax.map(
                lambda mr: rollout(x_gate, gt_end, coords, mr), masks)

        return jax.jit(run)

    def _final_gate(self, eps_fn: EpsFn, x_gate: Array, gt_end: Array,
                    params: PASParams) -> tuple[PASParams, list[int]]:
        """Greedy final-state gate (``calibrate_reference`` semantics) as one
        compiled scan: candidate c is the active mask with the c
        largest-index corrected steps dropped; the first candidate whose
        end-to-end error is within tolerance of the plain solver wins."""
        drop_order = np.nonzero(params.active)[0][::-1]
        m = params.active.copy()
        rows = []
        for j in drop_order:
            rows.append(m.copy())
            m[j] = False
        k_cand = len(rows)
        # pad the candidate block to a static (N, N) shape (repeat the last
        # real row): the gate compiles once per eps model instead of once
        # per adopted-step count, and its AOT shape is known before any
        # calibration ran; padded rows are sliced off below
        while len(rows) < self.nfe:
            rows.append(rows[-1].copy())
        masks = np.stack(rows)                       # (N, N) candidates

        # plain baseline through the spec's cached SamplingEngine: one
        # engine lookup, the same compiled plain scan sampling uses
        x_plain = self.sampling.sample(eps_fn, x_gate)
        e_plain = float(jnp.mean(jnp.linalg.norm(x_plain - gt_end, axis=-1)))

        key = ("gate", _fn_key(eps_fn))
        args = (x_gate, gt_end,
                jnp.asarray(params.coords, self.sampling.dtype),
                jnp.asarray(masks))
        gate = self._aot.get((key, _shape_sig(*args)))
        if gate is None:
            gate = self._get_compiled(key,
                                      lambda: self._build_gate(eps_fn),
                                      eps_fn)
        es = np.asarray(gate(*args))[:k_cand]

        for c, e in enumerate(es):
            if e <= e_plain * (1.0 + 1e-4):
                return (PASParams(active=masks[c].copy(),
                                  coords=params.coords),
                        [int(j) for j in drop_order[:c]])
        return (PASParams(active=np.zeros_like(params.active),
                          coords=params.coords),
                [int(j) for j in drop_order])

    # -- the fused nested-teacher builder -----------------------------------

    def _build_teacher(self, eps_fn: EpsFn) -> Callable:
        if self.spec is None:
            raise ValueError(
                "teacher_trajectory needs a spec-bound CalibrationEngine "
                "(the teacher grid lives on the SamplerSpec); pass gt= "
                "explicitly for solver-bound engines")
        s_ts, t_ts, m = self.spec.teacher_grid()
        tsol = self.spec.make_teacher(t_ts)
        n_student = len(s_ts) - 1
        eng = self.sampling

        def run(x_t: Array) -> Array:
            x0 = eng._constrain(x_t)

            def refine(carry, jj0):          # one student interval: m+1 steps
                def inner(c, i):
                    x, hist = c
                    x, hist, _ = tsol.step(eps_fn, x, jj0 + i, hist)
                    return (eng._constrain(x), hist), None
                carry, _ = jax.lax.scan(inner, carry, jnp.arange(m + 1))
                return carry, carry[0]

            (_, _), xs = jax.lax.scan(
                refine, (x0, tsol.init_hist(x_t)),
                jnp.arange(n_student) * (m + 1))
            return jnp.concatenate([x_t[None], xs], axis=0)

        return jax.jit(run)

    def teacher_trajectory(self, eps_fn: EpsFn, x_t: Array) -> Array:
        """Ground-truth trajectory on the spec's nested teacher grid (§3.3).

        One jitted scan over (student interval x refinement) on the engine
        mesh; only the (N+1) states aligned to the student grid are
        materialised, gt[0] = x_t.
        """
        key = ("teacher", _fn_key(eps_fn))
        x_t = self.sampling.shard(x_t)
        aot_fn = self._aot.get((key, _shape_sig(x_t)))
        if aot_fn is not None:
            return aot_fn(x_t)
        fn = self._get_compiled(key,
                                lambda: self._build_teacher(eps_fn), eps_fn)
        return fn(x_t)

    # -- public API ----------------------------------------------------------

    def calibrate(self, eps_fn: EpsFn, x_t: Array, gt: Array, *,
                  donate: bool = False) -> tuple[PASParams, dict]:
        """Learn the ~10 PAS parameters (paper Algorithm 1), fully compiled.

        ``x_t`` (B, D) and ``gt`` (N+1, B, D) follow the
        ``calibrate_reference`` contract.  ``donate=True`` donates the
        ``x_t`` buffer to the compiled program (aliased into the corrected
        end state it carries) — only pass it when the caller owns the
        buffer; the gate slice is copied out first.
        """
        self._require_lms()
        x_t = self.sampling.shard(x_t)
        cfg = self.cfg
        b = int(x_t.shape[0])
        n_val = int(round(b * cfg.val_fraction))
        va = slice(0, n_val) if n_val > 0 else slice(None)
        if donate and cfg.final_gate and n_val == 0:
            # the gate would need the whole batch back: donation buys
            # nothing over the full copy it would force, and skipping it
            # keeps donate/no-donate callers on one compiled variant
            donate = False
        if donate and cfg.final_gate:
            # materialise the (small) val-slice gate input before its
            # buffer is donated
            x_gate = jnp.array(x_t[va], copy=True)
        else:
            x_gate = None

        key = ("calibrate", _fn_key(eps_fn), donate)
        fn = self._aot.get((key, _shape_sig(x_t, gt)))
        if fn is None:
            fn = self._get_compiled(
                key, lambda: self._build_calibrate(eps_fn, donate), eps_fn)
        outputs = fn(x_t, gt)
        if x_gate is None and cfg.final_gate:
            x_gate = x_t[va]
        return self._postprocess(eps_fn, outputs, x_gate, gt[-1][va])

    def _postprocess(self, eps_fn: EpsFn, outputs, x_gate, gt_end
                     ) -> tuple[PASParams, dict]:
        """Host-side half of ``calibrate``: device outputs -> (params, diag).

        Shared with ``engine.zoo``, whose single compiled program returns
        one ``outputs`` tuple per spec; the final gate (when configured)
        runs through this engine's own compiled gate program on the
        ``x_gate`` validation slice against ``gt_end``.
        """
        cfg = self.cfg
        active_d, coords_d, l2p_d, l2c_d, final_d, _ = outputs
        # one device->host transfer for the adoption pattern + diagnostics
        active, l2p, l2c, final_l2 = jax.device_get(
            (active_d, l2p_d, l2c_d, final_d))
        active = np.asarray(active, dtype=bool)
        params = PASParams(active=active, coords=coords_d)
        diag = {"loss_before": [float(v) for v in l2p],
                "loss_after": [float(v) for v in l2c],
                "gain": [float(a - c) for a, c in zip(l2p, l2c)]}

        if cfg.final_gate and active.any():
            params, diag["final_gate_dropped"] = self._final_gate(
                eps_fn, x_gate, gt_end, params)

        diag["corrected_steps_paper_index"] = params.corrected_paper_steps()
        diag["n_stored_params"] = params.n_stored_params
        diag["final_l2_to_gt"] = float(final_l2)
        return params, diag

    # -- cold start: AOT compile + persistent-cache identity -----------------

    def engine_fingerprint(self) -> str:
        """Stable identity of this engine's compiled-program family.

        The sampling engine's fingerprint (solver, schedule, dtype, mesh)
        extended with the two calibration knobs the engine cache keys on
        (PASConfig, teacher), so a restored executable can never cross
        (spec, config, teacher) triples.
        """
        h = hashlib.sha256()
        h.update(self.sampling.engine_fingerprint().encode())
        h.update(repr(self.cfg).encode())
        teacher = self.spec.teacher if self.spec is not None else None
        h.update(repr(teacher).encode())
        return h.hexdigest()[:16]

    def _persist_key(self, model_key: Optional[str], program: str,
                     static_desc, sig) -> Optional[str]:
        """Executable-serialization key (None without a caller-named model;
        see ``SamplingEngine._persist_key`` for the contract)."""
        if model_key is None:
            return None
        return "|".join([str(model_key), self.engine_fingerprint(),
                         "cal-" + program, repr(static_desc), repr(sig)])

    def aot_compile(self, eps_fn: EpsFn, batch: int, dim: int, *,
                    donate: bool = True,
                    cache: Optional[compile_cache.CompileCache] = None,
                    model_key: Optional[str] = None) -> dict:
        """Lower + compile Algorithm 1 ahead of time; report placement.

        The calibration-side mirror of ``SamplingEngine.aot_compile``: for a
        (batch, dim) problem it AOT-compiles the nested-teacher scan
        (spec-bound engines only — solver-bound engines take ``gt``
        explicitly), the fused Algorithm-1 step program, and the final-state
        gate, reporting per-device memory and collective counts per program.
        ``donate`` selects the calibrate variant exactly as
        ``calibrate(donate=...)`` would dispatch it — the default matches
        ``Pipeline.calibrate``'s key-based path, including the forced
        no-donate fallback when the gate would need the whole batch back.

        On a single device the executables are stashed for direct dispatch
        by the next same-shape ``calibrate``/``teacher_trajectory`` call;
        with a compile cache active (``cache`` defaults to
        ``compile_cache.active()``) they are serialized under
        (``model_key``, engine fingerprint, program, shapes) and restored by
        later processes, skipping trace+lower+compile entirely.
        """
        self._require_lms()
        eng, cfg, n = self.sampling, self.cfg, self.nfe
        if cache is None:
            cache = compile_cache.active()
        executable_ok = eng.mesh is None
        n_val = int(round(batch * cfg.val_fraction))
        if donate and cfg.final_gate and n_val == 0:
            donate = False               # calibrate() forces the same fallback
        dt = eng.dtype
        x_sds = jax.ShapeDtypeStruct((batch, dim), dt)
        out = {
            "devices": eng.mesh.size if eng.mesh is not None else 1,
            "mesh": (eng.mesh_spec.to_dict() if eng.mesh_spec is not None
                     else None),
            "batch": batch, "dim": dim, "programs": {},
        }

        def program(name, key, build, arg_specs, static_desc=(),
                    serialize_ok=True):
            sig = tuple((tuple(s.shape), jnp.dtype(s.dtype).name)
                        for s in arg_specs)
            fn = self._get_compiled(key, build, eps_fn)
            out["programs"][name] = _aot_program(
                self._aot, (key, sig), fn, arg_specs, cache=cache,
                persist_key=self._persist_key(model_key, name, static_desc,
                                              sig),
                executable_ok=executable_ok, serialize_ok=serialize_ok)

        if self.spec is not None:
            program("teacher", ("teacher", _fn_key(eps_fn)),
                    lambda: self._build_teacher(eps_fn), [x_sds])
        program("calibrate", ("calibrate", _fn_key(eps_fn), donate),
                lambda: self._build_calibrate(eps_fn, donate),
                [x_sds, jax.ShapeDtypeStruct((n + 1, batch, dim), dt)],
                static_desc=(donate,), serialize_ok=not donate)
        if cfg.final_gate:
            vb = n_val if n_val > 0 else batch
            program("gate", ("gate", _fn_key(eps_fn)),
                    lambda: self._build_gate(eps_fn),
                    [jax.ShapeDtypeStruct((vb, dim), dt),
                     jax.ShapeDtypeStruct((vb, dim), dt),
                     jax.ShapeDtypeStruct((n, cfg.n_basis), dt),
                     jax.ShapeDtypeStruct((n, n), jnp.bool_)])
        return out

    def aot_variants(self) -> int:
        """Number of AOT executables stashed for direct dispatch."""
        return len(self._aot)


# ---------------------------------------------------------------------------
# engine cache (spec-keyed; same _lru_lookup instance as the sampler cache)
# ---------------------------------------------------------------------------


_CAL_ENGINES: dict[Any, CalibrationEngine] = {}
_STATS = _CacheStats()
_MAX_CAL_ENGINES = 64


def _lookup(key: Any, build: Callable[[], CalibrationEngine]) -> CalibrationEngine:
    return _lru_lookup(_CAL_ENGINES, _STATS, key, build, _MAX_CAL_ENGINES)


def get_calibration_engine_for_spec(spec) -> CalibrationEngine:
    """Calibration engine for a ``repro.api.SamplerSpec``.

    Keyed on (``spec.engine_key``, PASConfig, teacher): the sampling-relevant
    projection plus the two calibration-time knobs the sampler cache ignores.
    Specs sharing that triple share one compiled Algorithm 1.
    """
    return _lookup((spec.engine_key, spec.pas, spec.teacher),
                   lambda: CalibrationEngine(spec))


def calibration_engine_for_solver(solver: Solver,
                                  cfg: Optional[PASConfig] = None,
                                  dtype: jnp.dtype = jnp.float32
                                  ) -> CalibrationEngine:
    """Calibration engine for an already-bound solver (legacy-shim path).

    Registered solver names are lifted to canonical specs (sharing cache
    entries with spec-built pipelines); unregistered custom solvers key on
    the raw (name, schedule bytes, dtype, cfg) tuple, with no teacher bound
    (callers must pass ``gt`` explicitly — exactly the legacy contract).
    """
    if isinstance(solver, LinearMultistepSolver):
        from repro.api.spec import spec_from_schedule  # deferred: api > engine
        cfg = cfg if cfg is not None else PASConfig()
        try:
            spec = spec_from_schedule(solver.name, solver.ts, dtype)
            return get_calibration_engine_for_spec(spec.replace(pas=cfg))
        except ValueError:
            ts = np.asarray(solver.ts, np.float64)
            key = ("unregistered", solver.name, ts.tobytes(),
                   jnp.dtype(dtype).name, cfg)
            return _lookup(key, lambda: CalibrationEngine(
                solver=solver, cfg=cfg, dtype=dtype))
    # non-1-eval solvers get an (uncached, cheap) engine whose .calibrate()
    # raises the legacy TypeError at call time — the canonical error path
    return CalibrationEngine(solver=solver, cfg=cfg, dtype=dtype)


def clear_calibration_engine_cache() -> None:
    _CAL_ENGINES.clear()
    _STATS.hits = _STATS.misses = 0


def calibration_engine_cache_stats() -> dict[str, int]:
    return {"engines": len(_CAL_ENGINES), "hits": _STATS.hits,
            "misses": _STATS.misses,
            "compiled_variants": sum(e.compiled_variants()
                                     for e in _CAL_ENGINES.values()),
            "aot_variants": sum(e.aot_variants()
                                for e in _CAL_ENGINES.values())}
