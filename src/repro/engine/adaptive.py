"""AdaptiveEngine: the error-controlled sampling path, compiled.

The fixed-grid ``SamplingEngine`` runs a predetermined schedule; this engine
runs the embedded Euler/Heun pair with the PID step-size controller from
``repro.core.error_control`` (the k-diffusion ``dpm_solver_adaptive`` idiom,
SNIPPETS.md snippet 1), so each *sample* chooses its own step count between
the spec schedule's endpoints.  The data-dependent loop is compiled as a
**fixed-iteration ``lax.scan`` with an active mask** — ``max_iters``
iterations always trace, each lane (sample) masks itself out once a step
landing on ``t_min`` is accepted — which keeps the program jittable,
batchable, donation-friendly and mesh-placeable exactly like the fixed
engine's scan.

NFE accounting is honest per the serve-loop convention: ``info["nfe"]`` is
``2 * (n_accept + n_reject)`` per sample — every eval the controller
actually spent, rejected proposals included.  (The device additionally
burns masked evals for lanes that finish early — a *capacity* cost of the
fixed-length scan, reported as ``info["scan_evals"]``, never attributed to
samples.)

PAS on the adaptive grid: when calibrated params are supplied, each
accepted direction is pushed into a per-sample rolling Q window and every
step falling into a *corrected cell* of the calibration grid (the fixed
``spec.ts()`` interval containing the current t) applies that cell's
coordinates through the same fused kernels the fixed engine uses
(``ops.fused_pas_step`` folds projection + Euler update into one pass).
The coordinates were calibrated on the fixed grid, so this is a nearest-
cell transfer — benchmarks/adaptive_nfe.py quantifies what it buys.

``ErrorControlConfig.enabled`` is False (rtol <= 0) ⇒ every call delegates
to the spec's fixed-grid engine (the *same cached object* plain specs use),
so the rtol=0 adaptive path is bit-identical to the fixed engine by
construction (asserted in tests/test_adaptive.py).
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error_control as ec_mod
from repro.core.error_control import PIDState, pid_init, pid_propose
from repro.core.pca import pas_basis
from repro.kernels import ops

from . import compile_cache
from .engine import (_CacheStats, _aot_program, _compiled_lookup, _fn_key,
                     _lru_lookup, _shape_sig, get_engine_for_spec)

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]

__all__ = [
    "AdaptiveEngine",
    "get_adaptive_engine_for_spec",
    "clear_adaptive_engine_cache",
    "adaptive_engine_cache_stats",
]


class AdaptiveEngine:
    """One compiled error-controlled sampler bound to a spec.

    Owns no solver tables of its own: the schedule endpoints, calibration
    grid, dtype, and mesh placement all come from the spec's fixed
    ``SamplingEngine`` (``self.fixed`` — the shared cache entry the plain
    spec would use), so an adaptive spec adds exactly one new compiled
    program, not a parallel engine stack.
    """

    def __init__(self, spec):
        ec = spec.error_control
        if ec is None:
            raise ValueError(
                "AdaptiveEngine needs a spec with error_control set; plain "
                "specs are served by SamplingEngine (get_engine_for_spec)")
        self.spec = spec
        self.ec = ec
        self.fixed = get_engine_for_spec(spec.replace(error_control=None))
        self.dtype = self.fixed.dtype
        self.ts = self.fixed.ts                   # (N+1,) descending, float64
        self.t_min = float(self.ts[-1])
        self.t_max = float(self.ts[0])
        self._compiled: dict[Any, tuple[Callable, Callable]] = {}
        self._aot: dict[Any, Callable] = {}

    # -- cost model ----------------------------------------------------------

    @property
    def nfe(self) -> int:
        """Nominal fixed-grid NFE (the spec's); kept for display parity."""
        return self.fixed.nfe

    @property
    def evals_per_sample(self) -> int:
        """Worst-case evals one sample can cost: 2 per scan iteration.

        The honest *realised* cost is per-sample ``info["nfe"]``; this bound
        is what deadline-slack routing prices an adaptive lane at.
        """
        return 2 * self.ec.max_iters

    # -- placement delegation ------------------------------------------------

    def shard(self, x: Array) -> Array:
        return self.fixed.shard(x)

    @property
    def mesh(self):
        return self.fixed.mesh

    # -- compiled program ----------------------------------------------------

    def _build(self, eps_fn: EpsFn, pas_key, donate: bool) -> Callable:
        """Trace the fixed-iteration masked scan.

        ``pas_key`` is ``None`` (plain) or ``(active tuple, coord_mode,
        n_basis)`` — static, like the fixed engine's corrected-prefix key.
        Each lane evaluates eps per-sample (t varies across the batch) via
        ``vmap`` of the exact single-row call the eager reference makes, so
        the parity oracle and the compiled path run the same model math.
        """
        cfg = self.ec
        dtype = self.dtype
        t_min = jnp.asarray(self.t_min, dtype)
        t_max = jnp.asarray(self.t_max, dtype)
        constrain = self.fixed._constrain
        eps_vec = jax.vmap(lambda xb, tb: eps_fn(xb[None, :], tb)[0])
        # identity multistep row [alpha=1, beta0=1, t=0]: the fused kernel
        # computes x + nat with per-sample step size folded into nat
        coef_id = jnp.asarray([1.0, 1.0, 0.0], dtype)

        if pas_key is not None:
            active, coord_mode, n_basis = pas_key
            n_steps = len(self.ts) - 1
            ts_asc = jnp.asarray(self.ts[::-1].copy(), dtype)   # ascending
            active_tab = jnp.asarray(np.asarray(active, bool))  # (N,)
            cap_d = n_basis + 1       # rolling window of accepted directions

        def run_core(x_t: Array, coords_tab: Optional[Array]):
            x0 = constrain(x_t.astype(dtype))
            b = x0.shape[0]
            x0_rows = x0[:, None, :]            # (B, 1, D): the Q's x_T row

            def step(carry, _):
                if pas_key is not None:
                    x, x_prev, t, pid, alive, n_acc, n_rej, dirs, ndirs = carry
                else:
                    x, x_prev, t, pid, alive, n_acc, n_rej = carry
                hist0 = jnp.zeros((1,) + x.shape, x.dtype)
                t_next = jnp.maximum(t * jnp.exp(-pid.h), t_min)
                lands = t_next <= t_min * (1.0 + 1e-6)
                dt = t_next - t                                  # (B,) <= 0
                d1 = eps_vec(x, t)
                dd1 = dt[:, None] * d1
                x_low = constrain(ops.fused_step(x, dd1, hist0, coef_id))

                if pas_key is not None:
                    # which calibration-grid cell holds t — is it corrected?
                    j = jnp.clip(n_steps - jnp.searchsorted(ts_asc, t,
                                                            side="left"),
                                 0, n_steps - 1)
                    gate = active_tab[j] & alive
                    rows = jnp.concatenate([x0_rows, dirs], 1)   # (B,cap,D)
                    mask = jnp.concatenate(
                        [jnp.ones((b, 1), bool),
                         jnp.arange(cap_d)[None, :] < ndirs[:, None]], axis=1)
                    u = jax.vmap(pas_basis, in_axes=(0, 0, 0, None))(
                        rows, mask, d1, n_basis)                 # (B,k,D)
                    cs = coords_tab[j]                           # (B,k)
                    if coord_mode == "relative":
                        cs = cs * jnp.sqrt(jnp.sum(d1 * d1, -1))[:, None]
                    # fold the per-sample step size into the coordinates so
                    # the fused projection+update pass lands x_low directly
                    x_low_c, dd1_c, _ = ops.fused_pas_step(
                        x, u, cs * dt[:, None], hist0, coef_id,
                        native_x0=False)
                    g = gate[:, None]
                    x_low = jnp.where(g, constrain(x_low_c), x_low)
                    dd1 = jnp.where(g, dd1_c, dd1)

                d2 = eps_vec(x_low, t_next)
                x_high = constrain(ops.fused_step(
                    x, 0.5 * (dd1 + dt[:, None] * d2), hist0, coef_id))
                err = ec_mod.error_ratio(x_low, x_high, x_prev, cfg)
                pid_new, accept = pid_propose(pid, err, cfg)
                acc = accept & alive
                rej = jnp.logical_and(~accept, alive)
                am = acc[:, None]
                x = jnp.where(am, x_high, x)
                x_prev = jnp.where(am, x_low, x_prev)
                t = jnp.where(acc, t_next, t)
                pid = PIDState(*(jnp.where(alive, new, old) for new, old
                                 in zip(pid_new, pid)))
                n_acc = n_acc + acc.astype(jnp.int32)
                n_rej = n_rej + rej.astype(jnp.int32)
                alive_next = jnp.logical_and(alive, ~(acc & lands))
                if pas_key is not None:
                    d_used = dd1 / jnp.where(dt == 0, 1.0, dt)[:, None]
                    rolled = jnp.roll(dirs, 1, axis=1).at[:, 0].set(d_used)
                    dirs = jnp.where(acc[:, None, None], rolled, dirs)
                    ndirs = jnp.minimum(ndirs + acc.astype(jnp.int32), cap_d)
                    out = (x, x_prev, t, pid, alive_next, n_acc, n_rej,
                           dirs, ndirs)
                else:
                    out = (x, x_prev, t, pid, alive_next, n_acc, n_rej)
                return out, alive

            t = jnp.full((b,), t_max, dtype)
            carry = (x0, x0, t, pid_init(b, cfg, dtype),
                     jnp.ones((b,), bool), jnp.zeros((b,), jnp.int32),
                     jnp.zeros((b,), jnp.int32))
            if pas_key is not None:
                carry = carry + (
                    jnp.zeros((b, cap_d) + x0.shape[1:], x0.dtype),
                    jnp.zeros((b,), jnp.int32))
            carry, trace = jax.lax.scan(step, carry, None,
                                        length=cfg.max_iters)
            x, _, t, _, alive, n_acc, n_rej = carry[:7]
            return x, n_acc, n_rej, t, ~alive, trace

        if pas_key is not None:
            def run(x_t: Array, coords: Array):
                return run_core(x_t, coords)
        else:
            def run(x_t: Array):
                return run_core(x_t, None)

        return self.fixed._jit(run, donate)

    # -- public API ----------------------------------------------------------

    def sample_with_info(self, eps_fn: EpsFn, x_t: Array, params=None,
                         cfg=None, *, donate_x: bool = False
                         ) -> tuple[Array, dict]:
        """Adaptive sample + controller info (all device arrays, unread).

        info keys: ``nfe`` (B,) int32 — 2*(accepted+rejected) evals per
        sample; ``n_accept``/``n_reject`` (B,) int32; ``finished`` (B,)
        bool — landed on t_min within the iteration budget; ``t`` (B,) —
        final time (t_min when finished); ``alive_trace`` (max_iters, B)
        bool — lane activity per scan iteration (monotonically
        non-increasing per lane); ``scan_evals`` int — evals the device
        executed for the whole batch including masked lanes.
        """
        if not self.ec.enabled:
            # error control off: the fixed-grid engine *is* the sampler
            x = self.fixed.sample(eps_fn, x_t, params=params, cfg=cfg,
                                  donate_x=donate_x)
            b = int(x.shape[0])
            nfe = np.full((b,), self.fixed.nfe, np.int32)
            return x, {"nfe": nfe, "n_accept": None, "n_reject": None,
                       "finished": np.ones((b,), bool), "t": None,
                       "alive_trace": None, "scan_evals": b * self.fixed.nfe}

        key, build, coords = self._variant(eps_fn, params, cfg, donate_x)
        args = (x_t,) if coords is None else (x_t, coords)
        fn = self._aot.get((key, _shape_sig(*args)))
        if fn is None:
            fn = self._get_compiled(key, build, eps_fn)
        x, n_acc, n_rej, t, finished, trace = fn(*args)
        info = {
            "nfe": 2 * (n_acc + n_rej),
            "n_accept": n_acc,
            "n_reject": n_rej,
            "finished": finished,
            "t": t,
            "alive_trace": trace,
            "scan_evals": 2 * self.ec.max_iters * int(x.shape[0]),
        }
        return x, info

    def sample(self, eps_fn: EpsFn, x_t: Array, params=None, cfg=None, *,
               donate_x: bool = False) -> Array:
        """Adaptive sample, info discarded (mirrors the fixed engine API)."""
        x, _ = self.sample_with_info(eps_fn, x_t, params=params, cfg=cfg,
                                     donate_x=donate_x)
        return x

    def _variant(self, eps_fn: EpsFn, params, cfg, donate_x: bool
                 ) -> tuple[Any, Callable, Optional[Array]]:
        """(variant key, builder, coords-or-None) — the one mapping from a
        (params, cfg, donate) triple onto a compiled masked-scan program,
        shared by ``sample_with_info`` and ``aot_compile``."""
        if params is not None and bool(np.asarray(params.active).any()):
            if cfg is None:
                from repro.core.pas import PASConfig
                cfg = PASConfig()
            pas_key = (tuple(bool(a) for a in np.asarray(params.active)),
                       cfg.coord_mode, int(params.coords.shape[1]))
            key = ("adaptive-pas", _fn_key(eps_fn), pas_key, donate_x)
            build = lambda: self._build(eps_fn, pas_key, donate_x)  # noqa: E731
            return key, build, jnp.asarray(params.coords, self.dtype)
        key = ("adaptive", _fn_key(eps_fn), donate_x)
        return key, (lambda: self._build(eps_fn, None, donate_x)), None

    # -- cold start: AOT compile + persistent-cache identity -----------------

    def engine_fingerprint(self) -> str:
        """Fixed-engine fingerprint extended with the controller config —
        everything ``spec.engine_key`` adds for adaptive specs."""
        h = hashlib.sha256()
        h.update(self.fixed.engine_fingerprint().encode())
        h.update(repr(self.ec).encode())
        return h.hexdigest()[:16]

    def _persist_key(self, model_key: Optional[str], program: str,
                     static_desc, sig) -> Optional[str]:
        if model_key is None:
            return None
        return "|".join([str(model_key), self.engine_fingerprint(), program,
                         repr(static_desc), repr(sig)])

    def aot_compile(self, eps_fn: EpsFn, batch: int, dim: int, *,
                    params=None, cfg=None, donate_x: bool = False,
                    cache: Optional[compile_cache.CompileCache] = None,
                    model_key: Optional[str] = None) -> dict:
        """Lower + compile the masked-scan program ahead of time.

        Mirrors ``SamplingEngine.aot_compile`` for the error-controlled
        path: the exact variant ``sample_with_info`` would dispatch for
        (params, cfg, donate_x) is compiled (or restored from a serialized
        executable) at (batch, dim), stashed for direct dispatch on single
        devices, and reported with per-device memory and collective counts.
        With error control disabled the spec's fixed engine *is* the
        sampler, so this delegates to its ``aot_compile``.
        """
        if not self.ec.enabled:
            return self.fixed.aot_compile(
                eps_fn, batch, dim, params=params, cfg=cfg,
                donate_x=donate_x, cache=cache, model_key=model_key)
        key, build, coords = self._variant(eps_fn, params, cfg, donate_x)
        fn = self._get_compiled(key, build, eps_fn)
        arg_specs = [jax.ShapeDtypeStruct((batch, dim), self.dtype)]
        if coords is not None:
            arg_specs.append(jax.ShapeDtypeStruct(coords.shape, coords.dtype))
        sig = tuple((tuple(s.shape), jnp.dtype(s.dtype).name)
                    for s in arg_specs)
        if cache is None:
            cache = compile_cache.active()
        fixed = self.fixed
        out = {
            "program": key[0],
            "devices": fixed.mesh.size if fixed.mesh is not None else 1,
            "mesh": (fixed.mesh_spec.to_dict()
                     if fixed.mesh_spec is not None else None),
            "batch": batch, "dim": dim,
        }
        out.update(_aot_program(
            self._aot, (key, sig), fn, arg_specs, cache=cache,
            persist_key=self._persist_key(model_key, key[0], key[2:], sig),
            executable_ok=fixed.mesh is None, serialize_ok=not donate_x))
        return out

    def _get_compiled(self, key, build, eps_fn) -> Callable:
        return _compiled_lookup(self._compiled, key, build, eps_fn)

    def compiled_variants(self) -> int:
        return len(self._compiled)

    def aot_variants(self) -> int:
        return len(self._aot)


# ---------------------------------------------------------------------------
# cache (same LRU contract as the fixed-engine cache)
# ---------------------------------------------------------------------------

_ADAPTIVE: dict[Any, AdaptiveEngine] = {}
_STATS = _CacheStats()
_MAX_ADAPTIVE = 32


def get_adaptive_engine_for_spec(spec) -> AdaptiveEngine:
    """Adaptive engine for a spec with ``error_control`` set.

    Keyed on ``spec.engine_key`` — which includes the ``ErrorControlConfig``
    when present, so two adaptive specs differing only in tolerances get
    distinct compiled programs while their shared fixed engine stays one
    cache entry.
    """
    if spec.error_control is None:
        raise ValueError(
            "spec has no error_control; use get_engine_for_spec for "
            "fixed-grid sampling")
    return _lru_lookup(_ADAPTIVE, _STATS, spec.engine_key,
                       lambda: AdaptiveEngine(spec), _MAX_ADAPTIVE)


def clear_adaptive_engine_cache() -> None:
    _ADAPTIVE.clear()
    _STATS.hits = _STATS.misses = 0


def adaptive_engine_cache_stats() -> dict[str, int]:
    return {"engines": len(_ADAPTIVE), "hits": _STATS.hits,
            "misses": _STATS.misses,
            "compiled_variants": sum(e.compiled_variants()
                                     for e in _ADAPTIVE.values()),
            "aot_variants": sum(e.aot_variants()
                                for e in _ADAPTIVE.values())}
