"""SamplingEngine: the single compiled sampling surface for PAS solvers.

The seed repo had three overlapping sampling paths (``solvers.sample``, the
per-step Python dispatch in ``pas.pas_sample_trajectory``, and the serve
loop's ad-hoc branch between them), each re-tracing per call and each
materialising the PAS projection as a separate XLA round-trip.  The engine
replaces all of them with one object per (solver, schedule, NFE, dtype):

* the solver's (N, K) coefficient tables are packed once, host-side, into a
  single ``(N, K+2)`` row layout ``[alpha, beta_0..beta_{K-1}, t]`` that both
  fused kernels consume (kernels/fused_step.py);
* plain sampling is one jitted ``lax.scan`` whose body is a single fused
  multiply-add kernel pass — batch rides natively through the kernel tiles;
* PAS-corrected sampling compiles the corrected prefix (active steps are few
  by construction — the adaptive search keeps ~10 parameters) with static
  branches and finishes with the same plain scan for the correction-free
  tail.  Inactive steps therefore keep the paper's zero-overhead promise.
  A corrected step is two passes over the flattened D axis and nothing else:
  one Gram tile pass (``kernels.ops.gram_qd``) whose tiny (n+1)^2 output
  feeds the weight-space basis (``pca.basis_weights`` — PCA + pinned v1 +
  Gram-Schmidt as an (n_basis, n+1) coefficient matrix, ||d|| read off the
  Gram diagonal), and one fused projection+update tile pass
  (``kernels.ops.fused_pas_project_step``) contracting the projected
  coordinates pw = cs @ W directly against the Q-buffer rows.  The
  (B, n_basis, D) basis of the seed path is never materialised;
* engines and their compiled callables are cached:
  ``get_engine(name, ts, dtype)`` is keyed on (solver name, schedule bytes,
  NFE, dtype) and per-engine jitted functions are keyed on the eps-model and
  the static correction pattern;
* engines are **mesh-native**: bound to a non-trivial
  ``repro.parallel.MeshSpec`` (which participates in the spec's engine-cache
  key), the jitted scan and PAS prefix carry ``NamedSharding`` on every
  (batch, D) buffer — batch over the DP axis, the flattened state dim over
  the state axis.  Corrected steps route the PAS Gram through the
  ``core.distributed`` single-psum collective
  (``batched_pas_weights_sharded``) whenever the state dim is sharded — the
  ~1 KB Gram psum is the *only* collective a corrected step pays, issued
  ahead of the weight-space math so it overlaps local compute; uneven
  shapes degrade to the replicated weights with a counted, once-warned
  fallback (``PASShardingFallbackWarning``).  With DP-only sharding the
  partitioned program is bit-identical in fp32 to the single-device engine
  (tests/test_mesh.py).  All carries (x, hist, Q) live
  inside one jitted program, so they never round-trip host memory; the serve
  loop additionally donates its flush input buffer (``donate_x=True``).

``TwoEvalSolver`` teachers (heun, dpm2) are served by the same entry point
via a scan over ``solver.step`` so callers never branch on solver family;
PAS params on a 2-eval solver raise, as in calibration.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed
from repro.core import pas as pas_mod
from repro.core.pas import (_batched_weights, _materialize_basis,
                            _projected_coords, _QBuffer)
from repro.core.solvers import LinearMultistepSolver, Solver, TwoEvalSolver
from repro.kernels import ops
from repro.parallel.mesh import MeshSpec

from . import compile_cache

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]

__all__ = [
    "SamplingEngine",
    "PASShardingFallbackWarning",
    "get_engine",
    "get_engine_for_spec",
    "engine_for_solver",
    "clear_engine_cache",
    "engine_cache_stats",
]


def _shape_sig(*arrays) -> tuple:
    """Hashable (shape, dtype) signature of a concrete argument list."""
    return tuple((tuple(a.shape), jnp.dtype(a.dtype).name) for a in arrays)


def _collective_counts(hlo: str) -> dict[str, int]:
    """Count collective ops in compiled HLO text (the placement report)."""
    colls = {name: hlo.count(f" {name}(") + hlo.count(f" {name}-start(")
             for name in ("all-reduce", "all-gather", "reduce-scatter",
                          "collective-permute", "all-to-all")}
    return {k: v for k, v in colls.items() if v}


def _compiled_report(compiled) -> dict:
    """Collectives + per-device memory of one AOT-compiled executable."""
    out: dict = {}
    try:
        out["collectives"] = _collective_counts(compiled.as_text())
    except Exception:                      # deserialized executables may not
        out["collectives"] = None          # expose HLO text; report honestly
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is not None:
        out["memory_per_device_bytes"] = {
            "arguments": ma.argument_size_in_bytes,
            "outputs": ma.output_size_in_bytes,
            "temps": ma.temp_size_in_bytes,
        }
    return out


def _aot_program(aot_store: dict, store_key, jitted_fn, arg_specs, *,
                 cache: Optional[compile_cache.CompileCache] = None,
                 persist_key: Optional[str] = None,
                 executable_ok: bool = True,
                 serialize_ok: bool = True) -> dict:
    """AOT lower+compile one jitted program (or restore its serialized
    executable), stash it for direct dispatch, and report on it.

    The shared engine-AOT primitive: tries the executable-serialization
    layer first (skips tracing *and* lowering; only with both a cache and a
    caller-supplied ``persist_key`` — see ``compile_cache``), else pays one
    timed ``.lower().compile()`` (which the XLA persistent cache makes
    cheap when warm) and serializes the result for the next process.
    ``executable_ok=False`` compiles and reports without stashing — mesh
    engines keep jit dispatch (AOT executables pin input shardings), and
    still win across processes through the XLA-level cache.
    ``serialize_ok=False`` opts a program out of the serialization layer
    entirely (no save, no load): deserialized executables lose the
    donation bookkeeping jit tracks for live buffers, and calling one that
    donates an input corrupts the freed buffer — donating variants rely on
    the XLA-level cache alone, which restores the *compilation* and lets
    the live jit/AOT machinery own donation.
    """
    report: dict = {}
    fn = None
    if not serialize_ok:
        persist_key = None
    if cache is not None and persist_key is not None:
        fn = cache.load_executable(persist_key)
        if fn is not None:
            report["source"] = "deserialized"
            report.update(_compiled_report(fn))
    if fn is None:
        t0 = time.perf_counter()
        compiled = jitted_fn.lower(*arg_specs).compile()
        dt = time.perf_counter() - t0
        compile_cache.record_compile_seconds(dt)
        report["source"] = "compiled"
        report["compile_seconds"] = round(dt, 3)
        report.update(_compiled_report(compiled))
        if cache is not None and persist_key is not None:
            report["serialized"] = (
                cache.save_executable(persist_key, compiled) is not None)
        fn = compiled
    if executable_ok:
        aot_store[store_key] = fn
    report["dispatchable"] = executable_ok
    return report


class PASShardingFallbackWarning(UserWarning):
    """A mesh-bound engine silently degraded the PAS basis placement.

    Emitted (once per process per reason) when a trace drops the DP spec or
    falls back to the replicated basis because a shape is not divisible by
    the mesh — the conditions under which "sharded PAS" quietly stops
    scaling.  Structured fields: ``reason`` (``uneven_state`` /
    ``uneven_batch``), ``shape`` (the (B, D) that failed), ``mesh`` (the
    MeshSpec dict).  Counts are cumulative per engine
    (``SamplingEngine.basis_fallback_stats``) and repo-wide in
    ``engine_cache_stats()['basis_fallbacks']``.
    """

    def __init__(self, msg: str, *, reason: str = "", shape=None, mesh=None):
        super().__init__(msg)
        self.reason = reason
        self.shape = tuple(shape) if shape is not None else None
        self.mesh = mesh


_FALLBACK_WARNED: set[str] = set()  # one warning per reason per process


def _fn_key(fn: Callable) -> Any:
    """Stable hashable identity for an eps model.

    The callable itself is the key whenever it is hashable: this pins the fn
    (and, for bound methods like ``gmm.eps`` — which create a fresh object
    per attribute access — the underlying instance) in the key tuple, so a
    garbage-collected model's recycled ``id`` can never alias a stale
    compiled entry.  Unhashable callables fall back to ``id`` and rely on
    the cache entry pinning them (``_get_compiled`` stores the fn alongside
    the compiled program, keeping the id valid for the entry's lifetime).
    """
    try:
        hash(fn)
        return fn
    except TypeError:
        self_obj = getattr(fn, "__self__", None)
        if self_obj is not None:
            return (id(self_obj), getattr(fn, "__func__", fn))
        return id(fn)


def _scaled_coords(coords: Array, d: Array, mode: str) -> Array:
    """Fold coord_mode into the kernel input: cs (B, k) = coords * scale_b."""
    if mode == "relative":
        scale = jnp.sqrt(jnp.sum(d * d, axis=-1))          # (B,) = ||d||
        return coords[None, :] * scale[:, None]
    return jnp.broadcast_to(coords[None, :], (d.shape[0], coords.shape[0]))


class SamplingEngine:
    """One compiled, batch-vmapped sampling surface for a bound solver.

    ``mesh`` is an optional ``repro.parallel.MeshSpec``; a non-trivial spec
    builds the device mesh once at engine construction and every compiled
    program is placed on it (see module docstring).  The trivial spec (or
    ``None``) compiles the exact single-device program.
    """

    def __init__(self, solver: Solver, dtype: jnp.dtype = jnp.float32,
                 mesh: Optional[MeshSpec] = None):
        self.solver = solver
        self.dtype = jnp.dtype(dtype)
        self.name = solver.name
        self.ts = np.asarray(solver.ts, dtype=np.float64)
        self.nfe = solver.nfe          # evals, not steps: 2x for heun/dpm2
        self._compiled: dict[Any, tuple[Callable, Callable]] = {}
        self._aot: dict[Any, Callable] = {}   # (variant, shapes) -> executable
        self._basis_fallbacks: dict[str, int] = {}

        self.mesh_spec = (mesh if mesh is not None and not mesh.is_single
                          else None)
        self.mesh = self.mesh_spec.build() if self.mesh_spec else None

        if isinstance(solver, LinearMultistepSolver):
            alpha = np.asarray(solver.alpha, np.float64)      # (N,)
            beta = np.asarray(solver.beta, np.float64)        # (N, K)
            self.k = int(beta.shape[1])
            self.hist_len = max(self.k - 1, 1)
            self.native_x0 = solver.native == "x0"
            # the packed table both fused kernels consume
            coef = np.concatenate(
                [alpha[:, None], beta, self.ts[:-1, None]], axis=1)
            self.coef = jnp.asarray(coef, self.dtype)         # (N, K+2)
        else:
            self.k = 0
            self.hist_len = 0
            self.native_x0 = False
            self.coef = None

    # -- construction-time helpers -----------------------------------------

    @property
    def ts_jax(self) -> Array:
        return jnp.asarray(self.ts, self.dtype)

    def _hist0(self, x: Array) -> Array:
        return jnp.zeros((self.hist_len,) + x.shape, x.dtype)

    def _push_hist(self, hist: Array, nat: Array) -> Array:
        if self.k <= 1:   # ddim/euler keep no history
            return hist
        return jnp.roll(hist, 1, axis=0).at[0].set(nat)

    def _native(self, x: Array, d: Array, t: Array) -> Array:
        return x - t * d if self.native_x0 else d

    # -- mesh placement ------------------------------------------------------

    def _x_pspec(self, shape, leading: int = 0) -> P:
        """PartitionSpec for a (..., B, D) buffer, divisibility-checked.

        ``leading`` counts replicated leading axes (1 for hist (H, B, D) and
        Q rows (cap, B, D)).  An axis the mesh doesn't divide evenly falls
        back to replication for that buffer (jax < 0.5 rejects uneven
        explicit shardings; the serve loop pads flushes so the hot path
        never hits this).
        """
        ms = self.mesh_spec
        b, d = shape[leading], shape[leading + 1]
        return P(*((None,) * leading
                   + (ms.batch_axis if ms.dp > 1 and b % ms.dp == 0 else None,
                      ms.state_axis if ms.state > 1 and d % ms.state == 0
                      else None)))

    def _constrain(self, x: Array, leading: int = 0) -> Array:
        """Pin a (..., B, D) buffer to the engine mesh (no-op when unbound)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self._x_pspec(x.shape, leading)))

    def _jit(self, fn: Callable, donate: bool) -> Callable:
        """jit a sampling program; arg 0 is the (B, D) state (the only
        donation candidate).  Placement rides on trace-time sharding
        constraints (shape-aware, see ``_x_pspec``) rather than rigid
        ``in_shardings``, so one engine serves every batch size."""
        if not donate:
            return jax.jit(fn)
        return jax.jit(fn, donate_argnums=(0,))

    def shard(self, x: Array) -> Array:
        """Place a (B, D) buffer onto the engine mesh (identity when unbound).

        ``Pipeline`` routes priors and calibration batches through this so
        data starts life device-resident in the layout the compiled scan
        expects — no implicit reshard on the first step.
        """
        if self.mesh is None:
            return x
        return jax.device_put(
            x, NamedSharding(self.mesh, self._x_pspec(x.shape)))

    # -- compiled paths ------------------------------------------------------

    def _plain_body(self, eps_fn: EpsFn):
        def body(carry, inp):
            x, hist = carry
            t, cf = inp
            d = eps_fn(x, t)
            nat = self._native(x, d, t)
            x_next = self._constrain(ops.fused_step(x, nat, hist, cf))
            return (x_next, self._push_hist(hist, nat)), None
        return body

    def _build_plain(self, eps_fn: EpsFn, donate: bool = False) -> Callable:
        if isinstance(self.solver, TwoEvalSolver):
            solver = self.solver
            ts = self.ts_jax

            def run(x_t: Array) -> Array:
                def body(carry, j):
                    x, hist = carry
                    x, hist, _ = solver.step(eps_fn, x, j, hist)
                    return (self._constrain(x), hist), None
                (x, _), _ = jax.lax.scan(
                    body, (self._constrain(x_t), solver.init_hist(x_t)),
                    jnp.arange(len(ts) - 1))
                return x
            return self._jit(run, donate)

        body = self._plain_body(eps_fn)
        ts = self.ts_jax[:-1]
        coef = self.coef

        def run(x_t: Array) -> Array:
            (x, _), _ = jax.lax.scan(
                body, (self._constrain(x_t), self._hist0(x_t)), (ts, coef))
            return x
        return self._jit(run, donate)

    def _note_basis_fallback(self, reason: str, shape) -> None:
        """Count (per engine) + warn (once per process per reason) when a
        trace degrades the sharded basis placement.  Runs at trace time —
        one count per corrected step per compiled variant, i.e. the number
        of degraded basis computations baked into compiled programs (a
        trajectory with two active steps counts twice per trace)."""
        self._basis_fallbacks[reason] = \
            self._basis_fallbacks.get(reason, 0) + 1
        if reason in _FALLBACK_WARNED:
            return
        _FALLBACK_WARNED.add(reason)
        ms = self.mesh_spec
        detail = {
            "uneven_state": (
                f"state dim {shape[1]} is not divisible by the mesh state "
                f"axis ({ms.state}); the PAS basis runs REPLICATED for this "
                f"program — sharded PAS is not engaged"),
            "uneven_batch": (
                f"batch {shape[0]} is not divisible by dp={ms.dp}; the PAS "
                f"basis drops its DP spec for this program (state sharding "
                f"kept; pad the batch to engage DP)"),
        }[reason]
        warnings.warn(PASShardingFallbackWarning(
            f"[{self.name}] PAS basis placement degraded: {detail}. "
            f"Counts: SamplingEngine.basis_fallback_stats() / "
            f"engine_cache_stats()['basis_fallbacks'].",
            reason=reason, shape=shape, mesh=ms.to_dict()), stacklevel=3)

    def basis_fallback_stats(self) -> dict[str, int]:
        """Per-reason counts of compiled programs whose PAS basis placement
        degraded (see ``PASShardingFallbackWarning``)."""
        return dict(self._basis_fallbacks)

    def _weights_fn(self, n_basis: int) -> Callable:
        """(q_rows, q_mask, d) -> (w, d_norm): the weight-space basis.

        w (B, n_basis, cap+1) float32 with masked-row columns zeroed, d_norm
        (B,) from the Gram diagonal.  Replicated vmap path, or the
        ``core.distributed`` single-psum collective path when the state dim
        is sharded.  Shapes are inspected at trace time: shard_map needs
        evenly divisible axes, so an uneven batch drops its DP spec and an
        uneven state dim falls back to the replicated weights for that trace
        only — both degradations are counted and warned
        (``PASShardingFallbackWarning``).
        """
        replicated = lambda rows, mask, d: _batched_weights(
            _QBuffer(rows, mask), d, n_basis)
        if self.mesh is None or self.mesh_spec.state <= 1:
            return replicated
        ms = self.mesh_spec

        def weights(rows, mask, d):
            if d.shape[1] % ms.state != 0:
                self._note_basis_fallback("uneven_state", d.shape)
                return replicated(rows, mask, d)
            bax = (ms.batch_axis
                   if ms.dp > 1 and d.shape[0] % ms.dp == 0 else None)
            if ms.dp > 1 and bax is None:
                self._note_basis_fallback("uneven_batch", d.shape)
            return distributed.batched_pas_weights_sharded(
                self.mesh, ms.state_axis, bax, n_basis)(rows, mask, d)
        return weights

    def _basis_fn(self, n_basis: int) -> Callable:
        """(q_rows, q_mask, d) -> u (B, n_basis, D), materialised.

        Built on ``_weights_fn`` (same Gram, same W — calibration's SGD and
        the sampling projection can never disagree on the basis); only
        callers that reuse U across iterations (calibration) should pay the
        materialisation.
        """
        weights = self._weights_fn(n_basis)

        def basis(rows, mask, d):
            w, _ = weights(rows, mask, d)
            return _materialize_basis(w, rows, d)
        return basis

    def _build_pas(self, eps_fn: EpsFn, active: tuple[bool, ...],
                   coord_mode: str, n_basis: int,
                   donate: bool = False) -> Callable:
        if not isinstance(self.solver, LinearMultistepSolver):
            raise TypeError(
                f"PAS correction requires a 1-eval solver; got {self.name}")
        n = len(self.ts) - 1
        last = max(j for j in range(n) if active[j])
        ts = self.ts_jax
        coef = self.coef
        body = self._plain_body(eps_fn)
        weights = self._weights_fn(n_basis)

        def run(x_t: Array, coords: Array) -> Array:
            x = self._constrain(x_t)
            hist = self._constrain(self._hist0(x_t), leading=1)
            # the calibration-time Q buffer layout, bounded to the rows the
            # corrected prefix can actually touch (shared with pas.py so the
            # layouts can never drift apart)
            q = _QBuffer.create(x_t, cap=pas_mod._sampling_q_cap(last, n))
            q = _QBuffer(self._constrain(q.rows, leading=1), q.mask)

            for j in range(last + 1):     # static unroll: ~#corrected steps
                t = ts[j]
                d = eps_fn(x, t)
                if active[j]:
                    # corrected step = two D passes: the Gram contraction
                    # (inside _weights_fn; on a mesh its ~1 KB psum is the
                    # only collective and overlaps the weight-space math),
                    # then the fused project+update tile pass below.  The
                    # (B, n_basis, D) basis is never materialised and ||d||
                    # comes off the Gram diagonal for free.
                    w, d_norm = weights(q.rows, q.mask, d)
                    pw = _projected_coords(coords[j], w, d_norm, coord_mode)
                    x, d_used, nat = ops.fused_pas_project_step(
                        x, q.rows, d, pw, hist, coef[j],
                        native_x0=self.native_x0)
                    x = self._constrain(x)
                else:
                    nat = self._native(x, d, t)
                    d_used = d
                    x = self._constrain(ops.fused_step(x, nat, hist, coef[j]))
                hist = self._push_hist(hist, nat)
                if j < last:
                    q = q.push(d_used, j + 1)

            if last + 1 < n:              # correction-free tail: plain scan
                (x, _), _ = jax.lax.scan(
                    body, (x, hist), (ts[last + 1:-1], coef[last + 1:]))
            return x
        return self._jit(run, donate)

    # -- public API ----------------------------------------------------------

    def sample(self, eps_fn: EpsFn, x_t: Array, params=None, cfg=None, *,
               donate_x: bool = False) -> Array:
        """Sample ts[0] -> ts[N].  The one sampling entry point.

        ``params``/``cfg`` are ``pas.PASParams``/``pas.PASConfig``; omit them
        (or pass params with no active step) for the uncorrected solver.
        ``donate_x=True`` compiles a variant that donates the ``x_t`` buffer
        to the scan (the serve loop's flush path: its input is never reused,
        so the initial-state copy is free); the caller's array is invalidated.
        Donating a buffer that was already donated to a still-in-flight
        dispatch (the double-buffered serve scheduler keeps up to
        ``max_in_flight`` flushes outstanding) is rejected with a clear
        error instead of jax's generic deleted-array failure: every flush
        must stage a fresh buffer.
        """
        if donate_x and getattr(x_t, "is_deleted", None) and x_t.is_deleted():
            raise ValueError(
                "donate_x=True on a buffer that was already donated (the "
                "array is deleted). Double-buffered flushes must stage a "
                "fresh buffer per dispatch — never reuse one an in-flight "
                "flush owns (see runtime.scheduler.ServeScheduler._flush).")
        key, build, coords = self._variant(eps_fn, params, cfg, donate_x)
        args = (x_t,) if coords is None else (x_t, coords)
        aot_fn = self._aot.get((key, _shape_sig(*args)))
        if aot_fn is not None:
            return aot_fn(*args)
        fn = self._get_compiled(key, build, eps_fn)
        return fn(*args)

    def _variant(self, eps_fn: EpsFn, params, cfg, donate_x: bool
                 ) -> tuple[Any, Callable, Optional[Array]]:
        """(variant key, builder, coords-or-None): the one place a
        (params, cfg, donate) triple maps onto a compiled-program key, so
        ``sample``, ``aot_compile`` and the fleet pre-warm paths can never
        target different programs."""
        if params is not None and bool(np.asarray(params.active).any()):
            if cfg is None:
                from repro.core.pas import PASConfig
                cfg = PASConfig()
            key = ("pas", _fn_key(eps_fn),
                   tuple(bool(a) for a in np.asarray(params.active)),
                   cfg.coord_mode, int(params.coords.shape[1]), donate_x)
            build = lambda: self._build_pas(                       # noqa: E731
                eps_fn, key[2], cfg.coord_mode, key[4], donate_x)
            return key, build, jnp.asarray(params.coords, self.dtype)
        key = ("plain", _fn_key(eps_fn), donate_x)
        return key, (lambda: self._build_plain(eps_fn, donate_x)), None

    # -- cold start: AOT compile + persistent-cache identity -----------------

    def engine_fingerprint(self) -> str:
        """Stable identity of this engine's compiled-program family.

        Hashes (solver name, schedule bytes, dtype, mesh) — everything the
        engine key carries — into the persistent executable-cache key, so a
        restored executable can never cross engines.
        """
        h = hashlib.sha256()
        h.update(self.name.encode())
        h.update(self.ts.tobytes())
        h.update(self.dtype.name.encode())
        if self.mesh_spec is not None:
            h.update(repr(sorted(self.mesh_spec.to_dict().items())).encode())
        return h.hexdigest()[:16]

    def _persist_key(self, model_key: Optional[str], program: str,
                     static_desc, sig) -> Optional[str]:
        """Executable-serialization key, or None when the caller did not
        name the eps model (serialized programs bake the model in; without
        a caller-supplied identity only the HLO-keyed XLA cache is safe)."""
        if model_key is None:
            return None
        return "|".join([str(model_key), self.engine_fingerprint(), program,
                         repr(static_desc), repr(sig)])

    def aot_compile(self, eps_fn: EpsFn, batch: int, dim: int, *,
                    params=None, cfg=None, donate_x: bool = False,
                    cache: Optional[compile_cache.CompileCache] = None,
                    model_key: Optional[str] = None) -> dict:
        """Lower + compile a sampling program ahead of time; report placement.

        This is the serve dry-run *and* the fleet pre-warm primitive: under
        a virtual host mesh
        (``XLA_FLAGS=--xla_force_host_platform_device_count=N``) it
        exercises the exact partitioned program the mesh engine runs in
        production and returns {devices, per-device memory, collective op
        counts} without executing a single model eval.  ``params``/``cfg``/
        ``donate_x`` select the exact variant ``sample`` would dispatch
        (default: the plain no-donate program, the historical behaviour).

        On a single device the compiled executable is stashed so the next
        same-shape ``sample`` call dispatches it directly (no jit re-trace);
        with a ``compile_cache`` active (``cache`` defaults to
        ``compile_cache.active()``) the executable is additionally
        serialized under (``model_key``, engine fingerprint, variant,
        shapes) and restored by later processes, skipping trace+lower+
        compile entirely.  ``model_key=None`` skips serialization (the
        XLA-level persistent cache still applies — it keys on HLO content
        and is always safe).
        """
        key, build, coords = self._variant(eps_fn, params, cfg, donate_x)
        fn = self._get_compiled(key, build, eps_fn)
        arg_specs = [jax.ShapeDtypeStruct((batch, dim), self.dtype)]
        if coords is not None:
            arg_specs.append(jax.ShapeDtypeStruct(coords.shape, coords.dtype))
        sig = tuple((tuple(s.shape), jnp.dtype(s.dtype).name)
                    for s in arg_specs)
        if cache is None:
            cache = compile_cache.active()
        out = {
            "program": key[0],
            "devices": self.mesh.size if self.mesh is not None else 1,
            "mesh": (self.mesh_spec.to_dict() if self.mesh_spec is not None
                     else None),
            "batch": batch, "dim": dim,
        }
        out.update(_aot_program(
            self._aot, (key, sig), fn, arg_specs, cache=cache,
            persist_key=self._persist_key(model_key, key[0], key[2:], sig),
            executable_ok=self.mesh is None, serialize_ok=not donate_x))
        return out

    def _get_compiled(self, key, build, eps_fn) -> Callable:
        """Compiled-program cache (shared LRU contract, see ``_compiled_lookup``)."""
        return _compiled_lookup(self._compiled, key, build, eps_fn)

    def compiled_variants(self) -> int:
        """Number of distinct (model, correction-pattern) programs cached."""
        return len(self._compiled)

    def aot_variants(self) -> int:
        """Number of AOT executables stashed for direct dispatch."""
        return len(self._aot)


# ---------------------------------------------------------------------------
# engine cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _CacheStats:
    hits: int = 0
    misses: int = 0


_ENGINES: dict[Any, SamplingEngine] = {}
_STATS = _CacheStats()
_MAX_ENGINES = 64
_MAX_COMPILED_PER_ENGINE = 16


def _lru_lookup(cache: dict, stats: Optional[_CacheStats], key: Any,
                build: Callable[[], Any], max_size: int) -> Any:
    """Bounded LRU cache (callers holding an evicted entry keep it alive).

    The one engine-cache implementation — the sampling and calibration
    engine caches and both per-engine compiled-program caches are instances
    of it, so eviction/recency semantics can never drift apart.
    """
    entry = cache.get(key)
    if entry is None:
        if stats is not None:
            stats.misses += 1
        if len(cache) >= max_size:
            cache.pop(next(iter(cache)))
        entry = build()
    else:
        if stats is not None:
            stats.hits += 1
        del cache[key]                 # re-insert: dict order tracks recency
    cache[key] = entry
    return entry


def _compiled_lookup(cache: dict, key: Any, build: Callable[[], Callable],
                     eps_fn: Callable) -> Callable:
    """Per-engine compiled-program cache; pins eps_fn so id-based keys stay
    valid (see ``_fn_key``).  Bounded LRU (least-recently-used variant
    evicted) so processes that rotate models or correction patterns don't
    pin every model forever.  Shared by ``SamplingEngine`` and
    ``CalibrationEngine``.
    """
    entry = _lru_lookup(cache, None, key,
                        lambda: (eps_fn, build()), _MAX_COMPILED_PER_ENGINE)
    return entry[1]


def _lookup(key: Any, build: Callable[[], SamplingEngine]) -> SamplingEngine:
    return _lru_lookup(_ENGINES, _STATS, key, build, _MAX_ENGINES)


def get_engine_for_spec(spec) -> SamplingEngine:
    """Engine for a ``repro.api.SamplerSpec`` — the canonical keying.

    The cache key is ``spec.engine_key`` = (solver, nfe, schedule, dtype,
    mesh): the engine-relevant projection of the spec, so specs differing
    only in teacher or PASConfig share one compiled binding, while specs
    differing in placement get their own (a mesh engine and a single-device
    engine compile different programs).
    """
    return _lookup(spec.engine_key,
                   lambda: SamplingEngine(spec.make_solver(),
                                          jnp.dtype(spec.dtype),
                                          mesh=spec.mesh))


def _warn_legacy(old: str, new: str) -> None:
    import warnings
    warnings.warn(
        f"{old} is deprecated; migrate to {new} (see README "
        f"'Migrating from the legacy API')",
        DeprecationWarning, stacklevel=3)


def get_engine(name: str, ts: np.ndarray,
               dtype: jnp.dtype = jnp.float32) -> SamplingEngine:
    """Engine for (solver name, schedule, dtype) — thin shim over the spec
    keying: the ad-hoc tuple is lifted to a canonical ``SamplerSpec`` (see
    ``repro.api.spec_from_schedule``), so legacy callers share cache entries
    with spec-built pipelines.

    .. deprecated::
        Build a ``SamplerSpec`` and call ``get_engine_for_spec(spec)`` (or
        go through ``repro.api.Pipeline``, which owns the binding)."""
    _warn_legacy("get_engine(name, ts, dtype)",
                 "get_engine_for_spec(SamplerSpec(...))")
    from repro.api.spec import spec_from_schedule  # deferred: api builds on engine
    return get_engine_for_spec(spec_from_schedule(name, ts, dtype))


def engine_for_solver(solver: Solver,
                      dtype: jnp.dtype = jnp.float32) -> SamplingEngine:
    """Engine for an already-bound solver (shares the get_engine cache).

    .. deprecated::
        Build a ``SamplerSpec`` and call ``get_engine_for_spec(spec)`` (or
        go through ``repro.api.Pipeline``).  Custom solver objects whose
        name is not in the ``repro.api`` registry are still served here
        (the solver is already constructed — nothing to look up); they key
        on the raw (name, schedule bytes, dtype) tuple instead.
    """
    _warn_legacy("engine_for_solver(solver)",
                 "get_engine_for_spec(SamplerSpec(...)) / Pipeline.from_spec")
    return _engine_for_solver(solver, dtype)


def _engine_for_solver(solver: Solver,
                       dtype: jnp.dtype = jnp.float32) -> SamplingEngine:
    """Internal, warning-free half of ``engine_for_solver`` (compat shims
    and the calibration engine route here so legacy *public* calls warn
    exactly once, at the caller's boundary)."""
    from repro.api.spec import spec_from_schedule  # deferred: api builds on engine
    try:
        key = spec_from_schedule(solver.name, solver.ts, dtype).engine_key
    except ValueError:
        ts = np.asarray(solver.ts, np.float64)
        key = ("unregistered", solver.name, ts.tobytes(), len(ts) - 1,
               jnp.dtype(dtype).name)
    return _lookup(key, lambda: SamplingEngine(solver, dtype))


def clear_engine_cache() -> None:
    _ENGINES.clear()
    _STATS.hits = _STATS.misses = 0


def engine_cache_stats() -> dict:
    """Cache shape + per-engine compiled-program totals.

    ``compiled_variants`` sums ``compiled_variants()`` over every live cache
    entry, so mesh-keyed engines (which otherwise look identical in the
    ``engines`` count) are observable in the pipeline-smoke CI log.
    ``aot_variants`` counts executables stashed for direct dispatch by the
    pre-warm paths, and ``persistent`` carries the process-wide
    ``compile_cache`` counters (XLA disk-cache hits/misses, serialized-
    executable hits/stale fallbacks, wall seconds spent compiling) so a
    fleet log can tell a warm start from a cold one.
    """
    return {"engines": len(_ENGINES), "hits": _STATS.hits,
            "misses": _STATS.misses,
            "compiled_variants": sum(e.compiled_variants()
                                     for e in _ENGINES.values()),
            "aot_variants": sum(e.aot_variants() for e in _ENGINES.values()),
            "basis_fallbacks": sum(sum(e._basis_fallbacks.values())
                                   for e in _ENGINES.values()),
            "persistent": compile_cache.cache_stats()}
