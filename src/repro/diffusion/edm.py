"""EDM (Karras et al. 2022) preconditioning, training loss, and eps adapters.

The paper's setup: alpha_t = 1, sigma_t = t, PF-ODE dx/dt = eps(x, t).
Any raw network F(x, sigma) becomes a denoiser via

    D(x, sigma) = c_skip x + c_out F(c_in x, c_noise)

and PAS consumes eps(x, t) = (x - D(x, t)) / t.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = ["EDMConfig", "precondition", "eps_from_denoiser", "edm_loss",
           "sample_training_sigmas"]


@dataclasses.dataclass(frozen=True)
class EDMConfig:
    sigma_data: float = 0.5
    p_mean: float = -1.2       # log-normal training-sigma distribution
    p_std: float = 1.2
    sigma_min: float = 0.002
    sigma_max: float = 80.0


def _coeffs(sigma: Array, sd: float):
    s2 = sigma ** 2
    denom = s2 + sd ** 2
    c_skip = sd ** 2 / denom
    c_out = sigma * sd / jnp.sqrt(denom)
    c_in = 1.0 / jnp.sqrt(denom)
    c_noise = 0.25 * jnp.log(sigma)
    return c_skip, c_out, c_in, c_noise


def precondition(raw_fn: Callable, cfg: EDMConfig = EDMConfig()) -> Callable:
    """raw F(x_scaled, c_noise) -> denoiser D(x, sigma). x (B, D), sigma (B,)."""

    def denoiser(x: Array, sigma: Array) -> Array:
        sigma = jnp.broadcast_to(sigma, x.shape[:1]).astype(jnp.float32)
        c_skip, c_out, c_in, c_noise = _coeffs(sigma[:, None], cfg.sigma_data)
        return c_skip * x + c_out * raw_fn(c_in * x, c_noise[:, 0])

    return denoiser


def eps_from_denoiser(denoiser: Callable) -> Callable:
    """D(x, sigma) -> eps(x, t) for the PF-ODE solvers (paper eq. 6)."""

    def eps(x: Array, t: Array) -> Array:
        t = jnp.maximum(jnp.asarray(t, jnp.float32), 1e-8)
        return (x - denoiser(x, t)) / t

    return eps


def sample_training_sigmas(key, n: int, cfg: EDMConfig = EDMConfig()) -> Array:
    return jnp.exp(cfg.p_mean + cfg.p_std * jax.random.normal(key, (n,)))


def edm_loss(denoiser_fn: Callable, key, x0: Array,
             cfg: EDMConfig = EDMConfig()) -> Array:
    """Weighted denoising score-matching loss (EDM eq. 2-8)."""
    k_sig, k_eps = jax.random.split(key)
    sigma = sample_training_sigmas(k_sig, x0.shape[0], cfg)
    noise = jax.random.normal(k_eps, x0.shape, x0.dtype)
    x_noisy = x0 + sigma[:, None] * noise
    d = denoiser_fn(x_noisy, sigma)
    weight = (sigma ** 2 + cfg.sigma_data ** 2) / (sigma * cfg.sigma_data) ** 2
    return jnp.mean(weight[:, None] * (d - x0) ** 2)
