from .edm import (EDMConfig, edm_loss, eps_from_denoiser, precondition,
                  sample_training_sigmas)
from .mlp_denoiser import init_denoiser, raw_apply

__all__ = ["EDMConfig", "edm_loss", "eps_from_denoiser", "precondition",
           "sample_training_sigmas", "init_denoiser", "raw_apply"]
