"""Tiny sigma-conditioned MLP denoiser (the "learned model" path for PAS
validation: paper-kind EDM model trainable in seconds on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import sigma_embedding

Array = jax.Array

__all__ = ["init_denoiser", "raw_apply"]


def init_denoiser(key, data_dim: int, width: int = 256, depth: int = 4) -> dict:
    ks = jax.random.split(key, depth + 3)
    p = {"in": _lin(ks[0], data_dim + width, width),
         "temb": _lin(ks[1], width, width),
         "out": {"w": jnp.zeros((width, data_dim)),
                 "b": jnp.zeros((data_dim,))}}
    p["hidden"] = [_lin(ks[2 + i], width, width) for i in range(depth)]
    return p


def _lin(key, fan_in, fan_out) -> dict:
    return {"w": jax.random.normal(key, (fan_in, fan_out)) / jnp.sqrt(fan_in),
            "b": jnp.zeros((fan_out,))}


def _apply(p, x):
    return x @ p["w"] + p["b"]


def raw_apply(params: dict, x: Array, c_noise: Array) -> Array:
    """F(x, c_noise): x (B, D), c_noise (B,) -> (B, D)."""
    width = params["temb"]["w"].shape[0]
    t = sigma_embedding(jnp.exp(4.0 * c_noise), width)   # c_noise = log(s)/4
    t = jax.nn.silu(_apply(params["temb"], t))
    h = _apply(params["in"], jnp.concatenate([x, t], axis=-1))
    for layer in params["hidden"]:
        h = h + _apply(layer, jax.nn.silu(h))            # residual MLP
    return _apply(params["out"], jax.nn.silu(h))
