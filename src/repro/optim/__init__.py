from .optimizers import SGD, AdamW, AdamWState, SGDState, global_norm, warmup_cosine

__all__ = ["SGD", "AdamW", "AdamWState", "SGDState", "global_norm",
           "warmup_cosine"]
