"""int8 gradient compression with error feedback for DP all-reduce.

The cross-pod gradient all-reduce is the dominant multi-pod collective for
training (the pod axis is the slow DCN-ish link).  Compressing each leaf to
int8 + a per-leaf f32 scale cuts that traffic ~4x (f32) / ~2x (bf16); the
quantisation residual is carried in an error-feedback buffer so the bias is
O(1/steps) instead of accumulating (Seide et al. / EF-SGD).

Implemented as a shard_map collective:  q = round(g'/s)*psum -> dq ; where
g' = g + e (error-feedback) and s = psum-max(|g'|)/127 is shared so the int8
sum is exact up to clipping.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["init_error_feedback", "compressed_psum_mean", "ef_compress_leaf"]


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress_leaf(g: jax.Array, e: jax.Array, axis_name
                     ) -> tuple[jax.Array, jax.Array]:
    """One leaf: error-feedback int8 all-reduce-mean over `axis_name`.

    Returns (mean gradient approximation, new error buffer).
    """
    n = jax.lax.psum(1, axis_name)
    gf = g.astype(jnp.float32) + e
    scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    local_deq = q.astype(jnp.float32) * scale
    new_e = gf - local_deq                       # residual stays local
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = summed.astype(jnp.float32) * scale / n
    return mean.astype(g.dtype), new_e


def compressed_psum_mean(grads, error, axis_name):
    """Tree-wise error-feedback compressed mean all-reduce (inside shard_map)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    outs, new_es = [], []
    for g, e in zip(flat_g, flat_e):
        o, ne = ef_compress_leaf(g, e, axis_name)
        outs.append(o)
        new_es.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, new_es))
