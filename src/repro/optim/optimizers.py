"""Optimizers in pure JAX (optax is not available offline): AdamW + SGD,
global-norm clipping, LR schedules.  Moment states are float32 regardless of
param dtype; the state pytree mirrors params so FSDP sharding rules apply
leaf-by-leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array       # scalar int32
    m: Any            # pytree like params, float32
    v: Any            # pytree like params, float32


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Array], Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0

    def init(self, params) -> AdamWState:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(f32, params),
                          v=jax.tree.map(f32, params))

    def _lr(self, step: Array) -> Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads, state: AdamWState, params
               ) -> tuple[Any, AdamWState, dict]:
        step = state.step + 1
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv
                         + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state.v, grads)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mm, vv):
            u = (mm / c1) / (jnp.sqrt(vv / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v), {
            "grad_norm": gnorm, "lr": lr}


class SGDState(NamedTuple):
    step: Array
    mom: Any


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float = 1e-2
    momentum: float = 0.9

    def init(self, params) -> SGDState:
        return SGDState(step=jnp.zeros((), jnp.int32),
                        mom=jax.tree.map(
                            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(self, grads, state: SGDState, params):
        mom = jax.tree.map(
            lambda b, g: self.momentum * b + g.astype(jnp.float32),
            state.mom, grads)
        new_params = jax.tree.map(
            lambda p, b: (p.astype(jnp.float32) - self.lr * b).astype(p.dtype),
            params, mom)
        return new_params, SGDState(step=state.step + 1, mom=mom), {}


def global_norm(tree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable[[Array], Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return sched
