"""MeshSpec: the declarative, hashable placement half of a sampler spec.

A ``MeshSpec`` describes *where* a sampling program runs — a (dp, state)
device grid plus the mesh-axis names the batch and flattened state dims are
sharded over — the same way ``ScheduleSpec`` describes *when* it evaluates.
It is a frozen dataclass so it can ride inside ``repro.api.SamplerSpec``
(hashable: participates in the engine-cache key; JSON-round-trippable: lands
in the artifact header), while staying importable from the engine layer,
which sits below ``repro.api``.

Placement is not part of the sampler's math: two specs differing only in
mesh produce bit-identical fp32 samples (tests/test_mesh.py), and a
``PASArtifact`` saved under one mesh reloads onto any other
(``Pipeline.load(..., mesh=...)``).

Axis conventions match ``repro.parallel.sharding.AxisRules``: the batch axis
is data-parallel ("data"), the state axis shards the flattened sample dim D
("model") and is what the ``core.distributed`` collectives reduce over, and
the tensor-parallel axis ("tensor") shards backbone weights *inside* the eps
function (``repro.models.eps``) — engine (B, D) buffers are never sharded
over it, so its collectives nest freely inside the sampling scan.  (The
state axis predates real backbones and kept its historical "model" name;
backbone TP lives on "tensor".)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshSpec", "compat_make_mesh", "shard_map"]


try:                                    # jax >= 0.6 top-level export
    shard_map = jax.shard_map
except AttributeError:                  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]


def compat_make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """jax.make_mesh across jax versions (explicit Auto axis types on >=0.5)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """A (dp, state, tp) sampling mesh: batch-DP x state-dim x backbone-TP.

    ``dp`` shards the batch axis of every (B, D) sampling buffer;
    ``state`` shards the flattened state dim D (the axis every PAS reduction
    runs over — see ``core.distributed``); ``tp`` shards the *backbone*
    (eps-model weights and per-layer activations via
    ``parallel.sharding.AxisRules`` — see ``repro.models.eps``) and never
    touches the engine's (B, D) buffers.  The default (1, 1, 1) is the
    single-device spec: engines bound to it compile exactly the pre-mesh
    program and no mesh is constructed at all.  When ``tp == 1`` the built
    mesh is the legacy two-axis (dp, state) mesh, so every existing spec
    hashes, fingerprints, and compiles exactly as before.
    """

    dp: int = 1
    state: int = 1
    batch_axis: str = "data"
    state_axis: str = "model"
    tp: int = 1
    tp_axis: str = "tensor"

    def __post_init__(self):
        object.__setattr__(self, "dp", int(self.dp))
        object.__setattr__(self, "state", int(self.state))
        object.__setattr__(self, "tp", int(self.tp))
        if self.dp < 1 or self.state < 1 or self.tp < 1:
            raise ValueError(f"mesh axes must be >= 1, got dp={self.dp} "
                             f"state={self.state} tp={self.tp}")
        names = (self.batch_axis, self.state_axis, self.tp_axis)
        if len(set(names)) != 3:
            raise ValueError(f"mesh axis names must be distinct, got {names}")

    # -- geometry ----------------------------------------------------------

    @property
    def n_devices(self) -> int:
        return self.dp * self.state * self.tp

    @property
    def is_single(self) -> bool:
        """True for the trivial spec: no mesh is built, nothing is sharded."""
        return self.n_devices == 1

    def build(self) -> Mesh:
        """Construct the device mesh (requires dp*state*tp visible devices).

        ``tp == 1`` builds the historical two-axis (dp, state) mesh —
        bit-identical programs and cache keys for every pre-TP spec; only a
        genuine tensor-parallel request grows the third axis.
        """
        avail = len(jax.devices())
        if avail < self.n_devices:
            raise ValueError(
                f"MeshSpec(dp={self.dp}, state={self.state}, tp={self.tp}) "
                f"needs {self.n_devices} devices but only {avail} are visible "
                f"(hint: XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{self.n_devices} for a virtual host mesh)")
        if self.tp == 1:
            return compat_make_mesh((self.dp, self.state),
                                    (self.batch_axis, self.state_axis))
        return compat_make_mesh(
            (self.dp, self.state, self.tp),
            (self.batch_axis, self.state_axis, self.tp_axis))

    # -- shardings ---------------------------------------------------------

    def x_pspec(self) -> P:
        """PartitionSpec for a (B, D) sampling buffer under this mesh."""
        return P(self.batch_axis if self.dp > 1 else None,
                 self.state_axis if self.state > 1 else None)

    def x_sharding(self, mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.x_pspec())

    def replicated(self, mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, P())

    def pad_batch(self, n: int) -> int:
        """Rows of padding needed to make an n-row batch DP-divisible.

        Used by the serve loop for flushes and by ``launch/serve
        --calibrate-batch`` for calibration-on-launch batches; pad rows are
        always drawn in-distribution (repeated or fresh prior rows) and
        masked back out of anything user-visible.
        """
        return (-n) % self.dp

    def pad_rows(self, x) -> tuple["jax.Array", int]:
        """Pad a (B, D) buffer to a DP-divisible row count; returns (x, pad).

        Pad rows repeat the input rows (always in-distribution for the
        model) and must be masked back out of anything user-visible.  The
        single implementation every flush path shares (sync serve loop,
        async scheduler, ``Pipeline.sample_async``).
        """
        n = int(x.shape[0])
        pad = self.pad_batch(n)
        if not pad:
            return x, 0
        filler = jnp.tile(x, (pad // n + 1, 1))[:pad]
        return jnp.concatenate([x, filler], axis=0), pad

    # -- serialisation -----------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict | None) -> "MeshSpec":
        return cls(**(d or {}))
