from .mesh import MeshSpec
from .sharding import (AxisRules, axis_rules, constrain, current_rules,
                       param_partition_specs, spec_for)

__all__ = ["AxisRules", "MeshSpec", "axis_rules", "constrain", "current_rules",
           "param_partition_specs", "spec_for"]
