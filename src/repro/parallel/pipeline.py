"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Layers are split into S stages along a "stage" mesh axis; microbatches flow
through the classic (S + M - 1)-tick schedule: each tick every stage applies
its layer block to the activation it holds, then activations rotate one stage
forward with a single collective_permute.  Bubble fraction = (S-1)/(S+M-1).

Opt-in (parallel/pipeline is not used by the default 40-cell dry-run config —
scan-over-layers + FSDP is the default production layout; see DESIGN.md §5),
but fully functional and tested (tests/test_pipeline.py, 4 virtual devices).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(mesh: Mesh, stage_fn: Callable, stage_params,
                   x_microbatches: jax.Array, axis: str = "stage") -> jax.Array:
    """Run M microbatches through S pipeline stages.

    stage_fn(params_slice, x) -> x        (one stage's computation)
    stage_params: pytree with leading axis S (one slice per stage)
    x_microbatches: (M, mb, ...) microbatched input
    Returns (M, mb, ...) outputs, in order.
    """
    s = mesh.shape[axis]
    m = x_microbatches.shape[0]
    if m < 1:
        raise ValueError("need at least one microbatch")

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(axis), P(None)),       # params sharded by stage; x replicated
        out_specs=P(None),
    )
    def run(params_local, xs):
        params_local = jax.tree.map(lambda t: t[0], params_local)
        stage = jax.lax.axis_index(axis)
        n_ticks = s + m - 1
        fwd_perm = [(i, (i + 1) % s) for i in range(s)]

        xs = jax.lax.pvary(xs, (axis,))    # device-varying from the start
        buf = jnp.zeros_like(xs[0])        # activation currently held
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if any remain)
            inject = jnp.where(t < m, t, m - 1)
            buf = jnp.where(stage == 0,
                            jnp.where(t < m, xs[inject], buf), buf)
            live = jnp.logical_and(stage <= t, t - stage < m)
            y = stage_fn(params_local, buf)
            buf = jnp.where(live, y, buf)
            # last stage emits its finished microbatch (select, not cond —
            # shard_map tracks device-varyingness through both branches)
            emit_idx = jnp.clip(t - (s - 1), 0, m - 1)
            is_emit = jnp.logical_and(stage == s - 1, t >= s - 1)
            emitted = jax.lax.dynamic_update_slice_in_dim(
                outs, buf[None], emit_idx, axis=0)
            outs = jnp.where(is_emit, emitted, outs)
            # rotate activations forward one stage
            buf = jax.lax.ppermute(buf, axis, fwd_perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # outputs live on the last stage; share them with every stage
        outs = jax.lax.psum(
            jnp.where(stage == s - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return run(stage_params, x_microbatches)
