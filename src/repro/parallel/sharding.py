"""Logical-axis sharding rules (DP/FSDP/TP/EP/SP) for the model zoo.

Models annotate activations with *logical* axis names ("batch", "seq",
"model_dim", "heads", "ff", "experts", "vocab"); AxisRules maps them to
physical mesh axes.  Parameters get PartitionSpecs from their *role* (the
dict key path in the params pytree) — right-aligned, so scan-stacked leaves
(leading n_groups axis) shard their trailing matrix dims and replicate the
group axis.

Divisibility: a logical rule is applied only if the mapped mesh-axis product
divides the dimension; otherwise that dim falls back to replication (e.g.
kv_heads=1 MQA under 16-way TP -> KV replicated, Q sharded).
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["AxisRules", "axis_rules", "current_rules", "constrain",
           "spec_for", "param_partition_specs"]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Logical -> physical axis mapping + the mesh (for divisibility checks)."""

    mesh: jax.sharding.Mesh
    batch: tuple[str, ...] = ("pod", "data")   # DP axes
    model: tuple[str, ...] = ("model",)        # TP axes
    fsdp: tuple[str, ...] = ()                 # weight-shard axes (ZeRO-3)
    seq: tuple[str, ...] = ()                  # sequence-parallel axes
    expert: tuple[str, ...] = ("model",)       # EP axes

    def axes_size(self, axes: tuple[str, ...]) -> int:
        size = 1
        for a in axes:
            if a in self.mesh.shape:
                size *= self.mesh.shape[a]
        return size

    def physical(self, logical: Optional[str]) -> tuple[str, ...]:
        if logical is None:
            return ()
        table = {
            "batch": self.batch, "model": self.model, "fsdp": self.fsdp,
            "seq": self.seq, "expert": self.expert,
        }
        return tuple(a for a in table.get(logical, ()) if a in self.mesh.shape)


_STATE = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def _dim_spec(rules: AxisRules, logical: Optional[str], size: int):
    axes = rules.physical(logical)
    if not axes:
        return None
    n = rules.axes_size(axes)
    if n <= 1 or size % n != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def spec_for(shape: tuple[int, ...], logical: tuple[Optional[str], ...],
             rules: Optional[AxisRules] = None) -> P:
    """PartitionSpec for `shape` given right-aligned logical dim names.

    A mesh axis may appear at most once per spec; when two logical dims map
    to the same physical axis (e.g. SP seq->model and vocab->model on logits)
    the earlier dim wins and later dims replicate.
    """
    rules = rules or current_rules()
    if rules is None:
        return P()
    logical = (None,) * (len(shape) - len(logical)) + tuple(logical)
    used: set[str] = set()
    dims = []
    for s, l in zip(shape, logical):
        d = _dim_spec(rules, l, s)
        axes = (d,) if isinstance(d, str) else tuple(d or ())
        if d is not None and any(a in used for a in axes):
            d = None
            axes = ()
        used.update(axes)
        dims.append(d)
    return P(*dims)


def constrain(x, *logical: Optional[str]):
    """with_sharding_constraint using logical names; no-op without rules."""
    rules = current_rules()
    if rules is None:
        return x
    spec = spec_for(x.shape, logical, rules)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# parameter partition specs by role
# ---------------------------------------------------------------------------

# role patterns matched against the '/'-joined params path (right-aligned
# logical names for the trailing dims; leading scan-group dims replicate).
# (fsdp, model) 2-D sharding for the big matrices is the MaxText-style layout.
_PARAM_RULES: list[tuple[str, tuple[Optional[str], ...]]] = [
    (r"tok_embed$",        ("model", "fsdp")),     # (V, E): vocab-TP
    (r"pos_embed$",        (None, None)),
    (r"lm_head$",          ("fsdp", "model")),     # (E, V)
    (r"(wq|wk|wv)$",       ("fsdp", "model")),     # (E, H*Dh)
    (r"(bq|bk|bv)$",       ("model",)),
    (r"wo$",               ("model", "fsdp")),     # (H*Dh, E)
    (r"router$",           (None, None)),          # (E, n_exp) tiny, replicate
    (r"experts/(w1|w3)$",  ("expert", "fsdp", "model")),  # (n_exp, E, F)
    (r"experts/w2$",       ("expert", "model", "fsdp")),  # (n_exp, F, E)
    (r"(w1|w3)$",          ("fsdp", "model")),     # (E, F)
    (r"w2$",               ("model", "fsdp")),     # (F, E)
    (r"in_proj$",          ("fsdp", "model")),     # mamba/rglru (E, W)
    (r"gate_proj$",        ("fsdp", "model")),
    (r"out_proj$",         ("model", "fsdp")),     # (W, E)
    (r"conv_w$",           ("model", None)),       # (W, k)
    (r"conv_b$",           ("model",)),
    (r"x_proj$",           ("model", None)),       # (Di, r+2N)
    (r"dt_proj$",          (None, "model")),       # (r, Di)
    (r"dt_bias$",          ("model",)),
    (r"a_log$",            ("model", None)),       # (Di, N)
    (r"skip_d$",           ("model",)),
    (r"lru_a$",            ("model",)),            # (W,)
    (r"(lru_in_gate|lru_rec_gate)$", ("model", None)),
    (r"(scale|bias)$",     (None,)),               # norms: replicate
    (r".*",                (None,)),               # default: replicate
]


def _role_logical(path: str, ndim: int) -> tuple[Optional[str], ...]:
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            return logical
    return (None,)


def param_partition_specs(params, rules: Optional[AxisRules] = None):
    """PartitionSpec pytree for a params pytree (works on ShapeDtypeStructs)."""
    rules = rules or current_rules()

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        pstr = "/".join(str(k) for k in keys)
        return spec_for(leaf.shape, _role_logical(pstr, leaf.ndim), rules)

    return jax.tree_util.tree_map_with_path(one, params)
