"""Time schedules for PF-ODE sampling (paper eq. 19).

Conventions (DESIGN.md §9): schedules are *descending* arrays of length N+1,
``ts[0] = t_max (=T)`` down to ``ts[N] = t_min (=eps)``.  The paper indexes
steps i = N..1 with t_N = T, t_0 = eps; our array position ``j`` corresponds to
the paper's index ``i = N - j``.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "polynomial_schedule",
    "nested_teacher_schedule",
    "teacher_refinement",
    "paper_index",
]


def polynomial_schedule(
    nfe: int,
    t_min: float = 0.002,
    t_max: float = 80.0,
    rho: float = 7.0,
) -> np.ndarray:
    """EDM/Karras polynomial schedule (paper eq. 19), descending, len nfe+1.

    t_i = (t0^{1/rho} + (i/N) (tN^{1/rho} - t0^{1/rho}))^rho with the paper's
    i in [N..0]; returned as ts[j] for j = 0..N (j=0 is T, j=N is eps).
    """
    if nfe < 1:
        raise ValueError(f"nfe must be >= 1, got {nfe}")
    i = np.arange(nfe, -1, -1, dtype=np.float64)  # paper index N..0
    a = t_min ** (1.0 / rho)
    b = t_max ** (1.0 / rho)
    ts = (a + (i / nfe) * (b - a)) ** rho
    # exact endpoints (avoid fp drift so nested grids index-align bit-exactly)
    ts[0] = t_max
    ts[-1] = t_min
    return ts


def teacher_refinement(student_nfe: int, teacher_nfe: int) -> int:
    """Smallest positive integer M with student_nfe * (M+1) >= teacher_nfe."""
    if teacher_nfe <= student_nfe:
        raise ValueError("teacher must use more NFE than the student")
    m = int(np.ceil(teacher_nfe / student_nfe)) - 1
    return max(m, 1)


def nested_teacher_schedule(
    student_nfe: int,
    teacher_nfe: int,
    t_min: float = 0.002,
    t_max: float = 80.0,
    rho: float = 7.0,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Teacher grid containing the student grid as every (M+1)-th point.

    Returns (student_ts, teacher_ts, M). teacher_ts has student_nfe*(M+1)+1
    points; teacher_ts[j*(M+1)] == student_ts[j] (eq. 19 is closed under
    sub-indexing, verified in tests to ~1e-12).
    """
    m = teacher_refinement(student_nfe, teacher_nfe)
    student = polynomial_schedule(student_nfe, t_min, t_max, rho)
    teacher = polynomial_schedule(student_nfe * (m + 1), t_min, t_max, rho)
    return student, teacher, m


def paper_index(nfe: int, j: int) -> int:
    """Array position j (0..N) -> paper step index i (N..0)."""
    return nfe - j
