"""Gram-trick PCA and Gram-Schmidt for PAS basis extraction.

The trajectory buffer X has n rows (n <= NFE+2, ~12) and D columns (D = the
flattened sample dimension, possibly billions and sharded).  The TPU-native
formulation (DESIGN.md §3) never materialises an SVD of X: it forms the n x n
Gram matrix G = X X^T (on a mesh: local contraction + one tiny all-reduce),
eigendecomposes it, and reconstructs right singular vectors v_j = X^T w_j / s_j.

All functions are pure jnp on a single (n, D) buffer; batching is vmap;
the sharded variant lives in core/distributed.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = [
    "gram_matrix",
    "topk_right_singular",
    "schmidt",
    "pas_basis",
    "cumulative_variance",
]

_EVAL_FLOOR = 1e-30
_DEGENERATE_NORM = 1e-6


def gram_matrix(x: Array, mask: Array | None = None) -> Array:
    """G = X X^T over the feature axis, with optional row-validity mask."""
    if mask is not None:
        x = x * mask[:, None].astype(x.dtype)
    return x @ x.T


def topk_right_singular(x: Array, k: int, mask: Array | None = None,
                        gram: Array | None = None,
                        canonical_signs: bool = True) -> Array:
    """Top-k unit right singular vectors of X (n, D) via eigh of the Gram matrix.

    Returns (k, D); rows with (near-)zero singular value are zeroed — a zero
    basis vector is inert downstream (its learned coordinate multiplies zero).

    ``canonical_signs`` (beyond-paper, DESIGN.md §3): eigenvector signs are
    arbitrary, so coordinates learned on one sample's basis could flip meaning
    on another's.  We fix sign(v_j) by the dot with the buffer row-sum, making
    bases *consistent across samples* — required for the shared-coordinate
    generalisation the paper relies on.
    """
    if mask is not None:
        x = x * mask[:, None].astype(x.dtype)
    g = gram_matrix(x) if gram is None else gram
    evals, evecs = jnp.linalg.eigh(g)          # ascending
    top = jnp.flip(evals[-k:])                  # (k,) descending
    w = jnp.flip(evecs[:, -k:], axis=1)         # (n, k)
    s = jnp.sqrt(jnp.clip(top, _EVAL_FLOOR))
    v = (x.T @ w) / s                           # (D, k)
    ok = (top > _EVAL_FLOOR * 10).astype(x.dtype)
    v = (v * ok).T                              # (k, D)
    if canonical_signs:
        # sign convention without extra collectives: w sums = v . row_sum(X)
        sgn = jnp.sign(jnp.sum(w, axis=0))[:, None]
        v = v * jnp.where(sgn == 0, 1.0, sgn)
    return v


def schmidt(vs: Array, rel_tol: float = 1e-4) -> Array:
    """Modified Gram-Schmidt over rows of vs (k, D) -> orthonormal rows.

    Degenerate residuals (norm < rel_tol * ||v_in||, i.e. *relative* — float32
    cancellation leaves noise proportional to the input magnitude) become zero
    rows rather than blowing up — the paper notes the pinned v1 may be
    collinear with the PCA vectors.
    """
    k = vs.shape[0]
    us = []
    for j in range(k):
        v = vs[j]
        v_in_norm = jnp.linalg.norm(v)
        for u in us:
            v = v - jnp.vdot(u, v) * u
        nrm = jnp.linalg.norm(v)
        floor = jnp.maximum(rel_tol * v_in_norm, _DEGENERATE_NORM)
        u = jnp.where(nrm > floor, v / jnp.maximum(nrm, _DEGENERATE_NORM), 0.0)
        us.append(u)
    return jnp.stack(us, axis=0)


def pas_basis(q_buf: Array, q_mask: Array, d: Array, n_basis: int = 4) -> Array:
    """The paper's PCA() (Alg. 1 lines 2-6): basis U (n_basis, D), u_0 = d/||d||.

    q_buf  (n, D): trajectory buffer rows [x_T, d_{t_N}, ..., d_{t_{i+1}}]
    q_mask (n,)  : validity (fixed-capacity buffer, scan-friendly)
    d      (D,)  : current direction to correct
    """
    xp = jnp.concatenate([q_buf * q_mask[:, None].astype(q_buf.dtype), d[None]], 0)
    v_pca = topk_right_singular(xp, n_basis - 1)              # (n_basis-1, D)
    v1 = d / jnp.maximum(jnp.linalg.norm(d), _DEGENERATE_NORM)
    return schmidt(jnp.concatenate([v1[None], v_pca], axis=0))  # (n_basis, D)


def cumulative_variance(x: Array, center: bool = True) -> Array:
    """Cumulative percent variance of the principal components of X (n, D).

    Reproduces paper Fig. 2: PCA of a full trajectory saturates by 3 PCs.
    """
    if center:
        x = x - jnp.mean(x, axis=0, keepdims=True)
    evals = jnp.linalg.eigvalsh(gram_matrix(x))
    evals = jnp.clip(jnp.flip(evals), 0.0)
    return jnp.cumsum(evals) / jnp.maximum(jnp.sum(evals), _EVAL_FLOOR)
