"""Gram-trick PCA and Gram-Schmidt for PAS basis extraction.

The trajectory buffer X has n rows (n <= NFE+2, ~12) and D columns (D = the
flattened sample dimension, possibly billions and sharded).  The TPU-native
formulation (DESIGN.md §3) never materialises an SVD of X: it forms the n x n
Gram matrix G = X X^T (on a mesh: local contraction + one tiny all-reduce),
eigendecomposes it, and reconstructs right singular vectors v_j = X^T w_j / s_j.

``basis_weights`` pushes that one step further: because *every* PAS basis
vector is a linear combination of the rows of Xp = [Q * mask; d], the whole
basis — PCA reconstruction, the pinned v1 = d/||d||, and the Gram-Schmidt
orthonormalisation — can be computed as an (n_basis, n+1) coefficient matrix
W from G alone, with every inner product a quadratic form a^T G b.  The basis
U = W @ Xp never has to be materialised: a corrected sampling step contracts
the learned coordinates against W (tiny) and applies (cs @ W) @ Xp in the
fused step kernel, so the per-step D-axis traffic is one Gram pass + one
projection/update pass, and on a sharded mesh the *only* collective is the
(n+1)x(n+1) Gram psum.

All functions are pure jnp on a single (n, D) buffer; batching is vmap;
the sharded variant lives in core/distributed.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = [
    "gram_matrix",
    "topk_right_singular",
    "schmidt",
    "basis_weights",
    "pas_basis",
    "cumulative_variance",
]

_EVAL_FLOOR = 1e-30
_DEGENERATE_NORM = 1e-6
# Trust floor for weight-space eigencomponents, relative to lambda_max.  A
# float32 Gram stores entries rounded at ~eps * |G| ~ 1.2e-7 * lambda_max, so
# any eigenvalue below ~100x that floor is quantisation noise: its eigenvector
# is arbitrary and differs between a psummed and a locally-summed Gram even
# though both are "correct" to rounding.  Components below the floor carry no
# measurable signal in *any* f32 Gram formulation, so they are zeroed (inert
# downstream: their learned coordinate multiplies zero) — this reproduces the
# seed D-space path, whose materialised Gram-Schmidt residuals fell under the
# rel_tol floor at exactly these operating points.
_REL_EVAL_TOL = 1e-6


def gram_matrix(x: Array, mask: Array | None = None) -> Array:
    """G = X X^T over the feature axis, with optional row-validity mask."""
    if mask is not None:
        x = x * mask[:, None].astype(x.dtype)
    return x @ x.T


def topk_right_singular(x: Array, k: int, mask: Array | None = None,
                        gram: Array | None = None,
                        canonical_signs: bool = True) -> Array:
    """Top-k unit right singular vectors of X (n, D) via eigh of the Gram matrix.

    Returns (k, D); rows with (near-)zero singular value are zeroed — a zero
    basis vector is inert downstream (its learned coordinate multiplies zero).

    ``canonical_signs`` (beyond-paper, DESIGN.md §3): eigenvector signs are
    arbitrary, so coordinates learned on one sample's basis could flip meaning
    on another's.  We fix sign(v_j) by the dot with the buffer row-sum, making
    bases *consistent across samples* — required for the shared-coordinate
    generalisation the paper relies on.
    """
    if mask is not None:
        x = x * mask[:, None].astype(x.dtype)
    g = gram_matrix(x) if gram is None else gram
    evals, evecs = jnp.linalg.eigh(g)          # ascending
    top = jnp.flip(evals[-k:])                  # (k,) descending
    w = jnp.flip(evecs[:, -k:], axis=1)         # (n, k)
    s = jnp.sqrt(jnp.clip(top, _EVAL_FLOOR))
    v = (x.T @ w) / s                           # (D, k)
    ok = (top > _EVAL_FLOOR * 10).astype(x.dtype)
    v = (v * ok).T                              # (k, D)
    if canonical_signs:
        # sign convention without extra collectives: w sums = v . row_sum(X)
        sgn = jnp.sign(jnp.sum(w, axis=0))[:, None]
        v = v * jnp.where(sgn == 0, 1.0, sgn)
    return v


def schmidt(vs: Array, rel_tol: float = 1e-4) -> Array:
    """Modified Gram-Schmidt over rows of vs (k, D) -> orthonormal rows.

    Degenerate residuals (norm < rel_tol * ||v_in||, i.e. *relative* — float32
    cancellation leaves noise proportional to the input magnitude) become zero
    rows rather than blowing up — the paper notes the pinned v1 may be
    collinear with the PCA vectors.
    """
    k = vs.shape[0]
    us = []
    for j in range(k):
        v = vs[j]
        v_in_norm = jnp.linalg.norm(v)
        for u in us:
            v = v - jnp.vdot(u, v) * u
        nrm = jnp.linalg.norm(v)
        floor = jnp.maximum(rel_tol * v_in_norm, _DEGENERATE_NORM)
        u = jnp.where(nrm > floor, v / jnp.maximum(nrm, _DEGENERATE_NORM), 0.0)
        us.append(u)
    return jnp.stack(us, axis=0)


def basis_weights(g: Array, n_basis: int, mask: Array | None = None,
                  rel_tol: float = 1e-4) -> Array:
    """PAS basis as row-combination weights: W (n_basis, m) with U = W @ Xp.

    ``g`` is the (m, m) float32 Gram matrix of Xp = [Q * mask; d] (row m-1 is
    the current direction d).  Every basis vector the paper's PCA() produces
    lies in the row span of Xp, so the whole pipeline runs on G:

    * PCA reconstruction coefficients a_j = w_j / s_j from ``eigh(G)`` —
      the same eigenproblem ``topk_right_singular`` solves, with the same
      zero-singular-value and canonical-sign conventions;
    * the pinned v1 = d/||d|| is the coefficient vector e_{m-1}/||d|| with
      ||d|| = sqrt(G[-1, -1]) — no extra reduction over D;
    * modified Gram-Schmidt in the *eigenbasis coordinates* z = L^1/2 E^T a
      (E, L from the eigh above — free), where the G-inner product is the
      Euclidean one: <a, b>_G = z_a . z_b and every norm is a sum of
      squares.  Computing those norms as raw quadratic forms a^T G a
      instead cancels catastrophically for near-degenerate residuals
      (O(|G|) terms collapsing to ~1e-8), which made ``schmidt``'s
      keep/zero gate flip between a psummed and a locally-summed Gram;
    * a *trusted-eigenspace truncation* (``_REL_EVAL_TOL``): eigenvalues
      below 1e-6 of lambda_max are f32 quantisation noise (entries round at
      eps * lambda_max), so their components are gated to zero and their
      sqrt(lambda) contributions are dropped from every z — otherwise the
      pin's coordinates carry mesh-dependent noise into each residual norm
      right at the keep/zero floor.  The truncated geometry matches the
      stability of the seed path's materialised D-space norms
      (mesh-vs-replicated drift ~1e-5 at the acceptance operating points).

    ``mask`` (m,) zeroes the weight columns of invalid buffer rows.  That is
    numerically a no-op when G was built from masked rows (their G rows are
    exactly zero) but guarantees masked rows never leak into the projection
    even when the caller contracts W against *unmasked* row storage — which
    is exactly what the fused kernel path does.
    """
    gf = g.astype(jnp.float32)
    m = gf.shape[0]
    k = n_basis - 1

    # PCA coefficients (the topk_right_singular conventions, in weight space)
    evals, evecs = jnp.linalg.eigh(gf)              # ascending
    top = jnp.flip(evals[-k:])                      # (k,) descending
    w = jnp.flip(evecs[:, -k:], axis=1)             # (m, k)
    s = jnp.sqrt(jnp.clip(top, _EVAL_FLOOR))
    # trust gate: absolute floor AND the relative f32-Gram noise floor (see
    # _REL_EVAL_TOL) — components that an f32 Gram cannot measure are zeroed
    # identically on every mesh instead of amplifying rounding noise by 1/s
    floor = jnp.maximum(_EVAL_FLOOR * 10, _REL_EVAL_TOL * top[0])
    scale = jnp.where(top > floor,                  # ok-gate + canonical sign
                      jnp.where(jnp.sign(jnp.sum(w, axis=0)) == 0, 1.0,
                                jnp.sign(jnp.sum(w, axis=0))), 0.0)
    a_pca = (w / s).T * scale[:, None]              # (k, m): v_j = a_pca[j] @ Xp

    # eigenbasis coordinates z(a) = L^1/2 E^T a, *truncated to the trusted
    # eigenspace*: a_pca_j is w_j / s_j, so z is exactly the j-th top
    # coordinate axis (sqrt(l_j)/s_j = 1), gated/signed like a_pca.
    # Truncation matters for the pin's z below: an untrusted eigenvalue is
    # noise of order eps * lambda_max, and carrying its sqrt into the pin's
    # coordinates injects mesh-dependent jitter into every Gram-Schmidt
    # residual right at the keep/zero floor.  Zeroing those directions
    # measures all inner products only where the Gram carries signal.
    trusted = evals > jnp.maximum(_EVAL_FLOOR * 10, _REL_EVAL_TOL * evals[-1])
    sqrt_l = jnp.where(trusted, jnp.sqrt(jnp.clip(evals, 0.0)), 0.0)
    idx = m - 1 - jnp.arange(k)                     # eigh column of top_j
    z_pca = ((sqrt_l[idx] / s * scale)[:, None]
             * jax.nn.one_hot(idx, m, dtype=gf.dtype))

    # pinned v1 = d / max(||d||, eps): coefficient e_{m-1} scaled
    d_norm = jnp.sqrt(jnp.clip(gf[-1, -1], 0.0))
    inv_d = 1.0 / jnp.maximum(d_norm, _DEGENERATE_NORM)
    a1 = jnp.zeros((m,), gf.dtype).at[-1].set(inv_d)
    z1 = sqrt_l * evecs[-1, :] * inv_d              # z of e_{m-1} / ||d||
    vs = jnp.concatenate([a1[None], a_pca], axis=0)  # (n_basis, m)
    zs = jnp.concatenate([z1[None], z_pca], axis=0)

    # modified Gram-Schmidt (the ``schmidt`` semantics) carrying (v, z)
    # pairs: inner products and norms all live on the stable z side
    us: list[Array] = []
    zus: list[Array] = []
    for j in range(n_basis):
        v, z = vs[j], zs[j]
        v_in_norm = jnp.sqrt(jnp.sum(z * z))
        for u, zu in zip(us, zus):
            c = jnp.vdot(zu, z)
            v = v - c * u
            z = z - c * zu
        nrm = jnp.sqrt(jnp.sum(z * z))
        floor = jnp.maximum(rel_tol * v_in_norm, _DEGENERATE_NORM)
        keep = nrm > floor
        inv = 1.0 / jnp.maximum(nrm, _DEGENERATE_NORM)
        us.append(jnp.where(keep, v * inv, 0.0))
        zus.append(jnp.where(keep, z * inv, 0.0))
    out = jnp.stack(us, axis=0)                      # (n_basis, m)
    if mask is not None:
        out = out * mask[None, :].astype(out.dtype)
    return out


def pas_basis(q_buf: Array, q_mask: Array, d: Array, n_basis: int = 4) -> Array:
    """The paper's PCA() (Alg. 1 lines 2-6): basis U (n_basis, D), u_0 = d/||d||.

    q_buf  (n, D): trajectory buffer rows [x_T, d_{t_N}, ..., d_{t_{i+1}}]
    q_mask (n,)  : validity (fixed-capacity buffer, scan-friendly)
    d      (D,)  : current direction to correct

    One Gram pass over D + the weight-space pipeline (``basis_weights``) +
    one reconstruction contraction — the PCA vectors, pinned v1, and
    Gram-Schmidt never touch the D axis individually.
    """
    xp = jnp.concatenate([q_buf * q_mask[:, None].astype(q_buf.dtype), d[None]], 0)
    mask1 = jnp.concatenate(
        [q_mask.astype(jnp.float32), jnp.ones((1,), jnp.float32)])
    w = basis_weights(gram_matrix(xp), n_basis, mask=mask1)
    return w.astype(xp.dtype) @ xp                   # (n_basis, D)


def cumulative_variance(x: Array, center: bool = True) -> Array:
    """Cumulative percent variance of the principal components of X (n, D).

    Reproduces paper Fig. 2: PCA of a full trajectory saturates by 3 PCs.
    """
    if center:
        x = x - jnp.mean(x, axis=0, keepdims=True)
    evals = jnp.linalg.eigvalsh(gram_matrix(x))
    evals = jnp.clip(jnp.flip(evals), 0.0)
    return jnp.cumsum(evals) / jnp.maximum(jnp.sum(evals), _EVAL_FLOOR)
