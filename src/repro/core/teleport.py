"""Teleportation (TP) warm-start (Wang & Vastola 2024), as used by "DDIM+TP+PAS".

The Gaussian approximation of the data distribution admits a closed-form
PF-ODE solution; TP "teleports" x from sigma_max to sigma_skip along that
analytic solution and only then starts the numerical solver, spending the NFE
budget on the high-curvature region.  PAS then corrects the remaining steps —
the paper's strongest configuration (Table 2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .analytic import gaussian_ode_solution
from .schedules import polynomial_schedule

Array = jax.Array

__all__ = ["GaussianStats", "gaussian_stats_from_data", "teleport", "tp_schedule"]


@dataclasses.dataclass(frozen=True)
class GaussianStats:
    """First/second moments of the data distribution (the TP score surrogate)."""

    mean: Array      # (D,)
    variance: Array  # (D,) diagonal


def gaussian_stats_from_data(x0: Array) -> GaussianStats:
    """Moment-match a Gaussian to data samples x0 (B, D)."""
    return GaussianStats(mean=jnp.mean(x0, 0), variance=jnp.var(x0, 0) + 1e-8)


def teleport(stats: GaussianStats, x_t: Array, t_from: float, t_to: float) -> Array:
    """Analytic PF-ODE transport under the Gaussian score from t_from to t_to."""
    return gaussian_ode_solution(stats.mean, stats.variance, x_t,
                                 jnp.asarray(t_from), jnp.asarray(t_to))


def tp_schedule(nfe: int, sigma_skip: float = 10.0, t_min: float = 0.002,
                rho: float = 7.0) -> np.ndarray:
    """Post-teleport schedule: the full NFE budget on [t_min, sigma_skip]."""
    return polynomial_schedule(nfe, t_min=t_min, t_max=sigma_skip, rho=rho)
