"""Analytic score models under the EDM parameterisation (alpha=1, sigma=t).

These stand in for pretrained diffusion models (DESIGN.md §7): for a Gaussian
mixture data distribution the marginal q_t = sum_k w_k N(mu_k, S_k + t^2 I) has
an exact score, hence an exact eps(x, t) = -t * score.  For a single Gaussian
the PF-ODE additionally has a closed-form solution, giving a ground-truth
oracle for solver-order and PAS-gain measurements.

All eps functions have signature ``eps(x, t) -> eps`` with x of shape
(..., D) and scalar t, matching the solver interface in core/solvers.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]

__all__ = ["GaussianMixture", "make_gmm", "two_mode_gmm", "gaussian_ode_solution"]


@dataclasses.dataclass(frozen=True)
class GaussianMixture:
    """Diagonal-covariance Gaussian mixture q_data = sum_k w_k N(mu_k, diag(var_k))."""

    means: Array     # (K, D)
    variances: Array # (K, D) diagonal covariances
    log_weights: Array  # (K,)

    @property
    def dim(self) -> int:
        return self.means.shape[-1]

    @property
    def n_modes(self) -> int:
        return self.means.shape[0]

    def log_prob_t(self, x: Array, t: Array) -> Array:
        """log q_t(x) for x (..., D), scalar t. EDM forward: x_t = x_0 + t*eps."""
        var = self.variances + t**2  # (K, D)
        diff = x[..., None, :] - self.means  # (..., K, D)
        quad = jnp.sum(diff**2 / var, axis=-1)  # (..., K)
        logdet = jnp.sum(jnp.log(var), axis=-1)  # (K,)
        d = self.dim
        comp = -0.5 * (quad + logdet + d * jnp.log(2 * jnp.pi))
        return jax.nn.logsumexp(comp + self.log_weights, axis=-1)

    def score(self, x: Array, t: Array) -> Array:
        """grad_x log q_t(x): posterior-weighted Gaussian scores."""
        var = self.variances + t**2  # (K, D)
        diff = x[..., None, :] - self.means  # (..., K, D)
        quad = jnp.sum(diff**2 / var, axis=-1)  # (..., K)
        logdet = jnp.sum(jnp.log(var), axis=-1)
        log_r = self.log_weights - 0.5 * (quad + logdet)
        r = jax.nn.softmax(log_r, axis=-1)  # (..., K) responsibilities
        per_mode = -diff / var  # (..., K, D)
        return jnp.sum(r[..., None] * per_mode, axis=-2)

    def eps(self, x: Array, t: Array) -> Array:
        """Noise prediction: eps = -t * score (paper eq. 6 with sigma_t = t)."""
        return -t * self.score(x, t)

    def x0_pred(self, x: Array, t: Array) -> Array:
        """Data prediction E[x0 | x_t] = x + t^2 * score (Tweedie)."""
        return x + t**2 * self.score(x, t)

    def sample_data(self, key: jax.Array, n: int) -> Array:
        kk, kn = jax.random.split(key)
        comp = jax.random.categorical(kk, self.log_weights, shape=(n,))
        noise = jax.random.normal(kn, (n, self.dim))
        return self.means[comp] + jnp.sqrt(self.variances[comp]) * noise

    def sample_prior(self, key: jax.Array, n: int, t_max: float) -> Array:
        """x_T ~ N(0, T^2 I) (EDM prior; data term negligible at T=80)."""
        return t_max * jax.random.normal(key, (n, self.dim))


def make_gmm(key: jax.Array, dim: int, n_modes: int, spread: float = 4.0,
             var_lo: float = 0.05, var_hi: float = 0.6) -> GaussianMixture:
    """A reproducible random mixture with well-separated modes."""
    km, kv, kw = jax.random.split(key, 3)
    means = spread * jax.random.normal(km, (n_modes, dim))
    variances = jax.random.uniform(kv, (n_modes, dim), minval=var_lo, maxval=var_hi)
    logw = jax.nn.log_softmax(0.5 * jax.random.normal(kw, (n_modes,)))
    return GaussianMixture(means, variances, logw)


def two_mode_gmm(dim: int, sep: float = 6.0, var: float = 0.25) -> GaussianMixture:
    """Two well-separated modes along e_1: the minimal 'curved trajectory' model.

    Produces strongly S-shaped truncation error (paper Fig. 3) because the
    trajectory bends where posterior mass switches between modes.
    """
    mu = np.zeros((2, dim), np.float32)
    mu[0, 0] = +sep / 2
    mu[1, 0] = -sep / 2
    variances = np.full((2, dim), var, np.float32)
    logw = np.log(np.array([0.5, 0.5], np.float32))
    return GaussianMixture(jnp.asarray(mu), jnp.asarray(variances), jnp.asarray(logw))


def gaussian_ode_solution(mean: Array, variance: Array, x_t: Array,
                          t_from: Array, t_to: Array) -> Array:
    """Closed-form PF-ODE solution for a single diagonal Gaussian.

    dx/dt = eps(x,t) = t (x - mu) / (var + t^2)  per coordinate, so
    (x - mu)(t) = (x - mu)(T) * sqrt((var + t^2) / (var + T^2)).
    Exact for any t_from -> t_to; used as the solver-convergence oracle.
    """
    scale = jnp.sqrt((variance + t_to**2) / (variance + t_from**2))
    return mean + (x_t - mean) * scale
