"""Error-controlled step sizing: the PID controller behind adaptive NFE.

PAS corrects a *fixed* grid; this module supplies the other half of the
adaptive-NFE story (ROADMAP "Adaptive-NFE serving"): a low/high embedded
solver pair (Euler inside Heun on the EDM eps-ODE ``dx/dt = eps(x, t)``,
``sigma = t``) whose step size is driven by a PID controller over the
per-sample local-error estimate — the k-diffusion ``dpm_solver_adaptive``
idiom (SNIPPETS.md snippet 1), vectorised over the batch so it can ride a
fixed-iteration ``lax.scan`` inside the compiled engine
(``repro.engine.adaptive``).

Three layers live here, deliberately below ``repro.api``/``repro.engine``
so the spec can embed the config without an import cycle:

* ``ErrorControlConfig`` — the frozen, hashable, JSON-round-trippable knob
  set that rides inside ``repro.api.SamplerSpec`` (and hence in
  ``engine_key``: an adaptive engine is a different compiled program);
* the vectorised PID controller — ``PIDState`` + ``pid_init`` /
  ``pid_propose`` operating on ``(B,)`` error vectors, used verbatim by the
  compiled scan body;
* ``adaptive_sample_reference`` — the eager single-sample Python loop, the
  parity oracle the compiled engine is tested against
  (tests/test_adaptive.py).

Steps are taken in log-time ("lambda") space: the controller's ``h`` is a
log-step, ``t_next = max(t * exp(-h), t_min)``, so one dimensionless step
size serves the whole EDM range [0.002, 80] without scale-dependent tuning.
A sample finishes when a step landing exactly on ``t_min`` is accepted.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = [
    "ErrorControlConfig",
    "PIDState",
    "pid_init",
    "pid_propose",
    "error_ratio",
    "adaptive_sample_reference",
]

#: Guard against division by a zero error estimate (k-diffusion's eps).
_ERR_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class ErrorControlConfig:
    """The ~8 knobs of the error-controlled solver (defaults: k-diffusion).

    ``rtol <= 0`` disables error control (``enabled`` is False): the
    adaptive engine then delegates to the spec's fixed-grid engine, so a
    spec carrying a disabled config samples bit-identically to one carrying
    none.  ``max_iters`` bounds the compiled scan (each iteration is one
    accepted-or-rejected embedded step = 2 model evals); samples that have
    not landed on ``t_min`` within the budget are reported via the
    ``finished`` info mask rather than silently extended.
    """

    rtol: float = 0.05
    atol: float = 0.0078
    h_init: float = 0.35           # initial log-time step
    pcoeff: float = 0.0
    icoeff: float = 1.0
    dcoeff: float = 0.0
    accept_safety: float = 0.81    # accept iff PID factor >= this
    order: int = 2                 # embedded-pair order (PID exponents)
    max_iters: int = 64            # compiled scan length (accept + reject)

    def __post_init__(self):
        for f in ("rtol", "atol", "h_init", "pcoeff", "icoeff", "dcoeff",
                  "accept_safety"):
            object.__setattr__(self, f, float(getattr(self, f)))
        object.__setattr__(self, "order", int(self.order))
        object.__setattr__(self, "max_iters", int(self.max_iters))
        if self.rtol > 0 and self.atol < 0:
            raise ValueError(f"atol must be >= 0, got {self.atol}")
        if self.h_init <= 0:
            raise ValueError(f"h_init must be > 0, got {self.h_init}")
        if not 0 < self.accept_safety < 2.5:
            # limiter range is (1 - pi/2, 1 + pi/2); a threshold outside it
            # would accept everything or nothing
            raise ValueError(
                f"accept_safety must be in (0, 2.5), got {self.accept_safety}")
        if self.order < 1:
            raise ValueError(f"order must be >= 1, got {self.order}")
        if self.max_iters < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")

    @property
    def enabled(self) -> bool:
        """Whether error control is active (rtol > 0)."""
        return self.rtol > 0

    # -- serialisation (mirrors the other spec members) ---------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ErrorControlConfig":
        return cls(**d)


class PIDState(NamedTuple):
    """Vectorised controller state, one lane per sample.

    ``inv_err1``/``inv_err2`` are the inverse errors of the two previous
    *accepted* proposals; ``seeded`` marks lanes whose history is real (the
    first proposal seeds both to the current inverse error, k-diffusion's
    empty-``errs`` branch).
    """

    h: Array          # (B,) current log-time step
    inv_err1: Array   # (B,)
    inv_err2: Array   # (B,)
    seeded: Array     # (B,) bool


def pid_init(batch: int, cfg: ErrorControlConfig,
             dtype=jnp.float32) -> PIDState:
    return PIDState(
        h=jnp.full((batch,), cfg.h_init, dtype),
        inv_err1=jnp.ones((batch,), dtype),
        inv_err2=jnp.ones((batch,), dtype),
        seeded=jnp.zeros((batch,), bool),
    )


def _limiter(x: Array) -> Array:
    """Soft step-factor clamp 1 + atan(x - 1): range (1 - pi/2, 1 + pi/2)."""
    return 1.0 + jnp.arctan(x - 1.0)


def pid_propose(state: PIDState, err: Array,
                cfg: ErrorControlConfig) -> tuple[PIDState, Array]:
    """One controller update per lane: (new state, accept mask).

    The PID exponents follow k-diffusion's ``PIDStepSizeController``::

        b1 = (p + i + d) / order,  b2 = -(p + 2d) / order,  b3 = d / order
        factor = limiter(inv_err^b1 * inv_err1^b2 * inv_err2^b3)

    ``h`` is multiplied by the factor whether the step is accepted or not;
    the history shifts only on accept.  Caller masks finished lanes.
    """
    order = float(cfg.order)
    b1 = (cfg.pcoeff + cfg.icoeff + cfg.dcoeff) / order
    b2 = -(cfg.pcoeff + 2.0 * cfg.dcoeff) / order
    b3 = cfg.dcoeff / order
    inv = 1.0 / (err + _ERR_EPS)
    e1 = jnp.where(state.seeded, state.inv_err1, inv)
    e2 = jnp.where(state.seeded, state.inv_err2, inv)
    factor = _limiter(inv ** b1 * e1 ** b2 * e2 ** b3)
    accept = factor >= cfg.accept_safety
    new = PIDState(
        h=state.h * factor,
        inv_err1=jnp.where(accept, inv, e1),
        inv_err2=jnp.where(accept, e1, e2),
        seeded=jnp.ones_like(state.seeded),
    )
    return new, accept


def error_ratio(x_low: Array, x_high: Array, x_prev: Array,
                cfg: ErrorControlConfig) -> Array:
    """Per-sample RMS of (low - high) / (atol + rtol * max(|low|, |prev|)).

    ``x_*`` are (..., D); the reduction is over the trailing state axis, so
    a batched (B, D) call returns a (B,) error vector (the snippet's global
    ``norm / sqrt(numel)`` made per-sample).
    """
    delta = cfg.atol + cfg.rtol * jnp.maximum(jnp.abs(x_low), jnp.abs(x_prev))
    r = (x_low - x_high) / delta
    return jnp.sqrt(jnp.mean(r * r, axis=-1))


def adaptive_sample_reference(eps_fn: Callable[[Array, Array], Array],
                              x: Array, t_min: float, t_max: float,
                              cfg: ErrorControlConfig) -> tuple[Array, dict]:
    """Eager single-sample adaptive Heun loop — the compiled scan's oracle.

    ``x`` is one (D,) sample; ``eps_fn`` takes a (1, D) batch and a scalar
    t (exactly how the compiled engine evaluates each lane under ``vmap``).
    Runs the identical math to ``repro.engine.adaptive`` one Python
    iteration at a time and returns ``(x_0, info)`` with the controller
    counters — tests assert the compiled path reproduces both the state and
    the exact accept/reject sequence.
    """
    if x.ndim != 1:
        raise ValueError(f"reference loop takes one (D,) sample, "
                         f"got shape {x.shape}")
    dtype = x.dtype
    t = jnp.asarray(t_max, dtype)
    t_min = jnp.asarray(t_min, dtype)
    pid = pid_init(1, cfg, dtype)
    pid = PIDState(pid.h[0], pid.inv_err1[0], pid.inv_err2[0], pid.seeded[0])
    x_prev = x
    n_accept = n_reject = 0
    finished = False
    accepts: list[bool] = []
    for _ in range(cfg.max_iters):
        if finished:
            break
        t_next = jnp.maximum(t * jnp.exp(-pid.h), t_min)
        lands = bool(t_next <= t_min * (1.0 + 1e-6))
        dt = t_next - t
        d1 = eps_fn(x[None], t)[0]
        x_low = x + dt * d1
        d2 = eps_fn(x_low[None], t_next)[0]
        x_high = x + dt * 0.5 * (d1 + d2)
        err = error_ratio(x_low, x_high, x_prev, cfg)
        pid, accept = pid_propose(pid, err, cfg)
        accept = bool(accept)
        accepts.append(accept)
        if accept:
            x_prev = x_low
            x = x_high
            t = t_next
            n_accept += 1
            finished = lands
        else:
            n_reject += 1
    info = {
        "nfe": 2 * (n_accept + n_reject),
        "n_accept": n_accept,
        "n_reject": n_reject,
        "finished": finished,
        "t": float(t),
        "accepts": accepts,
    }
    return x, info
