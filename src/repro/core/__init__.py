"""repro.core — PAS (PCA-based Adaptive Search) and its solver substrate."""

from .analytic import GaussianMixture, gaussian_ode_solution, make_gmm, two_mode_gmm
from .error_control import ErrorControlConfig, adaptive_sample_reference
from .pas import (PASConfig, PASParams, calibrate, calibrate_reference,
                  pas_sample, pas_sample_trajectory, truncation_error_curve)
from .pca import cumulative_variance, pas_basis, schmidt, topk_right_singular
from .schedules import nested_teacher_schedule, polynomial_schedule
from .solvers import (SOLVER_NAMES, ground_truth_trajectory, make_solver,
                      sample, sample_trajectory)
from . import teleport
from .teleport import GaussianStats, gaussian_stats_from_data, tp_schedule

__all__ = [
    "ErrorControlConfig", "adaptive_sample_reference",
    "GaussianMixture", "gaussian_ode_solution", "make_gmm", "two_mode_gmm",
    "PASConfig", "PASParams", "calibrate", "calibrate_reference",
    "pas_sample", "pas_sample_trajectory",
    "truncation_error_curve", "cumulative_variance", "pas_basis", "schmidt",
    "topk_right_singular", "nested_teacher_schedule", "polynomial_schedule",
    "SOLVER_NAMES", "ground_truth_trajectory", "make_solver", "sample",
    "sample_trajectory", "GaussianStats", "gaussian_stats_from_data",
    "teleport", "tp_schedule", "distributed",
]

from . import distributed  # noqa: E402  (module-level export, no heavy deps)
