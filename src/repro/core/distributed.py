"""Distributed PAS: sharded-state basis/correction via shard_map + one psum.

The PAS state dimension D (flattened sample: S*E for diffusion-LM serving,
C*H*W for images) is sharded across the mesh.  Every PAS reduction is over D
and every basis vector lies in the row span of Xp = [Q * mask; d], so the
*entire* cross-device cost of a corrected step is **one psum of the
(n+1 x n+1) Gram matrix** (n <= NFE+2, so ~1 KB): the PCA eigenproblem, the
pinned v1 = d/||d|| (||d|| is the Gram's last diagonal entry), and the
Gram-Schmidt orthonormalisation all run on the replicated Gram via
``pca.basis_weights``, and the projection (cs @ W) @ Xp is elementwise along
D — local by construction.  The tiny psum is issued before any of that
weight-space compute, so the collective overlaps it instead of serialising
after it.

The seed formulation (kept below as ``topk_right_singular_sharded`` /
``schmidt_sharded`` — the explicit-collective oracles the single-psum path
is tested against) paid ~n_basis^2 + 2 *sequential* scalar psums per
corrected step on top of the Gram psum; that serialisation was what made
PAS overhead grow with device count (ROADMAP "Make sharded PAS actually
scale").

This is the TPU-native formulation of the paper's "PCA cost is negligible"
claim (DESIGN.md §3).  Two interchangeable paths:

  * ``pas_basis_sharded`` et al. — explicit collectives, for use inside
    shard_map (serving integration, and the path the dry-run exercises at
    512 devices);
  * plain ``core.pca`` under pjit — XLA inserts the same collectives
    automatically (tested equivalent).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops
from repro.parallel.mesh import shard_map  # the one version-compat shim

from .pca import _DEGENERATE_NORM, _EVAL_FLOOR, basis_weights

Array = jax.Array

__all__ = [
    "shard_map",
    "psum_gram",
    "topk_right_singular_sharded",
    "schmidt_sharded",
    "pas_basis_sharded",
    "batched_pas_weights_sharded",
    "batched_pas_basis_sharded",
    "corrected_direction_sharded",
    "make_sharded_pas_step",
]


def psum_gram(x_local: Array, axis_name) -> Array:
    """Gram matrix of a D-sharded buffer: local contraction + tiny all-reduce.

    The local contraction goes through ``kernels.ops.gram`` so the TPU path
    tiles the huge D_local axis through VMEM; inside shard_map the kernel
    sees the per-device shard, which is exactly the shape contract it tiles
    over (the dispatch layer stays shard_map-safe).
    """
    return jax.lax.psum(ops.gram(x_local), axis_name).astype(x_local.dtype)


def _pdot(a: Array, b: Array, axis_name) -> Array:
    return jax.lax.psum(jnp.vdot(a, b), axis_name)


def topk_right_singular_sharded(x_local: Array, k: int, axis_name,
                                mask: Array | None = None) -> Array:
    """Sharded version of pca.topk_right_singular; x_local (n, D_local).

    Legacy explicit-collective oracle: the production corrected step runs
    the single-psum weight path (``basis_weights`` on ``psum_gram``); this
    stays as the independently-derived reference it is tested against.
    """
    if mask is not None:
        x_local = x_local * mask[:, None].astype(x_local.dtype)
    g = psum_gram(x_local, axis_name)            # (n, n) replicated
    evals, evecs = jnp.linalg.eigh(g)            # tiny, replicated compute
    top = jnp.flip(evals[-k:])
    w = jnp.flip(evecs[:, -k:], axis=1)          # (n, k)
    s = jnp.sqrt(jnp.clip(top, _EVAL_FLOOR))
    v = (x_local.T @ w) / s                      # (D_local, k) — local
    ok = (top > _EVAL_FLOOR * 10).astype(x_local.dtype)
    v = (v * ok).T
    sgn = jnp.sign(jnp.sum(w, axis=0))[:, None]  # replicated sign convention
    return v * jnp.where(sgn == 0, 1.0, sgn)


def schmidt_sharded(vs_local: Array, axis_name, rel_tol: float = 1e-4) -> Array:
    """Modified Gram-Schmidt on row-sharded vectors (k, D_local).

    Legacy oracle: ~k^2 sequential scalar psums.  The production path
    orthonormalises in weight space on the already-replicated Gram
    (``basis_weights``) with zero additional collectives.
    """
    k = vs_local.shape[0]
    us = []
    for j in range(k):
        v = vs_local[j]
        v_in_norm = jnp.sqrt(_pdot(v, v, axis_name))
        for u in us:
            v = v - _pdot(u, v, axis_name) * u
        nrm = jnp.sqrt(_pdot(v, v, axis_name))
        floor = jnp.maximum(rel_tol * v_in_norm, _DEGENERATE_NORM)
        u = jnp.where(nrm > floor, v / jnp.maximum(nrm, _DEGENERATE_NORM), 0.0)
        us.append(u)
    return jnp.stack(us, axis=0)


def pas_basis_sharded(q_local: Array, q_mask: Array, d_local: Array,
                      axis_name, n_basis: int = 4) -> Array:
    """Sharded pas_basis: buffer (n, D_local) + direction (D_local,) -> (k, D_local).

    One Gram psum; the weight-space pipeline runs replicated on the ~1 KB
    result and the reconstruction W @ Xp is local.
    """
    xp = jnp.concatenate(
        [q_local * q_mask[:, None].astype(q_local.dtype), d_local[None]], 0)
    g = jax.lax.psum(ops.gram(xp), axis_name)        # the ONE collective
    mask1 = jnp.concatenate(
        [q_mask.astype(jnp.float32), jnp.ones((1,), jnp.float32)])
    w = basis_weights(g, n_basis, mask=mask1)
    return w.astype(xp.dtype) @ xp                   # (n_basis, D_local)


def batched_pas_weights_sharded(mesh: Mesh, state_axis: str,
                                batch_axis: str | None,
                                n_basis: int = 4) -> Callable:
    """Batched sharded PAS weights: the engine's corrected-step collective path.

    Returns ``f(q_rows, q_mask, d) -> (w, d_norm)`` over *global* shapes
    q_rows (cap, B, D), q_mask (cap,), d (B, D) -> w (B, n_basis, cap+1)
    float32 (replicated over the state axis), d_norm (B,), with B sharded
    over ``batch_axis`` (if given) and D over ``state_axis``.  Inside the
    shard_map each device contracts its local Gram tile through
    ``ops.gram_qd`` and issues the single tiny psum *first*, so the
    collective overlaps the weight-space eigh/Schmidt compute; the caller
    then projects with ``ops.fused_pas_project_step`` under pjit — local in
    D, no further collectives.
    """
    bax = batch_axis

    def local(q_rows, q_mask, d):
        g = jax.lax.psum(ops.gram_qd(q_rows, q_mask, d), state_axis)
        mask1 = jnp.concatenate(
            [q_mask.astype(jnp.float32), jnp.ones((1,), jnp.float32)])
        w = jax.vmap(lambda gg: basis_weights(gg, n_basis, mask=mask1))(g)
        d_norm = jnp.sqrt(jnp.clip(g[:, -1, -1], 0.0))
        return w, d_norm

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, bax, state_axis), P(None), P(bax, state_axis)),
        out_specs=(P(bax, None, None), P(bax)))


def batched_pas_basis_sharded(mesh: Mesh, state_axis: str,
                              batch_axis: str | None,
                              n_basis: int = 4) -> Callable:
    """Batched sharded *materialised* basis (calibration's SGD wants U).

    Same signature as before the weight-space rework:
    ``f(q_rows, q_mask, d) -> u`` over global shapes -> (B, n_basis, D),
    B over ``batch_axis``, D over ``state_axis``.  Internally one Gram psum
    (``batched_pas_weights_sharded``'s body) + a local W @ Xp contraction —
    the ~n_basis^2 sequential Schmidt psums of the seed path are gone.
    """
    bax = batch_axis

    def local(q_rows, q_mask, d):
        g = jax.lax.psum(ops.gram_qd(q_rows, q_mask, d), state_axis)
        mask1 = jnp.concatenate(
            [q_mask.astype(jnp.float32), jnp.ones((1,), jnp.float32)])
        w = jax.vmap(lambda gg: basis_weights(gg, n_basis, mask=mask1))(g)
        u = jnp.einsum("bkr,rbd->bkd", w[:, :, :-1],
                       q_rows.astype(w.dtype))
        u = u + w[:, :, -1][..., None] * d.astype(w.dtype)[:, None, :]
        return u.astype(d.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, bax, state_axis), P(None), P(bax, state_axis)),
        out_specs=P(bax, None, state_axis))


def corrected_direction_sharded(u_local: Array, coords: Array, d_local: Array,
                                axis_name, coord_mode: str = "relative") -> Array:
    """d~ = U^T C (local contraction; coords replicated)."""
    if coord_mode == "relative":
        d_norm = jnp.sqrt(_pdot(d_local, d_local, axis_name))
        coords = coords * d_norm
    return jnp.einsum("k,kd->d", coords, u_local)


def make_sharded_pas_step(mesh: Mesh, shard_axes, n_basis: int = 4,
                          coord_mode: str = "relative") -> Callable:
    """Build a jit-able, shard_map-wrapped PAS correction step.

    Returns f(q_rows, q_mask, d, coords) -> d_tilde where q_rows (n, D) and
    d (D,) are sharded over ``shard_axes`` on their last axis; coords (k,) and
    q_mask (n,) are replicated.  This is the op the serving path calls at the
    corrected steps and that the dry-run lowers at the production mesh.

    Fully fused: one Gram psum, then coordinates fold through the weight
    matrix ((coords * ||d||) @ W, with ||d|| free from the Gram diagonal)
    and one local contraction against the buffer rows produces d~.
    """
    axis_name = shard_axes

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(None, shard_axes), P(None), P(shard_axes), P(None)),
        out_specs=P(shard_axes),
    )
    def step(q_local, q_mask, d_local, coords):
        xp = jnp.concatenate(
            [q_local * q_mask[:, None].astype(q_local.dtype), d_local[None]],
            0)
        g = jax.lax.psum(ops.gram(xp), axis_name)    # the ONE collective
        mask1 = jnp.concatenate(
            [q_mask.astype(jnp.float32), jnp.ones((1,), jnp.float32)])
        w = basis_weights(g, n_basis, mask=mask1)    # (n_basis, n+1)
        cs = coords.astype(w.dtype)
        if coord_mode == "relative":
            cs = cs * jnp.sqrt(jnp.clip(g[-1, -1], 0.0))
        pw = cs @ w                                  # (n+1,)
        return (pw.astype(xp.dtype) @ xp)            # (D_local,)

    return jax.jit(step)
