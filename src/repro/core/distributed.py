"""Distributed PAS: sharded-state PCA/Schmidt/correction via shard_map + psum.

The PAS state dimension D (flattened sample: S*E for diffusion-LM serving,
C*H*W for images) is sharded across the mesh.  Every PAS reduction is over D,
so the *entire* cross-device cost of PAS is:

  * one psum of an (n+1 x n+1) Gram matrix (n <= NFE+2, so ~1 KB),
  * ~n_basis^2 scalar psums for Gram-Schmidt inner products,
  * one scalar psum for ||d||.

Everything else is local.  This is the TPU-native formulation of the paper's
"PCA cost is negligible" claim (DESIGN.md §3).  Two interchangeable paths:

  * ``pas_basis_sharded`` — explicit collectives, for use inside shard_map
    (serving integration, and the path the dry-run exercises at 512 devices);
  * plain ``core.pca`` under pjit — XLA inserts the same collectives
    automatically (tested equivalent).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops
from repro.parallel.mesh import shard_map  # the one version-compat shim

from .pca import _DEGENERATE_NORM, _EVAL_FLOOR

Array = jax.Array

__all__ = [
    "shard_map",
    "psum_gram",
    "topk_right_singular_sharded",
    "schmidt_sharded",
    "pas_basis_sharded",
    "batched_pas_basis_sharded",
    "corrected_direction_sharded",
    "make_sharded_pas_step",
]


def psum_gram(x_local: Array, axis_name) -> Array:
    """Gram matrix of a D-sharded buffer: local contraction + tiny all-reduce.

    The local contraction goes through ``kernels.ops.gram`` so the TPU path
    tiles the huge D_local axis through VMEM; inside shard_map the kernel
    sees the per-device shard, which is exactly the shape contract it tiles
    over (the dispatch layer stays shard_map-safe).
    """
    return jax.lax.psum(ops.gram(x_local), axis_name).astype(x_local.dtype)


def _pdot(a: Array, b: Array, axis_name) -> Array:
    return jax.lax.psum(jnp.vdot(a, b), axis_name)


def topk_right_singular_sharded(x_local: Array, k: int, axis_name,
                                mask: Array | None = None) -> Array:
    """Sharded version of pca.topk_right_singular; x_local (n, D_local)."""
    if mask is not None:
        x_local = x_local * mask[:, None].astype(x_local.dtype)
    g = psum_gram(x_local, axis_name)            # (n, n) replicated
    evals, evecs = jnp.linalg.eigh(g)            # tiny, replicated compute
    top = jnp.flip(evals[-k:])
    w = jnp.flip(evecs[:, -k:], axis=1)          # (n, k)
    s = jnp.sqrt(jnp.clip(top, _EVAL_FLOOR))
    v = (x_local.T @ w) / s                      # (D_local, k) — local
    ok = (top > _EVAL_FLOOR * 10).astype(x_local.dtype)
    v = (v * ok).T
    sgn = jnp.sign(jnp.sum(w, axis=0))[:, None]  # replicated sign convention
    return v * jnp.where(sgn == 0, 1.0, sgn)


def schmidt_sharded(vs_local: Array, axis_name, rel_tol: float = 1e-4) -> Array:
    """Modified Gram-Schmidt on row-sharded vectors (k, D_local)."""
    k = vs_local.shape[0]
    us = []
    for j in range(k):
        v = vs_local[j]
        v_in_norm = jnp.sqrt(_pdot(v, v, axis_name))
        for u in us:
            v = v - _pdot(u, v, axis_name) * u
        nrm = jnp.sqrt(_pdot(v, v, axis_name))
        floor = jnp.maximum(rel_tol * v_in_norm, _DEGENERATE_NORM)
        u = jnp.where(nrm > floor, v / jnp.maximum(nrm, _DEGENERATE_NORM), 0.0)
        us.append(u)
    return jnp.stack(us, axis=0)


def pas_basis_sharded(q_local: Array, q_mask: Array, d_local: Array,
                      axis_name, n_basis: int = 4) -> Array:
    """Sharded pas_basis: buffer (n, D_local) + direction (D_local,) -> (k, D_local)."""
    xp = jnp.concatenate(
        [q_local * q_mask[:, None].astype(q_local.dtype), d_local[None]], 0)
    v_pca = topk_right_singular_sharded(xp, n_basis - 1, axis_name)
    d_norm = jnp.sqrt(_pdot(d_local, d_local, axis_name))
    v1 = d_local / jnp.maximum(d_norm, _DEGENERATE_NORM)
    return schmidt_sharded(jnp.concatenate([v1[None], v_pca], 0), axis_name)


def batched_pas_basis_sharded(mesh: Mesh, state_axis: str,
                              batch_axis: str | None,
                              n_basis: int = 4) -> Callable:
    """Batched sharded PAS basis: the engine's corrected-step collective path.

    Returns ``f(q_rows, q_mask, d) -> u`` over *global* shapes
    q_rows (cap, B, D), q_mask (cap,), d (B, D) -> u (B, n_basis, D), with
    B sharded over ``batch_axis`` (if given) and D over ``state_axis``.
    Inside the shard_map each device holds its (B_local, D_local) tile and
    the per-sample PCA/Schmidt reductions run through the explicit psum
    collectives above — this replaces the replicated ``pas._batched_basis``
    whenever an engine has a state-sharded mesh bound.
    """
    bax = batch_axis

    def local(q_rows, q_mask, d):
        # q_rows (cap, B_l, D_l), d (B_l, D_l): vmap the per-sample sharded
        # basis over the local batch; psums batch across the vmap.
        f = lambda rows, dd: pas_basis_sharded(rows, q_mask, dd, state_axis,
                                               n_basis)
        return jax.vmap(f, in_axes=(1, 0), out_axes=0)(q_rows, d)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, bax, state_axis), P(None), P(bax, state_axis)),
        out_specs=P(bax, None, state_axis))


def corrected_direction_sharded(u_local: Array, coords: Array, d_local: Array,
                                axis_name, coord_mode: str = "relative") -> Array:
    """d~ = U^T C (local contraction; coords replicated)."""
    if coord_mode == "relative":
        d_norm = jnp.sqrt(_pdot(d_local, d_local, axis_name))
        coords = coords * d_norm
    return jnp.einsum("k,kd->d", coords, u_local)


def make_sharded_pas_step(mesh: Mesh, shard_axes, n_basis: int = 4,
                          coord_mode: str = "relative") -> Callable:
    """Build a jit-able, shard_map-wrapped PAS correction step.

    Returns f(q_rows, q_mask, d, coords) -> d_tilde where q_rows (n, D) and
    d (D,) are sharded over ``shard_axes`` on their last axis; coords (k,) and
    q_mask (n,) are replicated.  This is the op the serving path calls at the
    corrected steps and that the dry-run lowers at the production mesh.
    """
    axis_name = shard_axes

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(None, shard_axes), P(None), P(shard_axes), P(None)),
        out_specs=P(shard_axes),
    )
    def step(q_local, q_mask, d_local, coords):
        u_local = pas_basis_sharded(q_local, q_mask, d_local, axis_name, n_basis)
        return corrected_direction_sharded(u_local, coords, d_local, axis_name,
                                           coord_mode)

    return jax.jit(step)
