"""ODE solvers for EDM-parameterised diffusion sampling (dx/dt = eps(x, t)).

Two families, one functional interface:

* ``LinearMultistepSolver`` — every 1-NFE-per-step solver the paper plugs PAS
  into (DDIM/Euler, iPNDM orders 1..4, DEIS-tAB orders 1..3, DPM-Solver++(2M))
  reduces, on a *fixed* schedule, to

      x_{j+1} = alpha[j] * x_j + sum_m beta[j, m] * native_m

  where ``native_0`` is the current direction mapped to the solver's native
  space ("eps" or data-prediction "x0") and ``native_{m>0}`` come from the
  history buffer.  Warmup order is a deterministic function of the step index,
  so the (N, K) coefficient tables are precomputed in float64 numpy at bind
  time — the scan body is a handful of fused multiply-adds, and the paper's
  phi(x, d, t_i, t_{i-1}) is exactly linear in the corrected direction d.

* ``TwoEvalSolver`` — Heun's 2nd (EDM) and DPM-Solver-2, used mainly as
  ground-truth teachers.

Schedules are descending (schedules.py).  Step j advances ts[j] -> ts[j+1];
the paper's step index is i = N - j.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]

__all__ = [
    "SolverHist",
    "LinearMultistepSolver",
    "TwoEvalSolver",
    "make_solver",
    "SOLVER_NAMES",
    "sample",
    "sample_trajectory",
    "ground_truth_trajectory",
]


class SolverHist(NamedTuple):
    """Fixed-capacity history of native directions; buf[0] is most recent."""

    buf: Array      # (H, *state_shape)
    count: Array    # int32, number of valid entries


# ---------------------------------------------------------------------------
# coefficient tables
# ---------------------------------------------------------------------------

_AB_COEFS = {
    1: np.array([1.0]),
    2: np.array([3.0, -1.0]) / 2.0,
    3: np.array([23.0, -16.0, 5.0]) / 12.0,
    4: np.array([55.0, -59.0, 37.0, -9.0]) / 24.0,
}


def _euler_tables(ts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    n = len(ts) - 1
    alpha = np.ones(n)
    beta = (ts[1:] - ts[:-1])[:, None]  # (N, 1); negative (t descending)
    return alpha, beta


def _ipndm_tables(ts: np.ndarray, order: int) -> tuple[np.ndarray, np.ndarray]:
    """improved PNDM: Adams-Bashforth with lower-order warmup (Zhang & Chen)."""
    n = len(ts) - 1
    alpha = np.ones(n)
    beta = np.zeros((n, order))
    for j in range(n):
        k = min(j + 1, order)
        dt = ts[j + 1] - ts[j]
        beta[j, :k] = dt * _AB_COEFS[k]
    return alpha, beta


def _deis_tab_tables(ts: np.ndarray, order: int) -> tuple[np.ndarray, np.ndarray]:
    """DEIS-tAB: exact integrals of Lagrange interpolants of eps over [t_j, t_{j+1}].

    Under EDM (alpha=1, sigma=t) the exponential-integrator weights reduce to
    plain time-polynomial integrals: C_m = int_{t_j}^{t_{j+1}} prod_{q!=m}
    (t - t_q)/(t_m - t_q) dt with nodes at the times of the buffered eps.
    """
    n = len(ts) - 1
    alpha = np.ones(n)
    beta = np.zeros((n, order))
    for j in range(n):
        k = min(j + 1, order)
        nodes = np.array([ts[j - m] for m in range(k)], dtype=np.float64)
        for m in range(k):
            # Lagrange basis polynomial l_m over `nodes`
            poly = np.poly1d([1.0])
            for q in range(k):
                if q == m:
                    continue
                poly = poly * np.poly1d([1.0, -nodes[q]]) / (nodes[m] - nodes[q])
            integ = poly.integ()
            beta[j, m] = integ(ts[j + 1]) - integ(ts[j])
    return alpha, beta


def _dpmpp2m_tables(ts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """DPM-Solver++(2M) in lambda = -log t; native space is x0-prediction."""
    n = len(ts) - 1
    alpha = np.zeros(n)
    beta = np.zeros((n, 2))
    lam = -np.log(ts)
    for j in range(n):
        a = ts[j + 1] / ts[j]          # e^{-h}
        alpha[j] = a
        if j == 0:
            beta[j, 0] = 1.0 - a       # data-space DDIM step
        else:
            h = lam[j + 1] - lam[j]
            h_prev = lam[j] - lam[j - 1]
            r = h_prev / h
            beta[j, 0] = (1.0 - a) * (1.0 + 1.0 / (2.0 * r))
            beta[j, 1] = -(1.0 - a) / (2.0 * r)
    return alpha, beta


# ---------------------------------------------------------------------------
# solver classes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinearMultistepSolver:
    """A 1-NFE-per-step solver bound to a fixed descending schedule."""

    name: str
    ts: np.ndarray          # (N+1,) descending, host-side float64
    native: str             # "eps" | "x0"
    alpha: Array            # (N,)
    beta: Array             # (N, K)

    @property
    def nfe(self) -> int:
        return len(self.ts) - 1

    @property
    def evals_per_step(self) -> int:
        return 1

    @property
    def hist_len(self) -> int:
        return max(int(self.beta.shape[1]) - 1, 0)

    @property
    def ts_jax(self) -> Array:
        return jnp.asarray(self.ts, dtype=jnp.float32)

    def init_hist(self, x: Array) -> SolverHist:
        h = max(self.hist_len, 1)
        return SolverHist(
            buf=jnp.zeros((h,) + x.shape, x.dtype),
            count=jnp.zeros((), jnp.int32),
        )

    def to_native(self, x: Array, d: Array, j: Array) -> Array:
        """Map the eps-space direction d at step j to the solver's native space."""
        if self.native == "eps":
            return d
        t = jnp.asarray(self.ts_jax)[j]
        return x - t * d  # x0-prediction

    def phi(self, x: Array, d: Array, j: Array, hist: SolverHist,
            eps_fn: EpsFn | None = None) -> Array:
        """The paper's phi(x, d, t_i, t_{i-1}): pure & linear in d given history."""
        del eps_fn
        nat = self.to_native(x, d, j)
        a = self.alpha[j]
        b = self.beta[j]  # (K,)
        out = a * x + b[0] * nat
        for m in range(1, self.beta.shape[1]):
            out = out + b[m] * hist.buf[m - 1]
        return out

    def push(self, x: Array, d: Array, j: Array, hist: SolverHist) -> SolverHist:
        """Append the (possibly PAS-corrected) direction to the history buffer."""
        nat = self.to_native(x, d, j)
        if self.hist_len == 0:
            return SolverHist(hist.buf, hist.count + 1)
        buf = jnp.roll(hist.buf, 1, axis=0)
        buf = buf.at[0].set(nat)
        return SolverHist(buf, jnp.minimum(hist.count + 1, self.hist_len))

    def step(self, eps_fn: EpsFn, x: Array, j: Array, hist: SolverHist,
             d_override: Array | None = None) -> tuple[Array, SolverHist, Array]:
        t = self.ts_jax[j]
        d = eps_fn(x, t) if d_override is None else d_override
        x_next = self.phi(x, d, j, hist)
        hist = self.push(x, d, j, hist)
        return x_next, hist, d


@dataclasses.dataclass(frozen=True)
class TwoEvalSolver:
    """2-NFE-per-step single-step solvers: Heun-2 (EDM) and DPM-Solver-2."""

    name: str
    ts: np.ndarray
    kind: str  # "heun" | "dpm2"

    @property
    def nfe(self) -> int:
        return 2 * (len(self.ts) - 1)

    @property
    def evals_per_step(self) -> int:
        return 2

    @property
    def hist_len(self) -> int:
        return 0

    @property
    def ts_jax(self) -> Array:
        return jnp.asarray(self.ts, dtype=jnp.float32)

    def init_hist(self, x: Array) -> SolverHist:
        return SolverHist(buf=jnp.zeros((1,) + x.shape, x.dtype),
                          count=jnp.zeros((), jnp.int32))

    def phi(self, x: Array, d: Array, j: Array, hist: SolverHist,
            eps_fn: EpsFn | None = None) -> Array:
        if eps_fn is None:
            raise ValueError(f"{self.name}.phi requires eps_fn (2-eval solver)")
        ts = self.ts_jax
        t_cur, t_next = ts[j], ts[j + 1]
        if self.kind == "heun":
            x_e = x + (t_next - t_cur) * d
            d2 = eps_fn(x_e, t_next)
            return x + (t_next - t_cur) * 0.5 * (d + d2)
        # dpm2: midpoint at the geometric mean (r = 1/2 in lambda = -log t)
        t_mid = jnp.sqrt(t_cur * t_next)
        x_mid = x + (t_mid - t_cur) * d
        d2 = eps_fn(x_mid, t_mid)
        return x + (t_next - t_cur) * d2

    def push(self, x: Array, d: Array, j: Array, hist: SolverHist) -> SolverHist:
        return SolverHist(hist.buf, hist.count + 1)

    def step(self, eps_fn: EpsFn, x: Array, j: Array, hist: SolverHist,
             d_override: Array | None = None) -> tuple[Array, SolverHist, Array]:
        t = self.ts_jax[j]
        d = eps_fn(x, t) if d_override is None else d_override
        x_next = self.phi(x, d, j, hist, eps_fn)
        return x_next, self.push(x, d, j, hist), d


Solver = LinearMultistepSolver | TwoEvalSolver

SOLVER_NAMES = (
    "ddim", "euler", "ipndm", "ipndm1", "ipndm2", "ipndm3", "ipndm4",
    "deis", "deis1", "deis2", "deis3", "dpmpp2m", "heun", "dpm2",
)


def make_solver(name: str, ts: np.ndarray) -> Solver:
    """Bind a solver by name to a descending schedule ts (numpy, len N+1)."""
    ts = np.asarray(ts, dtype=np.float64)
    if ts.ndim != 1 or len(ts) < 2 or not np.all(np.diff(ts) < 0):
        raise ValueError("ts must be a descending 1-D schedule with >= 2 points")

    def lms(native: str, tables) -> LinearMultistepSolver:
        alpha, beta = tables
        return LinearMultistepSolver(
            name=name, ts=ts, native=native,
            alpha=jnp.asarray(alpha, jnp.float32),
            beta=jnp.asarray(beta, jnp.float32),
        )

    if name in ("ddim", "euler"):
        return lms("eps", _euler_tables(ts))
    if name.startswith("ipndm"):
        order = int(name[5:]) if len(name) > 5 else 3
        if order not in (1, 2, 3, 4):
            raise ValueError(f"ipndm order must be 1..4, got {order}")
        return lms("eps", _ipndm_tables(ts, order))
    if name.startswith("deis"):
        order = int(name[4:]) if len(name) > 4 else 3
        if order not in (1, 2, 3):
            raise ValueError(f"deis order must be 1..3, got {order}")
        return lms("eps", _deis_tab_tables(ts, order))
    if name == "dpmpp2m":
        return lms("x0", _dpmpp2m_tables(ts))
    if name in ("heun", "dpm2"):
        return TwoEvalSolver(name=name, ts=ts, kind=name)
    raise ValueError(f"unknown solver {name!r}; available: {SOLVER_NAMES}")


# ---------------------------------------------------------------------------
# sampling drivers
# ---------------------------------------------------------------------------


def sample(solver: Solver, eps_fn: EpsFn, x_T: Array) -> Array:
    """Run the full sampler ts[0] -> ts[N]; returns x at ts[N]."""
    n = solver.nfe if solver.evals_per_step == 1 else len(solver.ts) - 1

    def body(carry, j):
        x, hist = carry
        x, hist, _ = solver.step(eps_fn, x, j, hist)
        return (x, hist), None

    (x, _), _ = jax.lax.scan(body, (x_T, solver.init_hist(x_T)), jnp.arange(n))
    return x


def sample_trajectory(solver: Solver, eps_fn: EpsFn, x_T: Array
                      ) -> tuple[Array, Array]:
    """Full trajectory: returns (xs (N+1, ...), ds (N, ...)) along the path."""
    n = len(solver.ts) - 1

    def body(carry, j):
        x, hist = carry
        x_next, hist, d = solver.step(eps_fn, x, j, hist)
        return (x_next, hist), (x_next, d)

    (_, _), (xs, ds) = jax.lax.scan(
        body, (x_T, solver.init_hist(x_T)), jnp.arange(n))
    xs = jnp.concatenate([x_T[None], xs], axis=0)
    return xs, ds


def ground_truth_trajectory(
    eps_fn: EpsFn,
    student_ts: np.ndarray,
    teacher_ts: np.ndarray,
    m: int,
    x_T: Array,
    teacher: str | Solver = "heun",
) -> Array:
    """Paper §3.3: run the teacher on the refined grid, index every (M+1)-th state.

    ``teacher`` is a solver name, or an already-bound Solver (it must be
    bound to ``teacher_ts`` — the path ``repro.api`` uses for
    registry-resolved teachers).  Returns gt (N+1, ...) aligned with the
    student grid (gt[0] = x_T).
    """
    if not np.allclose(teacher_ts[:: m + 1], student_ts, rtol=1e-9, atol=1e-12):
        raise ValueError("teacher grid does not nest the student grid")
    if isinstance(teacher, str):
        tsol = make_solver(teacher, teacher_ts)
    else:
        tsol = teacher
        if not np.array_equal(np.asarray(tsol.ts), np.asarray(teacher_ts)):
            raise ValueError("bound teacher solver does not match teacher_ts")
    xs, _ = sample_trajectory(tsol, eps_fn, x_T)
    return xs[:: m + 1]
