"""PAS: PCA-based Adaptive Search (paper Algorithms 1 and 2).

Calibration (Alg. 1) learns, for each sampling step that needs it, a set of
``n_basis`` coordinates shared across samples; sampling (Alg. 2) applies them
to correct the solver direction.  Total stored parameters =
(#corrected steps) x n_basis ~= 10.

Coordinate parameterisation: the paper initialises c_1 = ||d||_2 per sample and
learns a shared C.  In high dimension ||eps|| concentrates (~sqrt(D)) so a
shared absolute c_1 is well-defined; in low-D toy problems it is not.  We
therefore support two modes (DESIGN.md §3):

* ``relative`` (default): d~ = sum_m (C[m] * ||d||) u_m with C init [1,0,0,0].
  Exactly the paper's parameterisation for each individual sample, but scale-
  equivariant across samples.
* ``absolute``: d~ = sum_m C[m] u_m with C init [mean||d||, 0, 0, 0] — the
  literal batch version of the paper's text.

Both reproduce the paper's single-sample algebra; `relative` generalises
better across samples and is used in all experiments unless noted.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .pca import basis_weights
from .solvers import LinearMultistepSolver, Solver, SolverHist

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]

__all__ = [
    "PASConfig", "PASParams", "LOSS_FNS",
    "calibrate", "calibrate_reference", "pas_sample", "pas_sample_trajectory",
    "truncation_error_curve",
]


LOSS_FNS = {
    "l1": lambda e: jnp.mean(jnp.abs(e)),
    "l2": lambda e: jnp.mean(e**2),
    "pseudo_huber": lambda e, c=0.03: jnp.mean(jnp.sqrt(e**2 + c**2) - c),
}


@dataclasses.dataclass(frozen=True)
class PASConfig:
    n_basis: int = 4
    lr: float = 1e-2
    n_sgd_iters: int = 200
    tolerance: float = 1e-4
    loss: str = "l1"               # training loss (paper recommends L1)
    coord_mode: str = "relative"   # "relative" | "absolute"
    val_fraction: float = 0.0      # beyond-paper: >0 decides step adoption on a
                                   # held-out slice of the calibration batch,
                                   # rejecting corrections that won't generalise
    final_gate: bool = True        # beyond-paper: after calibration, verify the
                                   # *end-to-end* error and greedily drop the
                                   # least-gainful corrected steps until PAS is
                                   # no worse than the plain solver (greedy
                                   # per-step adoption ignores how a corrected
                                   # direction propagates through a multistep
                                   # solver's history; cf. paper Table 11 where
                                   # iPNDM L2 gains vanish at NFE>=7)


class PASParams(NamedTuple):
    """The ~10 learned parameters: per-step activity mask + coordinates."""

    active: np.ndarray   # (N,) bool, host-side (drives static branch structure)
    coords: Array        # (N, n_basis)

    @property
    def n_stored_params(self) -> int:
        return int(self.active.sum()) * self.coords.shape[1]

    def corrected_paper_steps(self) -> list[int]:
        """Paper-convention step indices i (N..1) that get corrected (cf. Table 6)."""
        n = len(self.active)
        return [n - j for j in range(n) if self.active[j]]


# ---------------------------------------------------------------------------
# internals
# ---------------------------------------------------------------------------


def _corrected_direction(u: Array, c: Array, d_norm: Array, mode: str) -> Array:
    """d~ = U^T C with optional per-sample norm scaling. u (k, D), c (k,)."""
    scale = d_norm if mode == "relative" else jnp.asarray(1.0, u.dtype)
    return jnp.einsum("k,kd->d", c * scale, u)


def _init_coords(d: Array, mode: str, n_basis: int) -> Array:
    """C init (shared across batch): [c1, 0, ...] per the paper's eq. 15."""
    if mode == "relative":
        c1 = jnp.asarray(1.0, jnp.float32)
    else:
        c1 = jnp.mean(jax.vmap(jnp.linalg.norm)(d))
    return jnp.concatenate([c1[None], jnp.zeros((n_basis - 1,), jnp.float32)])


class _QBuffer(NamedTuple):
    """Fixed-capacity trajectory buffer: rows [x_T, d_N, d_{N-1}, ...]."""

    rows: Array   # (cap, B, D)
    mask: Array   # (cap,) float32 validity

    @staticmethod
    def create(x_t: Array, cap: int) -> "_QBuffer":
        rows = jnp.zeros((cap,) + x_t.shape, x_t.dtype).at[0].set(x_t)
        mask = jnp.zeros((cap,), jnp.float32).at[0].set(1.0)
        return _QBuffer(rows, mask)

    def push(self, d: Array, slot: Array | int) -> "_QBuffer":
        return _QBuffer(self.rows.at[slot].set(d), self.mask.at[slot].set(1.0))


def _batched_weights(q: _QBuffer, d: Array, n_basis: int
                     ) -> tuple[Array, Array]:
    """Batched weight-space basis: one Gram pass over D, everything else tiny.

    q.rows (cap, B, D), d (B, D) -> (W (B, n_basis, cap+1) float32 with
    masked-row columns zeroed, d_norm (B,) float32 read off the Gram
    diagonal).  This is the ONE basis computation: the replicated engine
    path, the seed reference (via ``_batched_basis``), and the sharded
    collective path (``distributed.batched_pas_weights_sharded``) all run
    ``ops.gram_qd`` + ``pca.basis_weights`` on the same Gram, so their
    bases can only differ where the Gram itself does (reduction order).
    """
    g = ops.gram_qd(q.rows, q.mask, d)                # (B, cap+1, cap+1)
    mask1 = jnp.concatenate(
        [q.mask.astype(jnp.float32), jnp.ones((1,), jnp.float32)])
    w = jax.vmap(lambda gg: basis_weights(gg, n_basis, mask=mask1))(g)
    d_norm = jnp.sqrt(jnp.clip(g[:, -1, -1], 0.0))
    return w, d_norm


def _materialize_basis(w: Array, rows: Array, d: Array) -> Array:
    """U = W @ Xp: (B, k, cap+1) weights against (cap, B, D) rows + (B, D) d.

    Rows are consumed unmasked — ``basis_weights`` zeroes the weight columns
    of invalid rows.  Only calibration (whose SGD reuses U across ~200
    iterations) materialises the basis; sampling contracts the coordinates
    against W and projects via ``ops.fused_pas_project_step`` instead.
    """
    u = jnp.einsum("bkr,rbd->bkd", w[:, :, :-1], rows.astype(w.dtype))
    u = u + w[:, :, -1][..., None] * d.astype(w.dtype)[:, None, :]
    return u.astype(d.dtype)


def _batched_basis(q: _QBuffer, d: Array, n_basis: int) -> Array:
    """Batched materialised basis: q.rows (cap,B,D), d (B,D) -> (B,k,D)."""
    w, _ = _batched_weights(q, d, n_basis)
    return _materialize_basis(w, q.rows, d)


def _projected_coords(coords_j: Array, w: Array, d_norm: Array,
                      mode: str) -> Array:
    """pw (B, cap+1) = cs @ W: the learned coordinates folded through the
    weight-space basis, with coord_mode's ||d|| scaling read off the Gram
    diagonal.  Shared by the engine hot path and the seed reference so both
    run the identical association (d~ = pw @ Xp); reassociating through a
    materialised basis instead lands within the documented ~1e-2
    noise-subspace sensitivity, not bitwise.
    """
    cs = coords_j[None, :].astype(d_norm.dtype)
    if mode == "relative":
        cs = cs * d_norm[:, None]
    else:
        cs = jnp.broadcast_to(cs, (d_norm.shape[0], coords_j.shape[0]))
    return jnp.einsum("bk,bkr->br", cs.astype(w.dtype), w)


def _sampling_q_cap(last_active: int, n: int) -> int:
    """Q-buffer rows a *sampling* pass needs: slots [x_T, d_1..d_last] + one
    spare, never more than the calibration-time ``n + 1``.  Rows past the
    last corrected step are dead HBM at large D (the mask zeroes them out of
    every Gram anyway), so both the engine prefix and the reference
    trajectory bound the allocation here (parity-tested in test_engine.py).
    """
    return min(last_active + 2, n + 1)


# ---------------------------------------------------------------------------
# Algorithm 1: calibration with adaptive search
# ---------------------------------------------------------------------------


def calibrate(
    solver: Solver,
    eps_fn: EpsFn,
    x_t: Array,          # (B, D) initial noise for the calibration trajectories
    gt: Array,           # (N+1, B, D) teacher trajectory aligned to solver.ts
    cfg: PASConfig = PASConfig(),
) -> tuple[PASParams, dict]:
    """Learn PAS coordinates (paper Algorithm 1) via the fused engine.

    .. deprecated::
        Compat shim for pre-``repro.api`` call sites.  New code should build
        a ``repro.api.Pipeline`` and call ``pipeline.calibrate`` — same
        fused ``CalibrationEngine`` underneath, plus teacher construction,
        artifacts, and spec-keyed caching in one object.

    Delegates to ``repro.engine.CalibrationEngine`` — the whole of
    Algorithm 1 (eps evals, PCA bases, SGD scans, on-device adoption,
    compiled final-state gate) as one cached XLA program.  The interpreted
    loop below (``calibrate_reference``) remains the reference
    implementation the engine is parity-tested against
    (tests/test_calibration_engine.py).
    """
    from repro.engine import calibration_engine_for_solver  # deferred: engine imports core
    return calibration_engine_for_solver(solver, cfg).calibrate(eps_fn, x_t, gt)


def calibrate_reference(
    solver: Solver,
    eps_fn: EpsFn,
    x_t: Array,          # (B, D) initial noise for the calibration trajectories
    gt: Array,           # (N+1, B, D) teacher trajectory aligned to solver.ts
    cfg: PASConfig = PASConfig(),
) -> tuple[PASParams, dict]:
    """Learn PAS coordinates (paper Algorithm 1), batched over B trajectories.

    Follows the paper exactly: steps are corrected *sequentially* (a corrected
    step changes every later state), each step's coordinates are trained with
    SGD against the teacher state, and the step is kept only if the L2 gain
    exceeds the tolerance (adaptive search).

    This is the readable per-step reference the fused
    ``repro.engine.CalibrationEngine`` is parity-tested against; production
    call sites go through the engine (``calibrate`` above, or
    ``Pipeline.calibrate``).  Per step it syncs one scalar (the adoption
    decision drives host-side branch structure); the loss diagnostics stay
    device-side and transfer once at the end.
    """
    if not isinstance(solver, LinearMultistepSolver):
        raise TypeError("PAS calibration requires a 1-eval solver (paper setup); "
                        f"got {solver.name}")
    n = solver.nfe
    train_loss = LOSS_FNS[cfg.loss]
    ts = solver.ts_jax

    x = x_t
    hist = solver.init_hist(x_t)
    q = _QBuffer.create(x_t, cap=n + 1)

    active = np.zeros(n, dtype=bool)
    coords_rows: list[tuple[int, Array]] = []
    l2_plain_steps: list[Array] = []
    l2_corr_steps: list[Array] = []

    sgd = _make_sgd(solver, cfg, train_loss)
    b = x_t.shape[0]
    n_val = int(round(b * cfg.val_fraction))
    tr = slice(n_val, None)   # SGD trains on this slice
    va = slice(0, n_val) if n_val > 0 else slice(None)  # adoption decided here

    for j in range(n):  # paper index i = N - j
        t = ts[j]
        d = eps_fn(x, t)                               # (B, D)
        u = _batched_basis(q, d, cfg.n_basis)          # (B, k, D)
        d_norm = jax.vmap(jnp.linalg.norm)(d)          # (B,)
        c0 = _init_coords(d, cfg.coord_mode, cfg.n_basis)

        c_opt = sgd(c0, x[tr], u[tr], d_norm[tr], _hist_slice(hist, tr),
                    gt[j + 1][tr], j)

        # adaptive-search decision on the L2 metric (paper eq. 20); the
        # decision scalar is the only per-step host sync (it drives the
        # static branch structure below)
        d_tilde = jax.vmap(_corrected_direction, (0, None, 0, None))(
            u, c_opt, d_norm, cfg.coord_mode)
        x_plain = solver.phi(x, d, j, hist)
        x_corr = solver.phi(x, d_tilde, j, hist)
        l2_plain = jnp.mean((x_plain[va] - gt[j + 1][va]) ** 2)
        l2_corr = jnp.mean((x_corr[va] - gt[j + 1][va]) ** 2)
        adopt = bool(l2_plain - (l2_corr + cfg.tolerance) > 0.0)

        l2_plain_steps.append(l2_plain)
        l2_corr_steps.append(l2_corr)

        if adopt:
            active[j] = True
            coords_rows.append((j, c_opt))
            x_new, d_used = x_corr, d_tilde
        else:
            x_new, d_used = x_plain, d

        hist = solver.push(x, d_used, j, hist)
        q = q.push(d_used, j + 1)
        x = x_new

    # one batched device->host transfer for coords + loss diagnostics
    # (the seed loop paid three blocking float() syncs per step here)
    l2p, l2c, final_l2 = jax.device_get(
        (jnp.stack(l2_plain_steps), jnp.stack(l2_corr_steps),
         jnp.mean((x - gt[-1]) ** 2)))
    coords = np.zeros((n, cfg.n_basis), dtype=np.float32)
    if coords_rows:
        rows = jax.device_get(jnp.stack([c for _, c in coords_rows]))
        for (j, _), row in zip(coords_rows, rows):
            coords[j] = row
    diag = {"loss_before": [float(v) for v in l2p],
            "loss_after": [float(v) for v in l2c],
            "gain": [float(p - c) for p, c in zip(l2p, l2c)]}

    params = PASParams(active=active, coords=jnp.asarray(coords))

    if cfg.final_gate and active.any():
        params, diag["final_gate_dropped"] = _final_state_gate(
            solver, eps_fn, x_t[va], gt[:, va], params, cfg)

    diag["corrected_steps_paper_index"] = params.corrected_paper_steps()
    diag["n_stored_params"] = params.n_stored_params
    diag["final_l2_to_gt"] = float(final_l2)
    return params, diag


def _final_state_gate(solver, eps_fn, x_gate, gt_gate, params: PASParams,
                      cfg: PASConfig) -> tuple[PASParams, list[int]]:
    """Greedily drop corrected steps until PAS's final error <= plain final error.

    Rollouts go through the cached ``SamplingEngine`` for the solver — one
    engine lookup; the plain baseline is the engine's compiled plain scan
    (the seed path re-built it from ``solvers.sample`` per gate call) and
    each trial mask reuses the engine's per-pattern compiled prefix instead
    of re-tracing the eager trajectory loop per trial.
    """
    from repro.engine.engine import _engine_for_solver  # deferred: engine imports core
    eng = _engine_for_solver(solver)
    x_plain = eng.sample(eps_fn, x_gate)
    e_plain = float(jnp.mean(jnp.linalg.norm(x_plain - gt_gate[-1], axis=-1)))
    active = params.active.copy()
    dropped: list[int] = []
    while active.any():
        trial = PASParams(active=active, coords=params.coords)
        x_pas = eng.sample(eps_fn, x_gate, params=trial, cfg=cfg)
        e_pas = float(jnp.mean(jnp.linalg.norm(x_pas - gt_gate[-1], axis=-1)))
        if e_pas <= e_plain * (1.0 + 1e-4):
            break
        # drop the active step with the largest index first (latest corrections
        # have the least downstream benefit and the most history interaction)
        j_drop = int(np.max(np.nonzero(active)[0]))
        active[j_drop] = False
        dropped.append(j_drop)
    return PASParams(active=active, coords=params.coords), dropped


def _hist_slice(hist: SolverHist, s: slice) -> SolverHist:
    """Slice the batch axis of the history buffer (axis 1: (H, B, D))."""
    return SolverHist(buf=hist.buf[:, s], count=hist.count)


def _sgd_loop(solver, cfg: PASConfig, train_loss):
    """The Alg. 1 inner trainer as a pure function of one step's tensors.

    ``run(c0, x, u, d_norm, hist, gt_next, j) -> c_opt``: an
    ``n_sgd_iters``-step SGD scan over the shared coordinates C, with the
    loss built from ``solver.phi`` (pure jnp — the kernels in
    ``repro.kernels`` are forward-only, see ops.py).  This is the ONE
    implementation of the trainer: the reference loop jits it per step
    (``_make_sgd``) and the fused ``repro.engine.CalibrationEngine`` inlines
    it into its compiled program, so the two paths can never train
    different coordinates by construction.
    """

    def loss_fn(c, x, u, d_norm, hist, gt_next, j):
        d_tilde = jax.vmap(_corrected_direction, (0, None, 0, None))(
            u, c, d_norm, cfg.coord_mode)
        x_next = solver.phi(x, d_tilde, j, hist)
        return train_loss(x_next - gt_next)

    grad = jax.grad(loss_fn)

    def run(c0, x, u, d_norm, hist, gt_next, j):
        def body(c, _):
            return c - cfg.lr * grad(c, x, u, d_norm, hist, gt_next, j), None
        c, _ = jax.lax.scan(body, c0, None, length=cfg.n_sgd_iters)
        return c

    return run


def _make_sgd(solver, cfg: PASConfig, train_loss):
    """jit-compiled SGD loop over the shared coordinates C."""
    return jax.jit(_sgd_loop(solver, cfg, train_loss))


# ---------------------------------------------------------------------------
# Algorithm 2: corrected sampling
# ---------------------------------------------------------------------------


def pas_sample(solver: Solver, eps_fn: EpsFn, x_t: Array, params: PASParams,
               cfg: PASConfig = PASConfig()) -> Array:
    """Corrected sampling via the fused engine.

    .. deprecated::
        Compat shim for pre-``repro.api`` call sites.  New code should build
        a ``repro.api.Pipeline`` (``Pipeline.from_spec(spec, eps_fn)``) and
        call ``pipeline.sample`` — same fused engine underneath, plus
        calibration, artifacts, and spec-keyed caching in one object.

    Delegates to ``repro.engine.SamplingEngine`` — one jitted scan with the
    PAS projection folded into the fused step kernel.  The unfused
    ``pas_sample_trajectory`` below remains the reference implementation the
    engine is parity-tested against (tests/test_engine.py).
    """
    import warnings
    warnings.warn(
        "pas_sample(solver, eps_fn, ...) is deprecated; migrate to "
        "repro.api.Pipeline (Pipeline.from_spec(spec, eps_fn).sample) — see "
        "README 'Migrating from the legacy API'",
        DeprecationWarning, stacklevel=2)
    from repro.engine.engine import _engine_for_solver  # deferred: engine imports core
    return _engine_for_solver(solver).sample(eps_fn, x_t, params=params, cfg=cfg)


def pas_sample_trajectory(
    solver: Solver,
    eps_fn: EpsFn,
    x_t: Array,          # (B, D)
    params: PASParams,
    cfg: PASConfig = PASConfig(),
) -> tuple[Array, Array]:
    """Corrected sampling (paper Algorithm 2). Returns (x_0, xs (N+1, B, D)).

    ``params.active`` is host-side, so inactive steps compile to the plain
    solver update with *zero* PAS overhead — the adaptive-search promise.
    The Q buffer is only maintained up to the last active step and only
    allocated that many rows (``_sampling_q_cap``).
    """
    n = solver.nfe
    ts = solver.ts_jax
    last_active = int(np.max(np.nonzero(params.active)[0])) if params.active.any() else -1

    x = x_t
    hist = solver.init_hist(x_t)
    q = (_QBuffer.create(x_t, cap=_sampling_q_cap(last_active, n))
         if last_active >= 0 else None)
    xs = [x_t]

    for j in range(n):
        d = eps_fn(x, ts[j])
        if params.active[j]:
            w, d_norm = _batched_weights(q, d, cfg.n_basis)
            pw = _projected_coords(params.coords[j], w, d_norm,
                                   cfg.coord_mode).astype(d.dtype)
            d = (jnp.einsum("br,rbd->bd", pw[:, :-1], q.rows)
                 + pw[:, -1:] * d)
        x_next = solver.phi(x, d, j, hist, eps_fn)
        hist = solver.push(x, d, j, hist)
        if q is not None and j < last_active:
            q = q.push(d, j + 1)
        x = x_next
        xs.append(x)

    return x, jnp.stack(xs, axis=0)


def truncation_error_curve(xs: Array, gt: Array) -> Array:
    """Mean L2 distance to the teacher per step (paper Fig. 3). xs,gt (N+1,B,D)."""
    return jnp.mean(jnp.linalg.norm(xs - gt, axis=-1), axis=-1)
