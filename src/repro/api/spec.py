"""SamplerSpec: the declarative, hashable description of one PAS sampler.

A spec fixes everything the rest of the repo used to thread around as loose
``(name, ts, dtype)`` tuples plus implicit teacher/calibration conventions:

* the student solver and its NFE budget,
* the time schedule as a *family + parameters* (polynomial/Karras by default,
  ``raw`` for explicit grids such as the post-teleport schedule),
* the compute dtype,
* the teacher used for calibration trajectories,
* the full ``PASConfig``,
* the placement (``repro.parallel.MeshSpec``): which (dp, state) device grid
  the compiled sampling program runs on.  Placement participates in
  ``engine_key`` (a mesh engine is a different compiled binding) but not in
  the sampler's *math* — ``sans_mesh()`` is the projection artifacts compare
  on, so a calibrated artifact reloads onto any mesh shape.

Specs are frozen dataclasses — hashable (the canonical engine-cache key, see
``repro.engine.get_engine``) and JSON-round-trippable (the artifact header,
see ``repro.api.artifact``).  Solvers, schedules, and teachers resolve
through registries so downstream code can plug in new members without
touching this module.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.error_control import ErrorControlConfig
from repro.core.pas import PASConfig
from repro.core.schedules import polynomial_schedule, teacher_refinement
from repro.core.solvers import SOLVER_NAMES, Solver, make_solver
from repro.parallel.mesh import MeshSpec

__all__ = [
    "ErrorControlConfig", "MeshSpec", "ScheduleSpec", "TeacherSpec",
    "SamplerSpec",
    "register_solver", "register_schedule", "register_teacher",
    "solver_names", "schedule_kinds", "teacher_names",
    "spec_from_schedule",
]

# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

SolverFactory = Callable[[str, np.ndarray], Solver]
ScheduleBuilder = Callable[["ScheduleSpec", int], np.ndarray]

_SOLVERS: dict[str, SolverFactory] = {}
_SCHEDULES: dict[str, ScheduleBuilder] = {}
_TEACHERS: dict[str, SolverFactory] = {}


def register_solver(name: str, factory: SolverFactory = make_solver) -> None:
    """Register a student solver; ``factory(name, ts) -> Solver``."""
    _SOLVERS[name] = factory


def register_teacher(name: str, factory: SolverFactory = make_solver) -> None:
    """Register a teacher solver usable in ``TeacherSpec``."""
    _TEACHERS[name] = factory


def register_schedule(kind: str, builder: ScheduleBuilder) -> None:
    """Register a schedule family; ``builder(spec, nfe) -> ts (nfe+1,)``."""
    _SCHEDULES[kind] = builder


def solver_names() -> tuple[str, ...]:
    return tuple(sorted(_SOLVERS))


def teacher_names() -> tuple[str, ...]:
    return tuple(sorted(_TEACHERS))


def schedule_kinds() -> tuple[str, ...]:
    return tuple(sorted(_SCHEDULES))


for _n in SOLVER_NAMES:
    register_solver(_n)
    register_teacher(_n)


# ---------------------------------------------------------------------------
# schedule spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """A schedule family + its parameters (descending grid, paper eq. 19).

    ``polynomial`` is the EDM/Karras family; ``raw`` carries an explicit grid
    (e.g. the post-teleport schedule) as a float tuple so it stays hashable
    and JSON-serialisable.
    """

    kind: str = "polynomial"
    t_min: float = 0.002
    t_max: float = 80.0
    rho: float = 7.0
    points: tuple[float, ...] | None = None   # kind == "raw" only

    def __post_init__(self):
        object.__setattr__(self, "t_min", float(self.t_min))
        object.__setattr__(self, "t_max", float(self.t_max))
        object.__setattr__(self, "rho", float(self.rho))
        if self.points is not None:
            object.__setattr__(
                self, "points", tuple(float(t) for t in self.points))
        if self.kind == "raw" and self.points is None:
            raise ValueError("raw schedule requires explicit points")
        if not self.t_max > self.t_min > 0:
            raise ValueError(f"need t_max > t_min > 0, got "
                             f"[{self.t_min}, {self.t_max}]")

    @staticmethod
    def raw(ts: np.ndarray) -> "ScheduleSpec":
        """Wrap an explicit descending grid as a spec."""
        ts = np.asarray(ts, np.float64)
        return ScheduleSpec(kind="raw", t_min=float(ts[-1]),
                            t_max=float(ts[0]),
                            points=tuple(float(t) for t in ts))

    def build(self, nfe: int) -> np.ndarray:
        """The (nfe+1,) descending grid this spec describes."""
        if self.kind not in _SCHEDULES:
            raise ValueError(f"unknown schedule kind {self.kind!r}; "
                             f"registered: {schedule_kinds()}")
        ts = np.asarray(_SCHEDULES[self.kind](self, nfe), np.float64)
        if len(ts) != nfe + 1 or not np.all(np.diff(ts) < 0):
            raise ValueError(
                f"schedule {self.kind!r} produced an invalid grid for "
                f"nfe={nfe}: len={len(ts)}")
        return ts


def _polynomial_builder(spec: ScheduleSpec, nfe: int) -> np.ndarray:
    return polynomial_schedule(nfe, spec.t_min, spec.t_max, spec.rho)


def _raw_builder(spec: ScheduleSpec, nfe: int) -> np.ndarray:
    pts = np.asarray(spec.points, np.float64)
    if len(pts) != nfe + 1:
        raise ValueError(
            f"raw schedule has {len(pts)} points but nfe={nfe} needs {nfe + 1}")
    return pts


register_schedule("polynomial", _polynomial_builder)
register_schedule("raw", _raw_builder)


# ---------------------------------------------------------------------------
# teacher spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TeacherSpec:
    """The high-NFE teacher that defines ground-truth trajectories (§3.3)."""

    solver: str = "heun"
    nfe: int = 100

    def __post_init__(self):
        object.__setattr__(self, "nfe", int(self.nfe))


# ---------------------------------------------------------------------------
# sampler spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """One hashable object = solver + schedule + dtype + teacher + PASConfig."""

    solver: str = "ddim"
    nfe: int = 10
    schedule: ScheduleSpec = ScheduleSpec()
    dtype: str = "float32"
    teacher: TeacherSpec = TeacherSpec()
    pas: PASConfig = PASConfig()
    mesh: MeshSpec = MeshSpec()
    #: Error-controlled (adaptive-NFE) sampling; ``None`` = fixed grid.
    #: When set, sampling runs the embedded-pair PID solver between the
    #: schedule's endpoints (``repro.engine.adaptive``) and ``nfe`` only
    #: names the *calibration* grid PAS coordinates live on.
    error_control: Optional[ErrorControlConfig] = None

    def __post_init__(self):
        object.__setattr__(self, "nfe", int(self.nfe))
        if self.nfe < 1:
            raise ValueError(f"nfe must be >= 1, got {self.nfe}")
        if self.solver not in _SOLVERS:
            raise ValueError(f"unknown solver {self.solver!r}; "
                             f"registered: {solver_names()}")
        if self.teacher.solver not in _TEACHERS:
            raise ValueError(f"unknown teacher {self.teacher.solver!r}; "
                             f"registered: {teacher_names()}")
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype).name)

    # -- construction ------------------------------------------------------

    def ts(self) -> np.ndarray:
        """The bound (nfe+1,) descending student grid."""
        return self.schedule.build(self.nfe)

    def make_solver(self) -> Solver:
        return _SOLVERS[self.solver](self.solver, self.ts())

    def make_teacher(self, teacher_ts: np.ndarray) -> Solver:
        return _TEACHERS[self.teacher.solver](self.teacher.solver, teacher_ts)

    def teacher_grid(self) -> tuple[np.ndarray, np.ndarray, int]:
        """(student_ts, teacher_ts, M): teacher grid nesting the student grid.

        Polynomial schedules refine within the same family (eq. 19 is closed
        under sub-indexing); other kinds subdivide each student interval
        linearly — either way ``teacher_ts[::M+1] == student_ts`` exactly.
        """
        if self.teacher.nfe <= self.nfe:
            raise ValueError(
                f"teacher nfe ({self.teacher.nfe}) must exceed student nfe "
                f"({self.nfe})")
        m = teacher_refinement(self.nfe, self.teacher.nfe)
        s = self.ts()
        if self.schedule.kind == "polynomial":
            t = polynomial_schedule(self.nfe * (m + 1), self.schedule.t_min,
                                    self.schedule.t_max, self.schedule.rho)
        else:
            t = np.empty(self.nfe * (m + 1) + 1, np.float64)
            for j in range(self.nfe):
                t[j * (m + 1):(j + 1) * (m + 1) + 1] = np.linspace(
                    s[j], s[j + 1], m + 2)
            t[:: m + 1] = s   # shared nodes bit-exact
        return s, t, m

    @property
    def engine_key(self):
        """The engine-relevant projection: what a compiled binding depends on.

        Teacher and PASConfig are calibration-time concerns; two specs
        differing only there share one ``SamplingEngine``.  Placement is
        engine-relevant: a mesh engine is a different compiled program.
        So is error control: an adaptive spec appends its
        ``ErrorControlConfig`` to the key (a different compiled program),
        while ``error_control=None`` keeps the historical 5-tuple exactly —
        existing artifacts and cache entries for fixed-NFE specs are
        untouched.
        """
        key = (self.solver, self.nfe, self.schedule, self.dtype, self.mesh)
        if self.error_control is None:
            return key
        return key + (self.error_control,)

    def sans_mesh(self) -> "SamplerSpec":
        """The placement-free projection: the sampler's *math*.

        Two specs equal under ``sans_mesh()`` produce bit-identical fp32
        samples on any mesh shape; this is what ``PASArtifact`` compares when
        an artifact calibrated on one mesh is reloaded onto another.
        """
        return self.replace(mesh=MeshSpec())

    def replace(self, **kw) -> "SamplerSpec":
        return dataclasses.replace(self, **kw)

    # -- serialisation -----------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SamplerSpec":
        sched = d.get("schedule", {})
        pts = sched.get("points")
        ec = d.get("error_control")   # absent in pre-adaptive JSON: fixed grid
        return cls(
            solver=d["solver"], nfe=int(d["nfe"]),
            schedule=ScheduleSpec(
                kind=sched.get("kind", "polynomial"),
                t_min=sched.get("t_min", 0.002),
                t_max=sched.get("t_max", 80.0),
                rho=sched.get("rho", 7.0),
                points=tuple(pts) if pts is not None else None),
            dtype=d.get("dtype", "float32"),
            teacher=TeacherSpec(**d.get("teacher", {})),
            pas=PASConfig(**d.get("pas", {})),
            mesh=MeshSpec.from_dict(d.get("mesh")),
            error_control=(ErrorControlConfig.from_dict(ec)
                           if ec is not None else None),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "SamplerSpec":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# the old-keying shim
# ---------------------------------------------------------------------------


def spec_from_schedule(solver: str, ts: np.ndarray,
                       dtype=jnp.float32) -> SamplerSpec:
    """Lift an ad-hoc ``(name, ts, dtype)`` tuple into a canonical spec.

    If ``ts`` is bit-identical to a default-rho polynomial schedule over its
    own endpoints, the spec is the polynomial one (so legacy callers share
    engine-cache entries with spec-built pipelines); anything else becomes a
    ``raw`` schedule carrying the grid verbatim.
    """
    ts = np.asarray(ts, np.float64)
    nfe = len(ts) - 1
    cand = polynomial_schedule(nfe, float(ts[-1]), float(ts[0]))
    if np.array_equal(cand, ts):
        sched = ScheduleSpec(t_min=float(ts[-1]), t_max=float(ts[0]))
    else:
        sched = ScheduleSpec.raw(ts)
    return SamplerSpec(solver=solver, nfe=nfe, schedule=sched,
                       dtype=jnp.dtype(dtype).name)
