"""Pipeline: solver + schedule + correction as one object.

``Pipeline.from_spec(spec, eps_fn)`` is the repo's single public entry point
for PAS sampling.  It owns the fused ``SamplingEngine`` binding (shared
through the spec-keyed engine cache), runs calibration (paper Algorithm 1)
against the spec's teacher in one call, samples through the engine (Algorithm
2 folded into the fused kernels), and persists/restores the learned ~10
floats as a ``PASArtifact``:

    spec = SamplerSpec(solver="ddim", nfe=10)
    pipe = Pipeline.from_spec(spec, eps_fn, dim=D)
    pipe.calibrate(key=jax.random.key(0), batch=512)
    x0 = pipe.sample(key=jax.random.key(1), batch=64)
    pipe.save(run_dir)                       # ~10 floats + spec, checksummed
    pipe2 = Pipeline.load(run_dir, eps_fn)   # bit-identical sampler

The old per-module wiring (``make_solver`` → ``ground_truth_trajectory`` →
``calibrate`` → ``engine_for_solver``) remains available but is internal;
new call sites should go through this module.
"""
from __future__ import annotations

from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np

from repro.core import pas as pas_mod
from repro.core import solvers as solvers_mod
from repro.core.pas import PASParams
from repro.engine import get_calibration_engine_for_spec, get_engine_for_spec

from .artifact import PASArtifact
from .spec import SamplerSpec

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]

__all__ = ["Pipeline", "teacher_trajectory"]


def teacher_trajectory(spec: SamplerSpec, eps_fn: EpsFn, x_t: Array) -> Array:
    """Ground-truth trajectory on the spec's nested teacher grid (§3.3).

    Runs the registry-resolved ``spec.teacher`` on the refined grid and
    indexes every (M+1)-th state; returns gt (N+1, B, D) aligned to the
    student grid, gt[0] = x_t.  Compiled: one jitted (student interval x
    refinement) scan on the spec's mesh, cached per (spec, eps model) by the
    ``CalibrationEngine`` — the eager reference lives in
    ``core.solvers.ground_truth_trajectory``.
    """
    return get_calibration_engine_for_spec(spec).teacher_trajectory(
        eps_fn, x_t)


class Pipeline:
    """A spec-bound sampler: calibrate once, sample forever, save ~10 floats."""

    def __init__(self, spec: SamplerSpec, eps_fn: EpsFn,
                 dim: Optional[int] = None,
                 params: Optional[PASParams] = None,
                 diag: Optional[dict] = None):
        self.spec = spec
        self.eps_fn = eps_fn
        self.dim = dim
        self.params = params
        self.diag = diag or {}
        self.engine = get_engine_for_spec(spec.replace(error_control=None))
        self.solver = self.engine.solver
        self._adaptive_engine = None
        #: info dict from the most recent adaptive ``sample`` call (per-sample
        #: nfe / accept / reject counters); None until then.
        self.last_adaptive_info: Optional[dict] = None

    @classmethod
    def from_spec(cls, spec: SamplerSpec, eps_fn: EpsFn,
                  dim: Optional[int] = None) -> "Pipeline":
        """Bind a spec to an eps model. ``dim`` enables key-based sampling."""
        return cls(spec, eps_fn, dim=dim)

    # -- state -------------------------------------------------------------

    @property
    def calibrated(self) -> bool:
        return self.params is not None

    def set_params(self, params: Optional[PASParams],
                   diag: Optional[dict] = None) -> "Pipeline":
        """Hot-swap the learned coordinates (no recompilation of the plain
        path; the corrected prefix re-specialises per active pattern)."""
        self.params = params
        self.diag = diag or {}
        return self

    @property
    def is_adaptive(self) -> bool:
        """Whether sampling runs the error-controlled (adaptive-NFE) path."""
        ec = self.spec.error_control
        return ec is not None and ec.enabled

    @property
    def adaptive_engine(self):
        """The spec's cached ``AdaptiveEngine`` (error-controlled scan)."""
        if self._adaptive_engine is None:
            from repro.engine import get_adaptive_engine_for_spec
            self._adaptive_engine = get_adaptive_engine_for_spec(self.spec)
        return self._adaptive_engine

    @property
    def evals_per_sample(self) -> int:
        """Model evals one sample costs — the routing/accounting unit.

        Fixed grids: exactly ``engine.nfe`` (which already counts evals, not
        steps — a two-eval solver at N steps reports 2N).  Adaptive: the
        compiled worst case ``2 * max_iters``; per-sample actuals come back
        in the sample info and replace this bound at retire time.
        """
        if self.is_adaptive:
            return self.adaptive_engine.evals_per_sample
        return self.engine.nfe

    @property
    def mesh_spec(self):
        """The spec's ``MeshSpec`` (trivial = single-device)."""
        return self.spec.mesh

    def prior(self, key: Array, batch: int) -> Array:
        """x_T ~ N(0, T^2 I) at the spec's t_max (EDM prior convention).

        The prior is placed straight onto the engine mesh (batch over DP,
        state dim over the state axis), so sampling and calibration start
        from device-resident buffers in the compiled program's layout.
        """
        if self.dim is None:
            raise ValueError(
                "Pipeline needs dim for key-based sampling; pass dim= to "
                "from_spec/load or provide x_t explicitly")
        t_max = float(self.spec.ts()[0])
        return self.engine.shard(
            t_max * jax.random.normal(key, (batch, self.dim)))

    def _resolve_x(self, x_t, key, batch) -> Array:
        if x_t is not None:
            return self.engine.shard(x_t)
        if key is None or batch is None:
            raise ValueError("provide either x_t or (key, batch)")
        return self.prior(key, batch)

    # -- calibration (Algorithm 1) -----------------------------------------

    @property
    def calibration_engine(self):
        """The spec's cached ``CalibrationEngine`` (Alg. 1, fully compiled).

        Calibration always runs on the spec's *fixed* grid (Algorithm 1 is
        defined against the nested teacher there); the adaptive sampler then
        transfers the learned coordinates to its own grid by nearest cell.
        Dropping ``error_control`` from the cache key keeps one compiled
        calibrator per artifact family instead of one per rtol setting.
        """
        return get_calibration_engine_for_spec(
            self.spec.replace(error_control=None))

    def calibrate(self, key: Optional[Array] = None, batch: int = 256, *,
                  x_t: Optional[Array] = None,
                  gt: Optional[Array] = None) -> "Pipeline":
        """Learn the ~10 PAS parameters against the spec's teacher.

        Builds the nested teacher trajectory internally (or takes a
        precomputed ``gt`` aligned to the student grid) and runs the paper's
        adaptive search — the whole of Algorithm 1 as one compiled,
        mesh-placed program (``repro.engine.CalibrationEngine``).  When the
        noise batch is built here (the ``key`` path) its buffer is donated
        to the compiled program.  Returns ``self`` so
        ``.calibrate(...).save(d)`` chains.
        """
        owns_x = x_t is None
        x_t = self._resolve_x(x_t, key, batch)
        if gt is None:
            gt = self.teacher_trajectory(x_t)
        self.params, self.diag = self.calibration_engine.calibrate(
            self.eps_fn, x_t, gt, donate=owns_x)
        return self

    def teacher_trajectory(self, x_t: Array) -> Array:
        return teacher_trajectory(self.spec, self.eps_fn, x_t)

    # -- sampling (Algorithm 2) --------------------------------------------

    def sample(self, x_t: Optional[Array] = None, *,
               key: Optional[Array] = None, batch: Optional[int] = None,
               use_pas: bool = True, donate_x: bool = False) -> Array:
        """One fused engine pass ts[0] -> ts[N]; corrected iff calibrated.

        ``donate_x=True`` donates the input buffer to the compiled scan
        (serve-loop flushes: the flush batch is never reused); the caller's
        ``x_t`` is invalidated.

        When the spec carries an enabled ``error_control`` the sample runs
        the adaptive engine instead of the fixed grid; per-sample NFE
        counters land in ``self.last_adaptive_info``.
        """
        x_t = self._resolve_x(x_t, key, batch)
        params = self.params if use_pas else None
        if self.is_adaptive:
            x, self.last_adaptive_info = self.adaptive_engine.sample_with_info(
                self.eps_fn, x_t, params=params, cfg=self.spec.pas,
                donate_x=donate_x)
            return x
        return self.engine.sample(self.eps_fn, x_t, params=params,
                                  cfg=self.spec.pas, donate_x=donate_x)

    def sample_async(self, x_t: Optional[Array] = None, *,
                     key: Optional[Array] = None, batch: Optional[int] = None,
                     use_pas: bool = True, donate_x: bool = False,
                     want_evals: bool = False):
        """Non-blocking sample: dispatch the compiled scan, return the future.

        Pads the batch to a DP-divisible row count under a mesh (repeated
        input rows as ballast — always in-distribution), dispatches the
        engine, and returns ``(y, valid)`` where ``y`` is the *device
        future* (JAX async dispatch: reading it — ``np.asarray``,
        ``block_until_ready`` — is what blocks) and ``valid`` is the
        host-side boolean row mask selecting the caller's real rows out of
        the padded result.  This is the serve scheduler's flush primitive:
        it lets host staging of the next batch overlap device compute on
        this one.  ``donate_x=True`` donates the (padded) input buffer —
        the caller must not reuse ``x_t``, and must never pass a buffer a
        still-in-flight flush owns (the engine rejects already-donated
        buffers).

        ``want_evals=True`` appends a third element: a per-row device array
        of model evals actually executed (the adaptive path's honest NFE;
        on a fixed grid, a constant ``engine.nfe`` per row).  The scheduler
        uses it for retire-time accounting — it rides the same async
        dispatch, so requesting it does not block.
        """
        x_t = self._resolve_x(x_t, key, batch)
        n = int(x_t.shape[0])
        x_t, pad = self.mesh_spec.pad_rows(x_t)
        params = self.params if use_pas else None
        if self.is_adaptive:
            y, info = self.adaptive_engine.sample_with_info(
                self.eps_fn, x_t, params=params, cfg=self.spec.pas,
                donate_x=donate_x)
            self.last_adaptive_info = info
            evals = info["nfe"]
        else:
            y = self.engine.sample(self.eps_fn, x_t, params=params,
                                   cfg=self.spec.pas, donate_x=donate_x)
            evals = np.full(n + pad, self.engine.nfe, dtype=np.int64)
        valid = np.zeros(n + pad, dtype=bool)
        valid[:n] = True
        if want_evals:
            return y, valid, evals
        return y, valid

    def precompile(self, batch: int, *, use_pas: bool = True,
                   donate_x: bool = True, calibration: bool = False,
                   cache=None, model_key: Optional[str] = None) -> dict:
        """AOT-compile the exact variant a serve flush would dispatch.

        ``batch`` is padded to the spec mesh's DP divisor exactly like
        ``sample_async`` pads flush buffers, so the warmed program is the
        one the scheduler runs — not a same-batch sibling that would still
        pay a first-flush compile.  ``use_pas=True`` warms the corrected
        variant when the pipeline is calibrated (plain otherwise — the
        corrected program's active-pattern key does not exist before
        calibration); adaptive specs warm the masked-scan program.
        ``calibration=True`` additionally AOT-compiles the calibration
        engine's programs (teacher scan, Algorithm 1, final gate) for this
        batch.  ``cache``/``model_key`` feed the persistent compile cache
        (see ``repro.engine.compile_cache``); returns the per-program
        placement reports.
        """
        if self.dim is None:
            raise ValueError("precompile needs dim; pass dim= to "
                             "from_spec/load")
        batch = int(batch)
        full = batch + self.mesh_spec.pad_batch(batch)
        params = self.params if use_pas else None
        eng = self.adaptive_engine if self.is_adaptive else self.engine
        out = {"sample": eng.aot_compile(
            self.eps_fn, full, self.dim, params=params, cfg=self.spec.pas,
            donate_x=donate_x, cache=cache, model_key=model_key)}
        if calibration:
            out["calibration"] = self.calibration_engine.aot_compile(
                self.eps_fn, full, self.dim, cache=cache,
                model_key=model_key)
        return out

    def trajectory(self, x_t: Optional[Array] = None, *,
                   key: Optional[Array] = None, batch: Optional[int] = None,
                   use_pas: bool = True) -> tuple[Array, Array]:
        """Full path (x_0, xs (N+1, B, D)) via the reference (unfused) path."""
        x_t = self._resolve_x(x_t, key, batch)
        if use_pas and self.params is not None:
            return pas_mod.pas_sample_trajectory(
                self.solver, self.eps_fn, x_t, self.params, self.spec.pas)
        xs, _ = solvers_mod.sample_trajectory(self.solver, self.eps_fn, x_t)
        return xs[-1], xs

    def stats(self) -> dict:
        """Spec + calibration + compiled-engine state, one dict."""
        from repro.engine import (calibration_engine_cache_stats,
                                  engine_cache_stats)
        out = {
            "spec": self.spec.to_dict(),
            "calibrated": self.calibrated,
            "engine_compiled_variants": self.engine.compiled_variants(),
            "engine_cache": engine_cache_stats(),
            "calibration_engine_cache": calibration_engine_cache_stats(),
            "mesh_devices": (self.engine.mesh.size
                             if self.engine.mesh is not None else 1),
        }
        if self.params is not None:
            out["n_stored_params"] = int(self.params.n_stored_params)
            out["corrected_paper_steps"] = self.params.corrected_paper_steps()
        if self.diag:
            out["calibration_diag"] = {
                k: self.diag[k]
                for k in ("corrected_steps_paper_index", "n_stored_params",
                          "final_l2_to_gt", "final_gate_dropped")
                if k in self.diag}
        return out

    # -- persistence -------------------------------------------------------

    def save(self, base_dir: str | Path) -> Path:
        """Persist (spec, params, diag) as a checksummed ``PASArtifact``."""
        if self.params is None:
            raise ValueError("pipeline is not calibrated; nothing to save "
                             "(call .calibrate(...) first)")
        return PASArtifact(self.spec, self.params, self.diag).save(base_dir)

    @classmethod
    def load(cls, base_dir: str | Path, eps_fn: EpsFn,
             dim: Optional[int] = None,
             expected_spec: Optional[SamplerSpec] = None,
             mesh=None) -> "Pipeline":
        """Rebuild a calibrated pipeline from a ``PASArtifact`` on disk.

        ``mesh`` (a ``repro.parallel.MeshSpec``) re-places the loaded spec:
        the ~10 learned floats are placement-free, so an artifact calibrated
        on one mesh shape serves on any other — including a single device.
        Without it the artifact's recorded mesh is rebuilt verbatim.
        """
        art = PASArtifact.load(base_dir, expected_spec=expected_spec,
                               mesh=mesh)
        return cls(art.spec, eps_fn, dim=dim, params=art.params,
                   diag=dict(art.diag))

    def __repr__(self) -> str:
        state = "calibrated" if self.calibrated else "uncalibrated"
        n = self.params.n_stored_params if self.calibrated else 0
        return (f"Pipeline({self.spec.solver}@{self.spec.nfe}nfe, "
                f"{self.spec.dtype}, {state}, {n} stored params)")
