"""repro.api — the public surface: SamplerSpec → Pipeline → PASArtifact.

Everything downstream (launchers, serving, examples, benchmarks) builds PAS
samplers through this package; the per-module wiring underneath
(``repro.core`` / ``repro.engine``) is internal.
"""

from repro.core.pas import PASConfig, PASParams

from .artifact import (ARTIFACT_DIRNAME, ARTIFACT_VERSION, ArtifactError,
                       PASArtifact)
from .pipeline import Pipeline, teacher_trajectory
from .spec import (MeshSpec, SamplerSpec, ScheduleSpec, TeacherSpec,
                   register_schedule, register_solver, register_teacher,
                   schedule_kinds, solver_names, spec_from_schedule,
                   teacher_names)

__all__ = [
    "MeshSpec", "SamplerSpec", "ScheduleSpec", "TeacherSpec",
    "Pipeline", "teacher_trajectory",
    "PASArtifact", "ArtifactError", "ARTIFACT_VERSION", "ARTIFACT_DIRNAME",
    "PASConfig", "PASParams",
    "register_solver", "register_schedule", "register_teacher",
    "solver_names", "schedule_kinds", "teacher_names",
    "spec_from_schedule",
]
