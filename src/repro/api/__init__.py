"""repro.api — the public surface: SamplerSpec → Pipeline → PASArtifact.

Everything downstream (launchers, serving, examples, benchmarks) builds PAS
samplers through this package; the per-module wiring underneath
(``repro.core`` / ``repro.engine``) is internal.

The serving types are part of this surface too — ``Request``,
``ServeConfig``, ``ServeHandle``, ``DiffusionServer``, the multi-pipeline
``PipelineRouter``, and the ``runtime.traffic`` arrival generators — so
callers never import from ``repro.runtime.*``.  They resolve lazily (PEP
562): ``repro.runtime`` builds *on top of* this package, so importing it
eagerly here would be circular, and a spec-only consumer shouldn't pay for
the serving stack at import time.
"""

from repro.core.pas import PASConfig, PASParams

from .artifact import (ARTIFACT_DIRNAME, ARTIFACT_VERSION, ArtifactError,
                       PASArtifact)
from .pipeline import Pipeline, teacher_trajectory
from .spec import (ErrorControlConfig, MeshSpec, SamplerSpec, ScheduleSpec,
                   TeacherSpec, register_schedule, register_solver,
                   register_teacher, schedule_kinds, solver_names,
                   spec_from_schedule, teacher_names)

# serving surface, re-exported from repro.runtime on first access
_SERVING_EXPORTS = {
    "Arrival": "repro.runtime.traffic",
    "DiffusionServer": "repro.runtime.serve_loop",
    "NFELadder": "repro.runtime.ladder",
    "PRIORITIES": "repro.runtime.scheduler",
    "PipelineRouter": "repro.runtime.router",
    "Request": "repro.runtime.serve_loop",
    "ServeConfig": "repro.runtime.serve_loop",
    "ServeHandle": "repro.runtime.scheduler",
    "ServeScheduler": "repro.runtime.scheduler",
    "StragglerMonitor": "repro.runtime.train_loop",
    "TrainLoopConfig": "repro.runtime.train_loop",
    "load_trace": "repro.runtime.traffic",
    "poisson_arrivals": "repro.runtime.traffic",
    "replay": "repro.runtime.traffic",
    "run_train_loop": "repro.runtime.train_loop",
    "save_trace": "repro.runtime.traffic",
}

__all__ = [
    "ErrorControlConfig", "MeshSpec", "SamplerSpec", "ScheduleSpec",
    "TeacherSpec",
    "Pipeline", "teacher_trajectory",
    "PASArtifact", "ArtifactError", "ARTIFACT_VERSION", "ARTIFACT_DIRNAME",
    "PASConfig", "PASParams",
    "register_solver", "register_schedule", "register_teacher",
    "solver_names", "schedule_kinds", "teacher_names",
    "spec_from_schedule",
    *sorted(_SERVING_EXPORTS),
]


def __getattr__(name: str):
    module = _SERVING_EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value              # cache: next access skips this hook
    return value


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(__all__))
