"""PASArtifact: the paper's ~10 learned floats as a durable, versioned file.

An artifact is the triple ``(SamplerSpec, PASParams, calibration diag)``
persisted under ``<dir>/pas_artifact/`` through the ``repro.checkpoint``
primitives — per-leaf sha256 checksums, atomic rename commit — so a
calibrated sampler becomes a hot-swappable file a few hundred bytes of
payload large.  Loading re-verifies checksums (tampering raises) and the
spec header round-trips exactly, so ``Pipeline.load(dir, eps_fn)`` rebuilds
a sampler whose output is bit-identical to the in-memory calibrated one.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointError, latest_step, restore, save
from repro.core.pas import PASParams

from .spec import SamplerSpec

__all__ = ["PASArtifact", "ArtifactError", "ARTIFACT_VERSION",
           "ARTIFACT_DIRNAME"]

ARTIFACT_VERSION = 1
ARTIFACT_DIRNAME = "pas_artifact"
_FORMAT = "pas-artifact"


class ArtifactError(CheckpointError):
    pass


def _jsonable(obj: Any) -> Any:
    """Best-effort conversion of calibration diagnostics to JSON types."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.generic,)):
        return obj.item()
    if isinstance(obj, (np.ndarray, jax.Array)):
        return np.asarray(obj).tolist()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return str(obj)


@dataclasses.dataclass(frozen=True)
class PASArtifact:
    """(spec, params, diag) with save/load under ``<dir>/pas_artifact/``."""

    spec: SamplerSpec
    params: PASParams
    diag: dict = dataclasses.field(default_factory=dict)

    # -- paths -------------------------------------------------------------

    @staticmethod
    def root(base_dir: str | Path) -> Path:
        return Path(base_dir) / ARTIFACT_DIRNAME

    @staticmethod
    def exists(base_dir: str | Path) -> bool:
        d = PASArtifact.root(base_dir)
        return d.is_dir() and latest_step(d) is not None

    # -- save / load -------------------------------------------------------

    def save(self, base_dir: str | Path) -> Path:
        """Checksummed, atomically-committed write. Returns the payload dir."""
        tree = {
            "active": np.asarray(self.params.active, bool),
            "coords": np.asarray(self.params.coords),
        }
        extra = {
            "format": _FORMAT,
            "version": ARTIFACT_VERSION,
            "spec": self.spec.to_dict(),
            "diag": _jsonable(self.diag),
            "n_stored_params": int(self.params.n_stored_params),
        }
        return save(self.root(base_dir), step=0, tree=tree, extra=extra)

    @classmethod
    def load(cls, base_dir: str | Path,
             expected_spec: SamplerSpec | None = None,
             mesh=None) -> "PASArtifact":
        """Load + verify. Raises ``ArtifactError`` on a missing/foreign/
        version-incompatible artifact and ``CheckpointError`` on corruption.

        Placement is not part of the sampler's identity: the spec header is
        compared against ``expected_spec`` modulo mesh (``sans_mesh()``), so
        an artifact calibrated on an 8-device mesh loads cleanly into a
        single-device (or any other) serving topology.  Pass ``mesh`` (a
        ``repro.parallel.MeshSpec``) to re-place the loaded spec; otherwise
        the artifact's recorded mesh is kept verbatim.
        """
        d = cls.root(base_dir)
        step = latest_step(d) if d.is_dir() else None
        if step is None:
            raise ArtifactError(f"no PAS artifact under {d}")
        manifest = json.loads(
            (d / f"step_{step:08d}" / "manifest.json").read_text())
        extra = manifest.get("extra", {})
        if extra.get("format") != _FORMAT:
            raise ArtifactError(f"{d} is not a PAS artifact "
                                f"(format={extra.get('format')!r})")
        if extra.get("version") != ARTIFACT_VERSION:
            raise ArtifactError(
                f"unsupported artifact version {extra.get('version')!r} "
                f"(this build reads version {ARTIFACT_VERSION})")
        spec = SamplerSpec.from_dict(extra["spec"])
        if (expected_spec is not None
                and spec.sans_mesh() != expected_spec.sans_mesh()):
            raise ArtifactError(
                f"artifact spec does not match the expected spec:\n"
                f"  artifact: {spec.to_json()}\n"
                f"  expected: {expected_spec.to_json()}")
        if mesh is not None:
            spec = spec.replace(mesh=mesh)

        # shapes/dtypes come from the manifest itself, so the payload
        # round-trips bit-exactly whatever dtype it was calibrated in
        metas = sorted(manifest["leaves"].values(), key=lambda v: v["index"])
        like = {
            "active": jax.ShapeDtypeStruct(tuple(metas[0]["shape"]),
                                           jnp.dtype(metas[0]["dtype"])),
            "coords": jax.ShapeDtypeStruct(tuple(metas[1]["shape"]),
                                           jnp.dtype(metas[1]["dtype"])),
        }
        tree, _ = restore(d, like, step=step, verify=True)
        params = PASParams(active=np.asarray(tree["active"], bool),
                           coords=tree["coords"])
        return cls(spec=spec, params=params, diag=extra.get("diag", {}))
