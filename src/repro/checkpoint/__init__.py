from .checkpoint import (CheckpointError, cleanup, latest_step, restore, save,
                         save_async)

__all__ = ["CheckpointError", "cleanup", "latest_step", "restore", "save",
           "save_async"]
