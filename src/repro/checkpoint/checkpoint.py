"""Sharded, checksummed, atomically-committed checkpoints with async save and
elastic (re-shardable) restore — no tensorstore/orbax offline, built here.

Layout:
  <dir>/step_<n>.tmp/...      (in-progress)
  <dir>/step_<n>/manifest.json + <leaf>.npy   (committed via atomic rename)
  <dir>/LATEST                (pointer file, written after commit)

Leaves are saved in a mesh-agnostic canonical layout (fully addressable host
arrays), so restore can re-shard onto a different mesh ("elastic" restarts) —
restore takes an optional shardings pytree and device_puts accordingly.
Integrity: per-leaf sha256 in the manifest, verified on load.
"""
from __future__ import annotations

import concurrent.futures as cf
import hashlib
import json
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "cleanup",
           "CheckpointError"]


class CheckpointError(RuntimeError):
    pass


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path) or "leaf"
        named.append((name, leaf))
    return named, treedef


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save(ckpt_dir: str | Path, step: int, tree, extra: Optional[dict] = None
         ) -> Path:
    """Blocking save. Returns the committed directory."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    named, _ = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i:04d}_{name[:80]}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][fname] = {
            "sha256": _sha256(arr), "shape": list(arr.shape),
            "dtype": str(arr.dtype), "index": i,
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                                  # atomic commit
    (ckpt_dir / "LATEST").write_text(str(step))
    return final


_EXECUTOR: Optional[cf.ThreadPoolExecutor] = None


def save_async(ckpt_dir: str | Path, step: int, tree,
               extra: Optional[dict] = None) -> cf.Future:
    """Non-blocking save: device_get happens on the caller thread (cheap on
    CPU; on TPU it snapshots), serialisation runs in a worker thread."""
    global _EXECUTOR
    if _EXECUTOR is None:
        _EXECUTOR = cf.ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix="ckpt")
    snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    return _EXECUTOR.submit(save, ckpt_dir, step, snapshot, extra)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    latest = ckpt_dir / "LATEST"
    if latest.exists():
        step = int(latest.read_text().strip())
        if (ckpt_dir / f"step_{step:08d}" / "manifest.json").exists():
            return step
    # fall back to scanning committed directories (LATEST write can race a
    # crash — resumability must not depend on it)
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                   if p.is_dir() and not p.name.endswith(".tmp")
                   and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, like, step: Optional[int] = None,
            shardings=None, verify: bool = True) -> tuple[Any, dict]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of Shardings
    for elastic re-sharding onto the current mesh."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise CheckpointError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    named, treedef = _flatten(like)
    by_index = {v["index"]: (k, v) for k, v in manifest["leaves"].items()}
    if len(by_index) != len(named):
        raise CheckpointError(
            f"leaf count mismatch: ckpt={len(by_index)} vs tree={len(named)}")

    shard_list = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None or hasattr(x, "device_set"))
        if shardings is not None else [None] * len(named))

    leaves = []
    for i, (name, leaf) in enumerate(named):
        fname, meta = by_index[i]
        arr = np.load(d / fname)
        if verify and _sha256(arr) != meta["sha256"]:
            raise CheckpointError(f"checksum mismatch for {fname}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise CheckpointError(
                f"shape mismatch for {fname}: {arr.shape} vs {leaf.shape}")
        sh = shard_list[i]
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jnp_asarray(arr, leaf))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


def jnp_asarray(arr: np.ndarray, like) -> Any:
    import jax.numpy as jnp
    return jnp.asarray(arr, dtype=getattr(like, "dtype", arr.dtype))


def cleanup(ckpt_dir: str | Path, keep: int = 3) -> None:
    ckpt_dir = Path(ckpt_dir)
    dirs = sorted((p for p in ckpt_dir.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp")),
                  key=lambda p: int(p.name.split("_")[1]))
    for p in dirs[:-keep]:
        shutil.rmtree(p)
