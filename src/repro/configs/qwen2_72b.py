"""qwen2-72b [dense]: GQA with QKV bias.

[arXiv:2407.10671; hf] 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    pattern=(LayerSpec("attn"),),
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
    max_position=131072,
    sub_quadratic=False,
))
