"""qwen1.5-0.5b [dense]: MHA (kv == heads) with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf] 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    pattern=(LayerSpec("attn"),),
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
    max_position=32768,
    sub_quadratic=False,
    tie_embeddings=True,
))
