"""internvl2-1b [vlm]: InternViT frontend (stub) + InternLM2-1B backbone.

[arXiv:2404.16821; hf] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, frontend_len, d_model) prepended to the text.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    pattern=(LayerSpec("attn"),),
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
    frontend="vision_patches",
    frontend_len=256,
    max_position=32768,
    sub_quadratic=False,
    notes="InternLM2 decoder; vision patches precomputed (frontend stub).",
))
