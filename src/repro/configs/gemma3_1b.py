"""gemma3-1b [dense]: 5 local : 1 global attention pattern, 128k-class context.

[hf:google/gemma-3-1b-pt; unverified] 26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144.  Local window 512; pattern-grouped scan handles the
5:1 mix (4 groups of 6 + 2 remainder local layers).

long_500k eligibility: the dominant (5/6) layers have bounded-window KV; the
rare global layers are O(L) per decoded token — included, noted in DESIGN.md.
"""
from .base import LayerSpec, ModelConfig, register

_LOCAL = LayerSpec("attn", window=512)
_GLOBAL = LayerSpec("attn", window=None)

CONFIG = register(ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    act="geglu",
    norm="rmsnorm",
    rope_theta=1e6,
    logits_soft_cap=30.0,
    max_position=131072,
    sub_quadratic=True,
    tie_embeddings=True,
))
