"""granite-34b [dense]: deep MQA code model, llama-style blocks.

[arXiv:2405.04324; hf] 88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    pattern=(LayerSpec("attn"),),
    act="gelu",            # code-model MLP (d_ff = 4x d_model)
    norm="layernorm",
    rope_theta=1e4,
    max_position=8192,
    sub_quadratic=False,
    notes="MQA (kv=1): KV projections replicated under TP, Q sharded.",
))
