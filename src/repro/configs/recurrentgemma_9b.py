"""recurrentgemma-9b [hybrid]: Griffin — RG-LRU + local attention, 1:2.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000.  Pattern (rglru, rglru, local-attn) -> 12 groups + 2 remainder
RG-LRU layers.  Fixed-size recurrent state + bounded window -> long_500k.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    pattern=(LayerSpec("rglru"), LayerSpec("rglru"),
             LayerSpec("attn", window=2048)),
    act="geglu",
    norm="rmsnorm",
    rope_theta=1e4,
    lru_width=4096,
    conv_width=4,
    max_position=1 << 20,
    sub_quadratic=True,
))
