"""edm-tiny: the paper's own model kind — a small EDM denoiser config.

The PAS paper corrects sampling of EDM-parameterised diffusion models
(CIFAR10-scale).  This config drives examples/train_denoiser.py and the
PAS-on-a-learned-model tests: an MLP denoiser over flattened images with
EDM preconditioning (diffusion/edm.py).  It is registered alongside the zoo
so launchers can select it, but it is not one of the 40 dry-run cells.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="edm-tiny",
    family="diffusion",
    n_layers=4,            # denoiser MLP depth
    d_model=256,           # hidden width
    n_heads=0,
    n_kv_heads=0,
    d_ff=512,
    vocab_size=0,
    pattern=(LayerSpec("attn"),),  # unused by the MLP denoiser
    rope_theta=None,
    dtype="float32",
    notes="image_dim set by the diffusion example (e.g. 8x8x3).",
))
