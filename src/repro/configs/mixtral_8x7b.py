"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
SWA (window 4096) bounds the decode KV cache -> long_500k eligible.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    pattern=(LayerSpec("attn", window=4096),),
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
    n_experts=8,
    moe_top_k=2,
    max_position=131072,
    sub_quadratic=True,
))
