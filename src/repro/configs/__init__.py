"""Architecture registry: one module per assigned architecture (+ paper's own).

``--arch <id>`` anywhere in the launchers resolves through get_config().
"""
from . import (falcon_mamba_7b, gemma3_1b, granite_34b, internvl2_1b,
               llama4_scout_17b_16e, mixtral_8x7b, qwen1_5_0_5b, qwen2_72b,
               recurrentgemma_9b, whisper_small)
from . import edm_tiny
from .base import ARCH_IDS, LayerSpec, ModelConfig, get_config, register

ARCH_MODULES = (internvl2_1b, falcon_mamba_7b, qwen2_72b, qwen1_5_0_5b,
                granite_34b, gemma3_1b, whisper_small, llama4_scout_17b_16e,
                mixtral_8x7b, recurrentgemma_9b, edm_tiny)

# the ten assigned zoo architectures (excludes the paper's own EDM config)
ASSIGNED_ARCHS = (
    "internvl2-1b", "falcon-mamba-7b", "qwen2-72b", "qwen1.5-0.5b",
    "granite-34b", "gemma3-1b", "whisper-small", "llama4-scout-17b-16e",
    "mixtral-8x7b", "recurrentgemma-9b",
)

__all__ = ["ARCH_IDS", "ASSIGNED_ARCHS", "LayerSpec", "ModelConfig",
           "get_config", "register"]
