"""whisper-small [audio]: encoder-decoder; conv/audio frontend is a STUB.

[arXiv:2212.04356; unverified] 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865.  Per the assignment, the modality frontend provides precomputed
frame embeddings: input_specs() supplies encoder states (B, 1500, d_model);
the framework runs the 12-layer decoder (self-attn + cross-attn).
decode shapes run the decoder with self+cross KV caches; long_500k skipped
(full attention + 448-token architectural decoder context).
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    pattern=(LayerSpec("attn", cross_attn=True),),
    act="gelu",
    norm="layernorm",
    rope_theta=None,       # learned absolute positions
    is_encoder_decoder=True,
    frontend="audio_frames",
    frontend_len=1500,
    max_position=448,
    sub_quadratic=False,
))
