"""Model configuration schema + registry for the assigned architecture zoo.

Heterogeneous layer stacks (gemma3's 5 local : 1 global, recurrentgemma's
1 attn : 2 RG-LRU) are expressed as a repeating ``pattern`` of LayerSpecs.
The transformer scans over ``n_layers // len(pattern)`` homogeneous groups
(keeping HLO size O(1) in depth) and unrolls the ``n_layers % len(pattern)``
remainder — every attention call site keeps a *static* window/global config,
so kernels never branch on traced flags.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["LayerSpec", "ModelConfig", "register", "get_config", "ARCH_IDS"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer position inside the repeating pattern."""

    kind: str = "attn"            # "attn" | "mamba" | "rglru"
    window: Optional[int] = None  # sliding-window size (None = full attention)
    cross_attn: bool = False      # add cross-attention (enc-dec decoders)

    @property
    def is_global(self) -> bool:
        return self.kind == "attn" and self.window is None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    pattern: tuple[LayerSpec, ...] = (LayerSpec("attn"),)
    qkv_bias: bool = False
    act: str = "swiglu"           # swiglu | geglu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    rope_theta: Optional[float] = 1e4   # None -> learned absolute positions
    logits_soft_cap: Optional[float] = None
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # SSM (mamba-1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None
    # RG-LRU (griffin)
    lru_width: Optional[int] = None
    conv_width: int = 4
    # enc-dec + modality frontend stubs
    is_encoder_decoder: bool = False
    frontend: str = "none"        # none | vision_patches | audio_frames
    frontend_len: int = 0         # stub prefix length (patches / enc frames)
    # misc
    max_position: int = 131072
    sub_quadratic: bool = False   # eligible for the long_500k cell
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    notes: str = ""

    # ----- derived -----
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        if self.dt_rank is not None:
            return self.dt_rank
        return max(self.d_model // 16, 1)

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers % len(self.pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # every zoo arch has an AR decoder stack

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        e, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, dh = self.n_heads, self.n_kv_heads, self.head_dim_
        per_layer = 0.0
        for spec in self.pattern:
            if spec.kind == "attn":
                p = e * (h * dh) + 2 * e * (kv * dh) + (h * dh) * e
                if spec.cross_attn:
                    p *= 2
            elif spec.kind == "mamba":
                di, n, r = self.d_inner, self.ssm_state, self.dt_rank_
                p = e * 2 * di + di * self.d_conv + di * (r + 2 * n) + r * di \
                    + di * n + di + di * e
            else:  # rglru
                w = self.lru_width_
                p = 2 * e * w + w * self.conv_width + 3 * w + w * e
            if spec.kind != "mamba":
                if self.n_experts > 0:
                    n_ff = 3 if self.act in ("swiglu", "geglu") else 2
                    p += self.n_experts * n_ff * e * f + e * self.n_experts
                    if self.shared_expert:
                        p += n_ff * e * f
                else:
                    n_ff = 3 if self.act in ("swiglu", "geglu") else 2
                    p += n_ff * e * f
            per_layer += p
        per_layer /= len(self.pattern)
        total = self.n_layers * per_layer + v * e
        if not self.tie_embeddings:
            total += v * e
        if self.is_encoder_decoder:
            total *= 1.0  # decoder-only accounting; encoder is a stub
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        e, f = self.d_model, self.d_ff
        n_ff = 3 if self.act in ("swiglu", "geglu") else 2
        inactive = (self.n_experts - self.moe_top_k) * n_ff * e * f
        return int(self.param_count() - self.n_layers * inactive)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test-sized config of the same family (scan path preserved)."""
        pat = len(self.pattern)
        small = dict(
            n_layers=2 * pat + min(self.n_remainder, 1),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            # tiny token counts make capacity drops likely and nondeterministic
            # across call shapes; smoke tests want routing-exact equivalence
            capacity_factor=4.0 if self.n_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 4) if self.ssm_state else 0,
            dt_rank=4 if self.ssm_state else None,
            lru_width=64 if self.lru_width or any(
                s.kind == "rglru" for s in self.pattern) else None,
            frontend_len=8 if self.frontend != "none" else 0,
            dtype="float32",
            pattern=tuple(
                dataclasses.replace(s, window=min(s.window, 8) if s.window else None)
                for s in self.pattern),
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import ARCH_MODULES  # ensure registration side effects ran
    del ARCH_MODULES
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def ARCH_IDS() -> list[str]:
    from . import ARCH_MODULES
    del ARCH_MODULES
    return sorted(_REGISTRY)
