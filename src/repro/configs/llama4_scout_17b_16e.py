"""llama4-scout-17b-a16e [moe]: 16 routed experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1, early fusion (stub).
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(LayerSpec("attn"),),
    act="swiglu",
    norm="rmsnorm",
    rope_theta=5e5,
    n_experts=16,
    moe_top_k=1,
    shared_expert=True,
    max_position=131072,
    sub_quadratic=False,
    notes="early-fusion multimodal -> text backbone only (frontend stub rule).",
))
