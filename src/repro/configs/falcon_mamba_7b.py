"""falcon-mamba-7b [ssm]: pure Mamba-1, attention-free.

[arXiv:2410.05355; unverified] 64L d_model=4096 (attn-free) d_ff=0
vocab=65024, ssm_state=16.  O(1) per-token state -> runs long_500k.
"""
from .base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    pattern=(LayerSpec("mamba"),),
    act="swiglu",          # unused (mamba blocks have no separate MLP)
    norm="rmsnorm",
    rope_theta=None,
    ssm_state=16,
    d_conv=4,
    expand=2,
    max_position=1 << 20,
    sub_quadratic=True,
    tie_embeddings=True,
    notes="mamba1 blocks only; d_inner=8192, dt_rank=256.",
))
